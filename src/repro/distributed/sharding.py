"""Sharding regimes for the production mesh.

Two regimes (DESIGN.md §4):

* ``tp`` (train / prefill): batch over DP axes; attention q-heads, FFN
  columns, experts, SSM/LRU channels over ``model``.  Archs whose head count
  does not divide the model axis (qwen2:14, smollm:15, whisper:8, rg:10)
  fall back to sequence-parallel attention (q positions sharded over
  ``model``, kv replicated) — the residual stream stays replicated over
  ``model`` either way.
* ``decode`` (serve): batch over DP axes; KV-cache *sequence* dim over
  ``model`` (flash-decoding partial softmax); experts over ``model``;
  attention projection weights replicated (q-heads unsharded).

All rules are name-based over the param pytree; divisibility is checked
against the concrete axis size and falls back to replication (recorded by
`explain()` for the roofline notes).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models.api import MeshAxes, ModelConfig
from repro.models import transformer as T

STACKS = ("layers", "units", "tail", "enc_layers")


def _names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        else:
            out.append(str(getattr(k, "idx", k)))
    return tuple(out)


def _div(n, tp):
    return tp > 0 and n % tp == 0


def param_specs(cfg: ModelConfig, axes: MeshAxes, tp: int, regime: str,
                n_dev: int = 0):
    """PartitionSpec pytree matching init_params(cfg).

    regimes: 'tp' (train/prefill TP+EP), 'decode' (DP+EP+SP),
    'fsdp' (ZeRO-3: every weight sharded over ALL axes on its largest
    divisible dim; XLA inserts per-layer all-gathers + grad
    reduce-scatters — the beyond-paper winner for small-model training,
    see EXPERIMENTS.md §Perf)."""
    M = axes.model
    shapes = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    all_ax = axes.batch + ((M,) if M else ())

    def rule(path, leaf):
        names = _names(path)
        stacked = names[0] in STACKS
        shape = leaf.shape[1:] if stacked else leaf.shape
        if regime == "fsdp":
            sp = _fsdp_rule(shape, n_dev, all_ax, tp, M)
        else:
            sp = _leaf_rule(cfg, names, shape, tp, M, regime)
        if stacked:
            sp = P(None, *sp)
        return sp

    return jax.tree_util.tree_map_with_path(rule, shapes)


def _fsdp_rule(shape, n_dev, all_ax, tp, M):
    """Shard the largest dim divisible by the full device count; fall back
    to a partial shard over the last mesh axis; else replicate."""
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if n_dev and shape[i] % n_dev == 0:
            return P(*[all_ax if j == i else None for j in range(len(shape))])
    last = all_ax[-1] if all_ax else M
    for i in order:
        if shape[i] % tp == 0:
            return P(*[last if j == i else None for j in range(len(shape))])
    return P(*([None] * len(shape)))


def _leaf_rule(cfg, names, shape, tp, M, regime):
    last = names[-1]
    in_moe = "moe" in names and "shared" not in names
    is_attn = any(n in ("attn", "xattn") for n in names) or (
        "t" in names and last in ("wq", "wk", "wv", "wo", "bq", "bk", "bv"))
    attn_repl = regime == "decode"  # decode: q-heads unsharded, cache sharded

    if last == "embed":
        return P(M, None) if _div(T.padded_vocab(cfg), tp) else P(None, None)
    if last == "lm_head":
        return P(None, M) if _div(T.padded_vocab(cfg), tp) else P(None, None)
    if last == "adapter":
        return P(None, None)

    # --- attention ---
    if is_attn or last in ("wq_a", "wq_b", "wkv_a", "wk_b", "wv_b",
                           "q_norm", "kv_norm"):
        if attn_repl:
            return P(*([None] * len(shape)))
        if last == "wq":
            return P(None, M, None) if _div(cfg.num_heads, tp) else P(None, None, None)
        if last in ("wk", "wv"):
            return P(None, M, None) if _div(cfg.num_kv_heads, tp) else P(None, None, None)
        if last == "wo":
            return P(M, None, None) if _div(cfg.num_heads, tp) else P(None, None, None)
        if last == "bq":
            return P(M, None) if _div(cfg.num_heads, tp) else P(None, None)
        if last in ("bk", "bv"):
            return P(M, None) if _div(cfg.num_kv_heads, tp) else P(None, None)
        if last in ("wq_b", "wk_b", "wv_b"):
            return P(None, M, None) if _div(cfg.num_heads, tp) else P(None, None, None)
        if last in ("wq_a", "wkv_a"):
            return P(None, None)
        if last in ("q_norm", "kv_norm"):
            return P(None)

    # --- MoE experts ---
    if in_moe:
        if last == "wg":
            return P(None, None)
        if last in ("w1", "w2", "w3") and len(shape) == 3:
            return (P(M, None, None) if _div(cfg.num_experts, tp)
                    else P(None, None, None))
        # shared-expert fallthrough handled below (dense rules)

    # --- dense MLP ---
    if last in ("w1", "w3"):
        F = shape[-1]
        return P(None, M) if _div(F, tp) else P(None, None)
    if last == "w2":
        F = shape[0]
        return P(M, None) if _div(F, tp) else P(None, None)

    # --- SSM (mamba2) ---
    if last in ("wz", "wx", "conv_x"):
        return P(None, M) if _div(cfg.d_inner if cfg.family == "ssm" else cfg.lru_width, tp) else P(None, None)
    if last in ("wB", "wC", "conv_B", "conv_C"):
        return P(None, None)
    if last == "wdt":
        return P(None, M) if _div(cfg.ssm_heads, tp) else P(None, None)
    if last in ("dt_bias", "A_log", "D_skip"):
        return P(M) if _div(cfg.ssm_heads, tp) else P(None)
    if last == "norm_w":
        return P(M) if _div(cfg.d_inner, tp) else P(None)
    if last == "wout":
        W = shape[0]
        return P(M, None) if _div(W, tp) else P(None, None)

    # --- RG-LRU ---
    if last in ("wgate",):
        return P(None, M) if _div(cfg.lru_width, tp) else P(None, None)
    if last == "conv":
        return P(None, M) if _div(cfg.lru_width, tp) else P(None, None)
    if last in ("Wa", "Wi"):
        return P(M, None, None) if _div(shape[0], tp) else P(None, None, None)
    if last in ("ba", "bi"):
        return P(M, None) if _div(shape[0], tp) else P(None, None)
    if last == "lam":
        return P(M) if _div(cfg.lru_width, tp) else P(None)

    # norms / biases / everything else: replicated
    return P(*([None] * len(shape)))


# ---------------------------------------------------------------------------
# cache specs (decode regime)
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, axes: MeshAxes, tp: int, batch: int,
                mesh_batch: int):
    """Spec pytree matching init_cache(cfg, B, S).

    Sequence dims shard over ``model`` (flash-decoding); batch over DP axes
    when divisible (long_500k batch=1 replicates).
    """
    M = axes.model
    Bax = axes.batch if batch % max(mesh_batch, 1) == 0 else None
    shapes = jax.eval_shape(lambda: T.init_cache(cfg, batch, 1024))

    def rule(path, leaf):
        names = _names(path)
        last = names[-1]
        nd = leaf.ndim
        # all caches are stacked: leading L/U dim
        if last in ("k", "v", "xk", "xv"):        # (L,B,S,Hkv,dh)
            return P(None, Bax, M, None, None)
        if last in ("ckv", "kr"):                 # (L,B,S,R)
            return P(None, Bax, M, None)
        if last == "pos":                         # (L,B,Wc)
            return P(None, Bax, M)
        if last == "state" and nd == 5:           # ssm (L,B,H,N,P)
            return P(None, Bax, M if _div(cfg.ssm_heads, tp) else None,
                     None, None)
        if last == "state":                       # rg (L,B,W)
            return P(None, Bax, M if _div(cfg.lru_width, tp) else None)
        if last in ("conv_x",):                   # (L,B,K-1,W)
            return P(None, Bax, None, M if _div(cfg.d_inner, tp) else None)
        if last in ("conv_B", "conv_C"):
            return P(None, Bax, None, None)
        if last == "conv":                        # rg (L,B,K-1,W)
            return P(None, Bax, None, M if _div(cfg.lru_width, tp) else None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, shapes)


# ---------------------------------------------------------------------------
# batch / activation hints
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, axes: MeshAxes, batch: int, mesh_batch: int,
                kind: str):
    Bax = axes.batch if batch % max(mesh_batch, 1) == 0 else None
    sp: Dict[str, Any] = {"tokens": P(Bax, None)}
    if kind == "train":
        sp["labels"] = P(Bax, None)
    if cfg.family == "vlm" and kind in ("train", "prefill"):
        sp["patches"] = P(Bax, None, None)
    if cfg.family == "audio" and kind in ("train", "prefill"):
        sp["frames"] = P(Bax, None, None)
    if kind == "decode":
        sp = {"tokens": P(Bax), "lengths": P(Bax)}
    return sp


def attention_mode(cfg: ModelConfig, tp: int) -> str:
    """'heads' TP when divisible, else sequence-parallel 'seq'."""
    if cfg.num_heads and cfg.num_heads % max(tp, 1) == 0:
        return "heads"
    return "seq"


def make_hint(cfg: ModelConfig, axes: MeshAxes, tp: int):
    """Sharding hint applied to (q, k, v) inside attention (tp regime)."""
    mode = attention_mode(cfg, tp)
    M = axes.model

    def hint(q, k, v):
        mesh = compat.get_abstract_mesh()
        if mesh.empty or M not in mesh.axis_names:
            return q, k, v
        wsc = jax.lax.with_sharding_constraint
        if mode == "heads":
            q = wsc(q, P(axes.batch, None, M, None))
            kv_sp = (P(axes.batch, None, M, None)
                     if cfg.num_kv_heads % max(tp, 1) == 0
                     else P(axes.batch, None, None, None))
            k, v = wsc(k, kv_sp), wsc(v, kv_sp)
        else:
            q = wsc(q, P(axes.batch, M, None, None))
            k = wsc(k, P(axes.batch, None, None, None))
            v = wsc(v, P(axes.batch, None, None, None))
        return q, k, v

    return hint


def explain(cfg: ModelConfig, tp: int) -> str:
    mode = attention_mode(cfg, tp)
    notes = [f"attention={mode}"]
    if cfg.is_moe:
        notes.append(f"EP {cfg.num_experts}/{tp} experts per shard")
    if cfg.family in ("ssm", "hybrid"):
        notes.append("channel TP")
    return ", ".join(notes)
