"""Collective-traffic accounting from partitioned HLO text.

``cost_analysis()`` does not expose collective bytes, so we parse the
post-SPMD HLO: every ``all-reduce`` / ``all-gather`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` op's result shape, dtype and replica
group size, and convert to wire bytes with ring-collective factors:

    all-reduce          2*(n-1)/n * bytes      (ring reduce+broadcast)
    all-gather          (n-1)/n  * bytes       (result = gathered tensor)
    reduce-scatter      (n-1)    * bytes       (result = one shard)
    all-to-all          (n-1)/n  * bytes
    collective-permute  1.0      * bytes

HLO is per-partition after SPMD, so all byte counts are per-device; divide
by per-chip ICI bandwidth for the collective roofline term.  NOTE: ops
inside a rolled ``while`` body appear once — roofline runs unroll the layer
scan so per-layer collectives are counted exactly (DESIGN.md §6).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute")

_OP_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\])\S*\s+"
    r"(?P<kind>all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"(?P<rest>[^\n]*)")

_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(rest: str, world: int) -> int:
    m = _GROUPS_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    return world


_FACTORS = {
    "all-reduce": lambda n: 2 * (n - 1) / max(n, 1),
    "all-gather": lambda n: (n - 1) / max(n, 1),
    "reduce-scatter": lambda n: float(n - 1),
    "all-to-all": lambda n: (n - 1) / max(n, 1),
    "collective-permute": lambda n: 1.0,
}


def hierarchical_a2a_cost(nbytes_per_device: float, pods: int, per_pod: int,
                          ici_bw: float = 50e9, dcn_bw: float = 12.5e9):
    """Two-hop (pod-local first) all-to-all vs flat all-to-all cost model.

    Flat a2a sends (g-1)/g of the buffer over the slowest link class; the
    hierarchical schedule first exchanges within the pod (fast ICI), then
    sends one aggregated stream per pod pair over the inter-pod links —
    cutting cross-pod message count from per_pod^2 to pods-1 streams and
    keeping (per_pod-1)/per_pod of the traffic on ICI.  Returns
    (flat_s, hierarchical_s).  Used by the §Perf collective notes and the
    plan optimizer for multi-pod EP."""
    g = pods * per_pod
    flat = nbytes_per_device * (g - 1) / g / dcn_bw
    intra = nbytes_per_device * (per_pod - 1) / per_pod / ici_bw
    inter = nbytes_per_device * (pods - 1) / pods / dcn_bw
    return flat, intra + inter


def collective_stats(hlo: str, world: int = 256) -> Dict:
    """Per-device collective byte totals from partitioned HLO text."""
    raw = defaultdict(float)
    wire = defaultdict(float)
    counts = defaultdict(int)
    for m in _OP_RE.finditer(hlo):
        kind = m.group("kind").replace("-start", "")
        nbytes = _shape_bytes(m.group("shape"))
        n = _group_size(m.group("rest"), world)
        if n <= 1:
            continue
        counts[kind] += 1
        raw[kind] += nbytes
        wire[kind] += nbytes * _FACTORS[kind](n)
    return {
        "counts": dict(counts),
        "raw_bytes": dict(raw),
        "wire_bytes": dict(wire),
        "total_wire_bytes": sum(wire.values()),
    }
