"""Mamba2-370M: 48L d_model=1024, attention-free SSD, ssm_state=128.
[arXiv:2405.21060; unverified]"""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_groups=1,
    head_dim=1,
)
