"""Architecture registry: one module per assigned architecture (+ the
paper's own DeepSeek-R1).  ``get_config(name)`` returns the full published
config; ``reduced_config(name)`` returns a tiny same-family config for CPU
smoke tests (same code paths, small dims)."""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "llama3_2_1b",
    "qwen2_0_5b",
    "smollm_360m",
    "h2o_danube_1_8b",
    "whisper_base",
    "pixtral_12b",
    "mamba2_370m",
    "recurrentgemma_2b",
    "phi3_5_moe",
    "qwen3_moe_30b",
    "deepseek_r1",   # the paper's own model (bonus, not an assigned cell)
]

ASSIGNED = ARCH_IDS[:10]


# Published generation defaults per architecture (generation_config.json
# style): used by default_sampling() when a caller doesn't pin its own
# SamplingParams.  Architectures absent here default to greedy.
SAMPLING_DEFAULTS = {
    "llama3_2_1b": dict(temperature=0.6, top_p=0.9),
    "qwen2_0_5b": dict(temperature=0.7, top_p=0.8, top_k=20,
                       repetition_penalty=1.1),
    "smollm_360m": dict(temperature=0.6, top_p=0.92),
    "h2o_danube_1_8b": dict(temperature=0.7, top_p=0.95),
    "phi3_5_moe": dict(temperature=0.7, top_p=0.95),
    "qwen3_moe_30b": dict(temperature=0.6, top_p=0.95, top_k=20),
    "deepseek_r1": dict(temperature=0.6, top_p=0.95),
    "recurrentgemma_2b": dict(temperature=1.0, top_k=64, top_p=0.95),
}


def get_config(name: str):
    name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def default_sampling(name: str, **overrides):
    """Recommended SamplingParams for an architecture (greedy when the
    model card publishes none).  ``overrides`` patch individual fields,
    e.g. ``default_sampling("llama3_2_1b", seed=7)``."""
    from repro.sampling.params import SamplingParams
    name = name.replace("-", "_").replace(".", "_")
    kw = dict(SAMPLING_DEFAULTS.get(name, {}))
    kw.update(overrides)
    return SamplingParams(**kw)


def reduced_config(name: str):
    """Tiny same-family config exercising identical code paths on CPU."""
    cfg = get_config(name)
    updates = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=32,
    )
    if cfg.num_experts:
        updates.update(num_experts=4, experts_per_token=2, moe_d_ff=64,
                       capacity_factor=2.0)
    if cfg.num_shared_experts:
        updates.update(shared_d_ff=64)
    if cfg.use_mla:
        updates.update(q_lora_rank=64, kv_lora_rank=32, rope_head_dim=16,
                       head_dim=32)
    if cfg.family == "ssm":
        updates.update(ssm_state=16, ssm_head_dim=16)
    if cfg.family == "hybrid":
        updates.update(lru_width=128, local_window=64, num_layers=5,
                       num_heads=4, head_dim=32)
    if cfg.family == "audio":
        updates.update(encoder_layers=2, encoder_seq=32)
    if cfg.sliding_window:
        updates.update(sliding_window=64)
    if cfg.num_patches:
        updates.update(num_patches=8)
    return dataclasses.replace(cfg, **updates)
