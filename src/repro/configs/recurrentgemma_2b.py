"""RecurrentGemma-2B: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000.  RG-LRU + local attention (window 2048), pattern 1:2 =
(rec, rec, attn) repeating; 26 = 8 units + 2 tail rec layers.
[arXiv:2402.19427; hf]"""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    d_ff=7680, vocab_size=256000, head_dim=256,
    act="gelu", logit_softcap=30.0,
    block_pattern=("rec", "rec", "attn"), lru_width=2560, local_window=2048,
)
