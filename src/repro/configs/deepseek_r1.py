"""DeepSeek-R1 (671B) — the paper's own flagship model (bonus config).
61L d_model=7168, MLA (kv_lora 512, q_lora 1536, rope dim 64), MoE 256
experts top-8 + 1 shared, expert d_ff=2048.  Structure approximated:
all layers MoE (real model: first 3 dense) — noted in DESIGN.md.
[arXiv deepseek-v3/r1; unverified]"""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-r1-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=0, vocab_size=129280, head_dim=128,
    num_experts=256, experts_per_token=8, moe_d_ff=2048,
    num_shared_experts=1, shared_d_ff=2048,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
)
