"""Pixtral-12B backbone: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072.  Pixtral-ViT frontend is a STUB (precomputed patch
embeddings, 1024 patches).  [hf:mistralai/Pixtral-12B-2409; unverified]"""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128, rope_theta=1000000000.0,
    num_patches=1024,
)
