"""Whisper-base backbone: 6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865.  Enc-dec; conv audio frontend is a STUB (input_specs provides
precomputed frame embeddings).  encoder_seq rounded 1500->1536 for even
sharding (DESIGN.md §5).  [arXiv:2212.04356; unverified]"""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865, head_dim=64,
    norm="layernorm", act="gelu",
    encoder_layers=6, encoder_seq=1536,
)
