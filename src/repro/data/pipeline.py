"""Data pipeline substrate: deterministic sharded synthetic LM data with
long-tail request generators for inference workloads.

Training side: every host builds only its shard (seeded by
(epoch, host_id)) — the pattern a 1000-node deployment needs: no global
shuffle state, resumable from a (step, epoch) cursor stored in the train
checkpoint.

Inference side: ``LongTailRequestStream`` generates batch-API request
dicts with lognormal prompt/output lengths (the Fig. 2c long-tail shape
``runtime.cluster.longtail_workload`` measures against), streamed one
request at a time so a million-line input file is written in O(1)
memory.  Requests are fully deterministic given the seed — the
streaming driver's byte-identical-resume tests depend on it.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, Iterator, List, Optional

import numpy as np

from repro.models.api import ModelConfig


@dataclasses.dataclass
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    markov_p: float = 0.8       # synthetic structure (learnable signal)


class SyntheticLMStream:
    """Infinite deterministic stream; host h yields rows
    [h*B/H, (h+1)*B/H) of the global batch."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_hosts == 0
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.trans = rng.integers(2, cfg.vocab_size,
                                  (cfg.vocab_size,)).astype(np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        B = c.global_batch // c.num_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.host_id]))
        toks = np.zeros((B, c.seq_len), np.int32)
        toks[:, 0] = rng.integers(2, c.vocab_size, B)
        for t in range(1, c.seq_len):
            follow = rng.random(B) < c.markov_p
            toks[:, t] = np.where(follow, self.trans[toks[:, t - 1]],
                                  rng.integers(2, c.vocab_size, B))
        return {"tokens": toks, "labels": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class LongTailRequestStream:
    """Seeded stream of batch-input request dicts with long-tail lengths.

    Prompt lengths are Poisson(mean_in); output budgets are lognormal
    (mu = log(mean_out) - sigma^2/2, so the mean is ``mean_out`` and the
    P99/P95 tail ratio lands near Fig. 2c at sigma≈1.0) — the same
    calibration as ``longtail_workload``, but emitted as jsonl-ready
    request dicts one at a time instead of a materialized Workload.

    Each request draws from its own ``SeedSequence([seed, i])``, so
    request *i* is a pure function of (seed, i): regeneration, resume
    and replica reassignment all see identical requests.  Greedy by
    default (temperature 0) — simulated greedy decode is deterministic,
    which the driver's byte-identical merged-output contract needs.
    """

    def __init__(self, n: int, *, seed: int = 0, mean_in: int = 64,
                 mean_out: int = 24, sigma: float = 1.0,
                 max_in_cap: int = 4096, max_out_cap: int = 2048,
                 vocab: int = 32000, temperature: float = 0.0):
        self.n = int(n)
        self.seed = int(seed)
        self.mean_in = int(mean_in)
        self.mean_out = int(mean_out)
        self.sigma = float(sigma)
        self.max_in_cap = int(max_in_cap)
        self.max_out_cap = int(max_out_cap)
        self.vocab = int(vocab)
        self.temperature = float(temperature)

    def request(self, i: int) -> Dict[str, Any]:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, i]))
        n_in = int(min(max(rng.poisson(self.mean_in), 4), self.max_in_cap))
        mu = math.log(self.mean_out) - self.sigma ** 2 / 2
        n_out = int(min(max(int(rng.lognormal(mu, self.sigma)), 2),
                        self.max_out_cap))
        body: Dict[str, Any] = {
            "prompt": [int(t) for t in rng.integers(2, self.vocab, n_in)],
            "max_tokens": n_out,
        }
        if self.temperature > 0.0:
            # explicit per-request seed: sampled decode stays a pure
            # function of the request, never of the scheduler's seq_id
            body["temperature"] = self.temperature
            body["seed"] = self.seed * 1_000_003 + i
        return {"custom_id": f"req-{i:08d}", "body": body}

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        for i in range(self.n):
            yield self.request(i)

    def write_jsonl(self, path: str) -> int:
        """Stream the whole job to a jsonl input file (O(1) memory)."""
        with open(path, "w", encoding="utf-8", newline="\n") as fh:
            for req in self:
                fh.write(json.dumps(req) + "\n")
        return self.n


def frontend_stub(cfg: ModelConfig, batch: Dict[str, np.ndarray],
                  rng: Optional[np.random.Generator] = None):
    """Attach the modality-frontend stand-ins the VLM/audio archs need
    (precomputed patch/frame embeddings, per the assignment spec)."""
    rng = rng or np.random.default_rng(0)
    B = batch["tokens"].shape[0]
    if cfg.family == "vlm":
        batch["patches"] = rng.standard_normal(
            (B, cfg.num_patches, cfg.d_model)).astype(np.float32) * 0.02
        batch["labels"] = np.concatenate(
            [np.full((B, cfg.num_patches), -1, np.int32), batch["labels"]], 1)
    if cfg.family == "audio":
        batch["frames"] = rng.standard_normal(
            (B, cfg.encoder_seq, cfg.d_model)).astype(np.float32) * 0.02
    return batch
