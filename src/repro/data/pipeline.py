"""Data pipeline substrate: deterministic sharded synthetic LM data with
long-tail request generators for inference workloads.

Every host builds only its shard (seeded by (epoch, host_id)) — the pattern
a 1000-node deployment needs: no global shuffle state, resumable from a
(step, epoch) cursor stored in the train checkpoint.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.models.api import ModelConfig


@dataclasses.dataclass
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    markov_p: float = 0.8       # synthetic structure (learnable signal)


class SyntheticLMStream:
    """Infinite deterministic stream; host h yields rows
    [h*B/H, (h+1)*B/H) of the global batch."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_hosts == 0
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.trans = rng.integers(2, cfg.vocab_size,
                                  (cfg.vocab_size,)).astype(np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        B = c.global_batch // c.num_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.host_id]))
        toks = np.zeros((B, c.seq_len), np.int32)
        toks[:, 0] = rng.integers(2, c.vocab_size, B)
        for t in range(1, c.seq_len):
            follow = rng.random(B) < c.markov_p
            toks[:, t] = np.where(follow, self.trans[toks[:, t - 1]],
                                  rng.integers(2, c.vocab_size, B))
        return {"tokens": toks, "labels": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def frontend_stub(cfg: ModelConfig, batch: Dict[str, np.ndarray],
                  rng: Optional[np.random.Generator] = None):
    """Attach the modality-frontend stand-ins the VLM/audio archs need
    (precomputed patch/frame embeddings, per the assignment spec)."""
    rng = rng or np.random.default_rng(0)
    B = batch["tokens"].shape[0]
    if cfg.family == "vlm":
        batch["patches"] = rng.standard_normal(
            (B, cfg.num_patches, cfg.d_model)).astype(np.float32) * 0.02
        batch["labels"] = np.concatenate(
            [np.full((B, cfg.num_patches), -1, np.int32), batch["labels"]], 1)
    if cfg.family == "audio":
        batch["frames"] = rng.standard_normal(
            (B, cfg.encoder_seq, cfg.d_model)).astype(np.float32) * 0.02
    return batch
