"""Phase-agnostic GPU buffer model: resident params + transient ring buffer.

Paper §5.2 Figure 7: device memory is split into (i) resident parameters
(small — norms, or all attention weights during decode when memory permits)
and (ii) a transient parameter/KV staging buffer whose slots are released as
soon as a module finishes.  Prefill runs a ring of expert/param prefetches
overlapped with compute and offloads each layer's KV immediately, so at most
two layers of KV are device-resident.

The same class gates a REAL transfer path: ``NodeEngine`` meters its
pipelined device→host KV staging (``stage_appends``) through a RingBuffer
— every in-flight blob reserves a slot, draining releases it, and a stage
that would overflow the capacity falls back to a synchronous drain (the
stall the plan optimizer sizes ``ring_buffer_bytes`` against).  The
timing model (``prefetch``) additionally drives the plan optimizer and
the cluster simulator; on TPU the same slot discipline would drive async
device_put round-robins.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple


@dataclasses.dataclass
class Slot:
    name: str
    nbytes: int
    ready_t: float = 0.0      # simulated time the transfer completes


class RingBuffer:
    """Fixed-capacity staging buffer with FIFO slot reuse."""

    def __init__(self, capacity_bytes: int, bw_bytes_per_s: float):
        self.capacity = capacity_bytes
        self.bw = bw_bytes_per_s
        self.slots: Deque[Slot] = deque()
        self.used = 0
        self.clock = 0.0
        self.stalls = 0.0

    def prefetch(self, name: str, nbytes: int, now: float) -> float:
        """Schedule a host->device transfer; returns completion time.
        Blocks (advances clock) if the buffer is full — that stall is the
        signal the plan optimizer uses to size the buffer."""
        while self.used + nbytes > self.capacity and self.slots:
            old = self.slots.popleft()
            if old.ready_t > now:
                self.stalls += old.ready_t - now
                now = old.ready_t
            self.used -= old.nbytes
        start = max(now, self.clock)
        done = start + nbytes / self.bw
        self.clock = done
        self.slots.append(Slot(name, nbytes, done))
        self.used += nbytes
        return done

    def release(self, name: str):
        for s in list(self.slots):
            if s.name == name:
                self.slots.remove(s)
                self.used -= s.nbytes
                return

    # -- occupancy gate (live backpressure for staged transfers) -----------
    def can_fit(self, nbytes: int) -> bool:
        """Would a reservation of ``nbytes`` fit right now?  A blob larger
        than the whole buffer never fits — callers must fall back to a
        synchronous (stage-and-drain) transfer for it."""
        return self.used + nbytes <= self.capacity

    def reserve(self, name: str, nbytes: int):
        """Claim ``nbytes`` of staging space without the timing model —
        the live engine's accounting for an in-flight async copy.  Pair
        with ``release(name)`` when the transfer is drained."""
        self.slots.append(Slot(name, nbytes))
        self.used += nbytes

    def reset(self):
        """Drop every reservation (failed-node teardown: the in-flight
        blobs it metered were abandoned, not drained, so their space must
        not stay claimed forever)."""
        self.slots.clear()
        self.used = 0


@dataclasses.dataclass
class DeviceMemoryPlan:
    """Byte budget split for one phase (prefill or decode)."""
    hbm_bytes: int
    resident_param_bytes: int
    ring_buffer_bytes: int
    kv_pool_bytes: int
    workspace_bytes: int

    @property
    def ok(self) -> bool:
        return (self.resident_param_bytes + self.ring_buffer_bytes
                + self.kv_pool_bytes + self.workspace_bytes) <= self.hbm_bytes

    def kv_pages(self, page_bytes: int) -> int:
        return max(self.kv_pool_bytes // max(page_bytes, 1), 0)


def plan_phase_memory(hbm_bytes: int, param_bytes_resident: int,
                      ring_bytes: int, workspace_bytes: int,
                      page_bytes: int) -> DeviceMemoryPlan:
    """Everything not claimed by params/ring/workspace becomes KV pool —
    the paper's 'reconfigure sizes at phase swap' in one function."""
    kv = hbm_bytes - param_bytes_resident - ring_bytes - workspace_bytes
    return DeviceMemoryPlan(hbm_bytes, param_bytes_resident, ring_bytes,
                            max(kv, 0), workspace_bytes)
