"""Device KV page allocator: two-page lazy allocation + eviction policy.

Paper §5.2/§5.3: each active sequence reserves only TWO pages ahead;
extension happens at page boundaries; when the pool is exhausted the
scheduler evicts the sequences with the most progress (their KV is already
checkpointed to host and they are closest to completion), until every
remaining active sequence can hold two pages.

The allocator also carries the **memory-pressure watermark pair** the
governor polls every REFILL round: ``above_high()`` means occupancy
crossed ``high_watermark`` and the scheduler should preempt
least-progress sequences to host; ``below_low()`` means occupancy fell
under ``low_watermark`` and preempted sequences may re-admit.  The gap
between the two is hysteresis — without it a run oscillates
preempt/re-admit every round at the boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set


@dataclasses.dataclass
class AllocStats:
    allocs: int = 0
    frees: int = 0
    evictions: int = 0
    peak_used: int = 0


class PageAllocator:
    def __init__(self, total_pages: int, page_size: int, *,
                 high_watermark: float = 0.85, low_watermark: float = 0.60,
                 governed: bool = True):
        assert total_pages > 0
        assert 0.0 < low_watermark <= high_watermark <= 1.0
        self.total = total_pages
        self.page_size = page_size
        self.high_watermark = high_watermark
        self.low_watermark = low_watermark
        # governed=False marks a pool whose size is a modelling artifact
        # rather than a configured byte budget (e.g. the sim's default
        # max_active*4 soft pool): the scheduler's memory-pressure
        # governor must not steer admission or preempt against it
        self.governed = governed
        self.free: List[int] = list(range(total_pages))
        self.owned: Dict[int, List[int]] = {}       # seq_id -> page ids
        self.stats = AllocStats()

    # -- queries -------------------------------------------------------------
    @property
    def used(self) -> int:
        return self.total - len(self.free)

    @property
    def occupancy(self) -> float:
        return self.used / self.total

    def above_high(self) -> bool:
        return self.occupancy > self.high_watermark

    def below_low(self) -> bool:
        return self.occupancy < self.low_watermark

    def pages_of(self, seq_id: int) -> List[int]:
        return self.owned.get(seq_id, [])

    def can_admit(self, reserve: int = 2) -> bool:
        return len(self.free) >= reserve

    # -- alloc/free ----------------------------------------------------------
    def alloc(self, seq_id: int, n: int = 1) -> Optional[List[int]]:
        if len(self.free) < n:
            return None
        got = [self.free.pop() for _ in range(n)]
        self.owned.setdefault(seq_id, []).extend(got)
        self.stats.allocs += n
        self.stats.peak_used = max(self.stats.peak_used, self.used)
        return got

    def free_seq(self, seq_id: int) -> int:
        pages = self.owned.pop(seq_id, [])
        self.free.extend(pages)
        self.stats.frees += len(pages)
        return len(pages)

    # -- policy ---------------------------------------------------------------
    def ensure_two_pages(self, active: Dict[int, int]) -> List[int]:
        """Evict most-progress-first until every active seq can reserve 2
        pages.  `active`: seq_id -> decoded length.  Returns evicted ids."""
        evicted: List[int] = []
        need = lambda: 2 * (len(active) - len(evicted)) - sum(
            len(self.owned.get(s, [])) for s in active if s not in evicted)
        # tie-break equal progress by seq_id: victim order must not depend
        # on dict insertion order or chaos replays diverge from fault-free
        order = sorted(active, key=lambda s: (-active[s], s))
        i = 0
        while len(self.free) < max(need(), 0) and i < len(order):
            victim = order[i]
            i += 1
            self.free_seq(victim)
            evicted.append(victim)
            self.stats.evictions += 1
        return evicted
