"""Paged KV storage: host store (single source of truth) + device slot pool.

Paper §5.2: host memory holds parameters and the KV cache of *every*
sequence scheduled to a node; the device holds only the active working set.
YIELD checkpoints a sequence's device state to host pages; COMBINE restores
it into a free device slot.  Token-indexed cache leaves (k/v/ckv/kr) are
paged at ``page_size`` tokens; fixed-size state (SSM state, conv stubs,
ring caches) is stored whole.

Shared-prefix reuse rides the page granularity: a sequence may *share* its
leading full pages with other sequences through the node's
:class:`~repro.prefix.index.PrefixIndex`.  Shared pages are frozen
(``writeable = False``) and refcounted by the index; every write path here
copy-on-writes — a private page is allocated at the first divergent write
and the shared original is untouched.  ``drop`` releases the sequence's
span reference exactly once (the SeqState is popped, so a second drop is a
no-op), and MIGRATE moves a span's bytes once per span via ``adopt``, not
once per sequence.

On this CPU container "host" is NumPy and "device" is the jax array holding
the engine's dense decode cache; on a real TPU deployment the same classes
wrap pinned host buffers + device_put/device_get with async staging through
the ring buffer (memory/buffers.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from repro.prefix.index import PrefixIndex, PrefixNode

PAGED_LEAVES = ("k", "v", "ckv", "kr")  # token-indexed (dim 1 = position)


@dataclasses.dataclass
class SeqState:
    """Host-resident state of one sequence (paged).

    ``prefix_node`` is the deepest trie node of the shared span this
    sequence rides (``None`` = fully private); the span covers the first
    ``prefix_len`` tokens (always a multiple of the page size)."""
    seq_id: int
    length: int = 0                       # tokens represented in KV
    pages: Dict[str, List[np.ndarray]] = dataclasses.field(default_factory=dict)
    whole: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    prefix_node: Optional[PrefixNode] = None
    prefix_len: int = 0

    def nbytes(self) -> int:
        n = sum(p.nbytes for ps in self.pages.values() for p in ps)
        return n + sum(w.nbytes for w in self.whole.values())

    def private_nbytes(self) -> int:
        """Bytes owned by this sequence alone (shared span excluded)."""
        n = 0
        for ps in self.pages.values():
            n += sum(p.nbytes for p in ps if p.flags.writeable)
        return n + sum(w.nbytes for w in self.whole.values())


class HostKVStore:
    """Per-node unified host store; page granularity = P tokens."""

    def __init__(self, page_size: int = 64, enable_prefix: bool = True,
                 max_prefix_pages: int = 4096,
                 budget_bytes: Optional[int] = None):
        self.page_size = page_size
        self.seqs: Dict[int, SeqState] = {}
        self.prefix_index: Optional[PrefixIndex] = (
            PrefixIndex(page_size, max_prefix_pages) if enable_prefix
            else None)
        self.cow_copies = 0
        # host-spill byte budget (None = unbounded): exceeding it cascades
        # to prefix-span LRU eviction (enforce_budget); a store still over
        # budget afterwards is the driver tier's throttle signal
        self.budget_bytes = budget_bytes
        self.budget_evictions = 0       # spans evicted by the byte budget
        # incrementally-maintained mirror of the nbytes() dedup walk —
        # the governor polls it every round, so it must be O(1)
        self._nbytes = 0
        self._page_refs: Dict[int, int] = {}    # id(page) -> list refs

    # -- incremental byte accounting ----------------------------------------
    def _ref_page(self, p: np.ndarray) -> None:
        k = id(p)
        c = self._page_refs.get(k, 0)
        if c == 0:
            self._nbytes += int(p.nbytes)
        self._page_refs[k] = c + 1

    def _unref_page(self, p: np.ndarray) -> None:
        k = id(p)
        c = self._page_refs.get(k, 0)
        if c <= 1:
            self._page_refs.pop(k, None)
            if c == 1:
                self._nbytes -= int(p.nbytes)
        else:
            self._page_refs[k] = c - 1

    def _ref_state(self, st: SeqState) -> None:
        for ps in st.pages.values():
            for p in ps:
                self._ref_page(p)
        self._nbytes += sum(int(w.nbytes) for w in st.whole.values())

    def _unref_state(self, st: SeqState) -> None:
        for ps in st.pages.values():
            for p in ps:
                self._unref_page(p)
        self._nbytes -= sum(int(w.nbytes) for w in st.whole.values())

    def _set_whole(self, st: SeqState, name: str, arr: np.ndarray) -> None:
        old = st.whole.get(name)
        if old is not None:
            self._nbytes -= int(old.nbytes)
        st.whole[name] = arr
        self._nbytes += int(arr.nbytes)

    # -- bookkeeping --------------------------------------------------------
    def has(self, seq_id: int) -> bool:
        return seq_id in self.seqs

    def nbytes(self) -> int:
        """Resident bytes; a page shared by N sequences counts once.
        O(1): reads the incrementally-maintained counter (see
        ``nbytes_walk`` for the recomputed ground truth)."""
        return self._nbytes

    def nbytes_walk(self) -> int:
        """Recompute resident bytes by walking every SeqState (dedup by
        page identity) — the invariant ``nbytes() == nbytes_walk()`` is
        asserted in tests; production callers use the O(1) counter."""
        seen, n = set(), 0
        for s in self.seqs.values():
            for ps in s.pages.values():
                for p in ps:
                    if id(p) not in seen:
                        seen.add(id(p))
                        n += p.nbytes
            n += sum(w.nbytes for w in s.whole.values())
        return n

    def host_bytes(self) -> int:
        """Total host KV footprint the byte budget governs: sequence-
        resident bytes plus span pages cached in the prefix trie.
        Conservative: a page both sequence-bound and trie-resident counts
        in both terms, so the budget can never under-estimate."""
        n = self._nbytes
        if self.prefix_index is not None:
            n += self.prefix_index.cached_nbytes
        return n

    def over_budget(self) -> bool:
        return (self.budget_bytes is not None
                and self.host_bytes() > self.budget_bytes)

    def enforce_budget(self) -> int:
        """Byte-budget cascade: evict LRU zero-ref prefix spans until the
        footprint fits (or the trie has nothing evictable).  Returns spans
        evicted.  A store still over budget afterwards carries only live
        sequence state — the driver tier throttles admissions instead of
        dying."""
        if self.budget_bytes is None or self.prefix_index is None:
            return 0
        evicted = 0
        while self.host_bytes() > self.budget_bytes:
            if not self.prefix_index.evict_lru():
                break
            evicted += 1
        self.budget_evictions += evicted
        return evicted

    def num_pages(self, seq_id: int) -> int:
        s = self.seqs[seq_id]
        return max((len(ps) for ps in s.pages.values()), default=0)

    def drop(self, seq_id: int):
        """Span-aware release: pops the SeqState and drops its span
        reference exactly once — a duplicate drop (forked teardown racing a
        recovery path) finds nothing to pop and touches no refcount."""
        st = self.seqs.pop(seq_id, None)
        if st is not None:
            self._unref_state(st)
            if st.prefix_node is not None and self.prefix_index is not None:
                self.prefix_index.release(st.prefix_node)
                st.prefix_node = None

    def pop_state(self, seq_id: int) -> Optional[SeqState]:
        """MIGRATE src side: detach a SeqState without touching its span
        refcount (the caller releases the span only after the destination
        adopted it, so shared ancestors never transit refcount zero
        mid-move)."""
        st = self.seqs.pop(seq_id, None)
        if st is not None:
            self._unref_state(st)
        return st

    # -- shared-prefix spans -------------------------------------------------
    def publish_prefix(self, seq_id: int, tokens) -> Optional[List[PrefixNode]]:
        """Register a freshly prefilled sequence's full prompt pages in the
        prefix index and bind the sequence to the span.  Pages entering the
        trie are frozen read-only; pages already in the trie (an identical
        prompt published earlier) replace this sequence's private copies, so
        duplicate submits dedupe to one canonical span."""
        if self.prefix_index is None or len(tokens) < self.page_size:
            return None
        st = self.seqs[seq_id]
        idx = self.prefix_index

        def pages_for(i: int) -> Dict[str, np.ndarray]:
            return {name: ps[i] for name, ps in st.pages.items()
                    if i < len(ps)}

        chain = idx.extend(idx.match(tokens), tokens, pages_for)
        for i, nd in enumerate(chain):
            for name, page in nd.pages.items():
                if name in st.pages and i < len(st.pages[name]):
                    old = st.pages[name][i]
                    if old is not page:     # dedupe to the canonical object
                        self._ref_page(page)
                        self._unref_page(old)
                    st.pages[name][i] = page
        self.bind_prefix(seq_id, chain)
        return chain

    def bind_prefix(self, seq_id: int, chain: List[PrefixNode]) -> None:
        """Point a sequence at a span chain and take one reference.  A
        rebind (publish after attach extends the span) releases the old
        reference — after taking the new one, so the shared ancestors can
        never transit refcount zero mid-rebind."""
        if not chain or self.prefix_index is None:
            return
        st = self.seqs[seq_id]
        prev = st.prefix_node
        st.prefix_node = chain[-1]
        st.prefix_len = len(chain) * self.page_size
        self.prefix_index.acquire(st.prefix_node)
        if prev is not None:
            self.prefix_index.release(prev)

    def clone_shared(self, src_seq_id: int, dst_seq_id: int) -> SeqState:
        """Fork: create ``dst`` sharing ``src``'s span pages; everything
        past the span (the partial prompt-tail page, whole-state leaves) is
        deep-copied so the fork diverges without touching the lead."""
        src = self.seqs[src_seq_id]
        chain = src.prefix_node.chain() if src.prefix_node is not None else []
        k = len(chain)
        st = SeqState(dst_seq_id, length=src.length)
        for name, ps in src.pages.items():
            st.pages[name] = list(ps[:k]) + [p.copy() for p in ps[k:]]
        st.whole = {n: w.copy() for n, w in src.whole.items()}
        self.seqs[dst_seq_id] = st
        self._ref_state(st)
        self.bind_prefix(dst_seq_id, chain)
        self.enforce_budget()
        return st

    def attach_shared(self, seq_id: int, chain: List[PrefixNode]) -> SeqState:
        """Cross-submit prefix hit: seed a new sequence from a matched span;
        the caller appends the recomputed tail via ``append_tokens``."""
        st = SeqState(seq_id, length=len(chain) * self.page_size)
        names = set()
        for nd in chain:
            names.update(nd.pages)
        for name in sorted(names):
            if all(name in nd.pages for nd in chain):
                st.pages[name] = [nd.pages[name] for nd in chain]
        self.seqs[seq_id] = st
        self._ref_state(st)
        self.bind_prefix(seq_id, chain)
        return st

    def adopt(self, seq_id: int, st: SeqState) -> int:
        """MIGRATE dst side: take ownership of a SeqState checkpointed on a
        peer store.  The shared span is grafted into this store's index —
        pages a sibling already moved here cost zero bytes — and the span
        reference is re-taken locally.  Returns bytes actually moved."""
        moved = st.private_nbytes()
        if st.prefix_node is not None and self.prefix_index is not None:
            chain, new_bytes = self.prefix_index.graft(st.prefix_node)
            moved += new_bytes
            k = len(chain)
            for name in st.pages:
                for i, nd in enumerate(chain[:len(st.pages[name])]):
                    if name in nd.pages:
                        st.pages[name][i] = nd.pages[name]
            st.prefix_node = chain[-1] if chain else None
            st.prefix_len = k * self.page_size
            self.seqs[seq_id] = st
            self._ref_state(st)
            self.prefix_index.acquire(st.prefix_node)
        else:
            if st.prefix_node is not None:
                # dst has no index: span becomes private; nothing to ref
                st.prefix_node = None
                st.prefix_len = 0
                moved = st.nbytes()
            self.seqs[seq_id] = st
            self._ref_state(st)
        self.enforce_budget()
        return moved

    # -- checkpoint (YIELD) -------------------------------------------------
    def checkpoint(self, seq_id: int, cache_slices: Dict[str, np.ndarray],
                   length: int):
        """Store a sequence's cache arrays.  Paged leaves have layout
        (L, S, ...) with S = positions; only the first `length` positions are
        persisted, page by page.  Pages inside a shared span are kept as-is
        (decode never rewrites past KV, and rewriting them would break the
        share), so YIELD/COMBINE cycles preserve sharing."""
        st = self.seqs.setdefault(seq_id, SeqState(seq_id))
        st.length = length
        P = self.page_size
        keep_pages = st.prefix_len // P
        for name, arr in cache_slices.items():
            if name in PAGED_LEAVES:
                existing = st.pages.get(name, [])
                keep = min(keep_pages, len(existing))
                pages = list(existing[:keep])
                for p in existing[keep:]:
                    self._unref_page(p)     # replaced by fresh pages below
                for start in range(keep * P, length, P):
                    end = min(start + P, length)
                    page = np.zeros((arr.shape[0], P) + arr.shape[2:],
                                    arr.dtype)
                    page[:, : end - start] = arr[:, start:end]
                    pages.append(page)
                    self._ref_page(page)
                st.pages[name] = pages
            else:
                self._set_whole(st, name, np.array(arr))
        self.enforce_budget()

    # -- incremental append (async KV propagation, §5.3 Sync phase) --------
    def append_tokens(self, seq_id: int, new_slices: Dict[str, np.ndarray],
                      start: int):
        """Propagate freshly decoded KV entries (device -> host).

        Writes are batched page-by-page (one slice assignment per touched
        page) rather than token-by-token, so a whole decode-page block
        lands in at most ``ceil(n_new/P) + 1`` copies per leaf.  A write
        landing on a frozen shared page copy-on-writes it first."""
        st = self.seqs[seq_id]
        P = self.page_size
        n_new = next(iter(new_slices.values())).shape[1]
        for name, arr in new_slices.items():
            if name not in PAGED_LEAVES:
                self._set_whole(st, name, np.array(arr))
                continue
            pages = st.pages.setdefault(name, [])
            i = 0
            while i < n_new:
                pidx, off = divmod(start + i, P)
                while len(pages) <= pidx:
                    page = np.zeros((arr.shape[0], P) + arr.shape[2:],
                                    arr.dtype)
                    pages.append(page)
                    self._ref_page(page)
                if not pages[pidx].flags.writeable:
                    copy = pages[pidx].copy()   # first divergent write
                    self._ref_page(copy)
                    self._unref_page(pages[pidx])
                    pages[pidx] = copy
                    self.cow_copies += 1
                take = min(P - off, n_new - i)
                pages[pidx][:, off: off + take] = arr[:, i: i + take]
                i += take
        st.length = max(st.length, start + n_new)
        self.enforce_budget()

    # -- restore (COMBINE) --------------------------------------------------
    def restore(self, seq_id: int, max_len: int) -> Dict[str, np.ndarray]:
        """Materialize dense (L, max_len, ...) arrays from pages."""
        st = self.seqs[seq_id]
        P = self.page_size
        out = {}
        for name, pages in st.pages.items():
            if not pages:
                continue
            proto = pages[0]
            full = np.zeros((proto.shape[0], max_len) + proto.shape[2:],
                            proto.dtype)
            for pidx, page in enumerate(pages):
                start = pidx * P
                end = min(start + P, max_len)
                if start >= max_len:
                    break
                full[:, start:end] = page[:, : end - start]
            out[name] = full
        out.update({k: v.copy() for k, v in st.whole.items()})
        return out
