"""Paged KV storage: host store (single source of truth) + device slot pool.

Paper §5.2: host memory holds parameters and the KV cache of *every*
sequence scheduled to a node; the device holds only the active working set.
YIELD checkpoints a sequence's device state to host pages; COMBINE restores
it into a free device slot.  Token-indexed cache leaves (k/v/ckv/kr) are
paged at ``page_size`` tokens; fixed-size state (SSM state, conv stubs,
ring caches) is stored whole.

On this CPU container "host" is NumPy and "device" is the jax array holding
the engine's dense decode cache; on a real TPU deployment the same classes
wrap pinned host buffers + device_put/device_get with async staging through
the ring buffer (memory/buffers.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

PAGED_LEAVES = ("k", "v", "ckv", "kr")  # token-indexed (dim 1 = position)


@dataclasses.dataclass
class SeqState:
    """Host-resident state of one sequence (paged)."""
    seq_id: int
    length: int = 0                       # tokens represented in KV
    pages: Dict[str, List[np.ndarray]] = dataclasses.field(default_factory=dict)
    whole: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    def nbytes(self) -> int:
        n = sum(p.nbytes for ps in self.pages.values() for p in ps)
        return n + sum(w.nbytes for w in self.whole.values())


class HostKVStore:
    """Per-node unified host store; page granularity = P tokens."""

    def __init__(self, page_size: int = 64):
        self.page_size = page_size
        self.seqs: Dict[int, SeqState] = {}

    # -- bookkeeping --------------------------------------------------------
    def has(self, seq_id: int) -> bool:
        return seq_id in self.seqs

    def nbytes(self) -> int:
        return sum(s.nbytes() for s in self.seqs.values())

    def num_pages(self, seq_id: int) -> int:
        s = self.seqs[seq_id]
        return max((len(ps) for ps in s.pages.values()), default=0)

    def drop(self, seq_id: int):
        self.seqs.pop(seq_id, None)

    # -- checkpoint (YIELD) -------------------------------------------------
    def checkpoint(self, seq_id: int, cache_slices: Dict[str, np.ndarray],
                   length: int):
        """Store a sequence's cache arrays.  Paged leaves have layout
        (L, S, ...) with S = positions; only the first `length` positions are
        persisted, page by page."""
        st = self.seqs.setdefault(seq_id, SeqState(seq_id))
        st.length = length
        P = self.page_size
        for name, arr in cache_slices.items():
            if name in PAGED_LEAVES:
                pages = []
                for start in range(0, length, P):
                    end = min(start + P, length)
                    page = np.zeros((arr.shape[0], P) + arr.shape[2:],
                                    arr.dtype)
                    page[:, : end - start] = arr[:, start:end]
                    pages.append(page)
                st.pages[name] = pages
            else:
                st.whole[name] = np.array(arr)

    # -- incremental append (async KV propagation, §5.3 Sync phase) --------
    def append_tokens(self, seq_id: int, new_slices: Dict[str, np.ndarray],
                      start: int):
        """Propagate freshly decoded KV entries (device -> host).

        Writes are batched page-by-page (one slice assignment per touched
        page) rather than token-by-token, so a whole decode-page block
        lands in at most ``ceil(n_new/P) + 1`` copies per leaf."""
        st = self.seqs[seq_id]
        P = self.page_size
        n_new = next(iter(new_slices.values())).shape[1]
        for name, arr in new_slices.items():
            if name not in PAGED_LEAVES:
                st.whole[name] = np.array(arr)
                continue
            pages = st.pages.setdefault(name, [])
            i = 0
            while i < n_new:
                pidx, off = divmod(start + i, P)
                while len(pages) <= pidx:
                    pages.append(np.zeros((arr.shape[0], P) + arr.shape[2:],
                                          arr.dtype))
                take = min(P - off, n_new - i)
                pages[pidx][:, off: off + take] = arr[:, i: i + take]
                i += take
        st.length = max(st.length, start + n_new)

    # -- restore (COMBINE) --------------------------------------------------
    def restore(self, seq_id: int, max_len: int) -> Dict[str, np.ndarray]:
        """Materialize dense (L, max_len, ...) arrays from pages."""
        st = self.seqs[seq_id]
        P = self.page_size
        out = {}
        for name, pages in st.pages.items():
            if not pages:
                continue
            proto = pages[0]
            full = np.zeros((proto.shape[0], max_len) + proto.shape[2:],
                            proto.dtype)
            for pidx, page in enumerate(pages):
                start = pidx * P
                end = min(start + P, max_len)
                if start >= max_len:
                    break
                full[:, start:end] = page[:, : end - start]
            out[name] = full
        out.update({k: v.copy() for k, v in st.whole.items()})
        return out
