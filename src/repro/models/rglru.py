"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427).

Train/prefill evaluate the linear recurrence with an associative scan;
decode is an O(1) step.  Gates are block-diagonal (official
BlockDiagonalLinear) with ``RG_BLOCKS=16`` blocks so each block lives on one
model shard (RecurrentGemma uses num_heads=10 blocks; 10 does not divide the
16-wide model axis — recorded in DESIGN.md).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.api import ModelConfig
from repro.models import layers
from repro.models.ssm import _causal_conv, _conv_step

RG_BLOCKS = 16
_C = 8.0  # RG-LRU temperature


def init_rglru(cfg: ModelConfig, key):
    dt = jnp.dtype(cfg.dtype)
    D, W = cfg.d_model, cfg.lru_width
    K = 4
    nb, wb = RG_BLOCKS, cfg.lru_width // RG_BLOCKS
    ks = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(D)
    # Lambda init so that a^c in [0.9, 0.999] (official init range)
    u = jax.random.uniform(ks[0], (W,), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(jnp.exp(-jnp.log(u) / (2 * _C)) - 1.0)  # softplus^-1(-log u /2c)
    return {
        "wx": (jax.random.normal(ks[1], (D, W)) * sc).astype(dt),
        "wgate": (jax.random.normal(ks[2], (D, W)) * sc).astype(dt),
        "conv": (jax.random.normal(ks[3], (K, W)) / math.sqrt(K)).astype(dt),
        "Wa": (jax.random.normal(ks[4], (nb, wb, wb)) / math.sqrt(wb)).astype(dt),
        "ba": jnp.zeros((nb, wb), dt),
        "Wi": (jax.random.normal(ks[5], (nb, wb, wb)) / math.sqrt(wb)).astype(dt),
        "bi": jnp.zeros((nb, wb), dt),
        "lam": lam,
        "wout": (jax.random.normal(key, (W, D)) / math.sqrt(W)
                 / math.sqrt(max(cfg.num_layers, 1))).astype(dt),
    }


def _block_diag(u, W, b):
    """u (B,S,width) @ block-diag W (nb,wb,wb) + b."""
    B, S, width = u.shape
    nb, wb = W.shape[0], W.shape[1]
    ub = u.reshape(B, S, nb, wb)
    return (jnp.einsum("bsnw,nwv->bsnv", ub, W) + b).reshape(B, S, width)


def _gates(p, u):
    r = jax.nn.sigmoid(_block_diag(u, p["Wa"], p["ba"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(u, p["Wi"], p["bi"]).astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(p["lam"])                 # (B,S,W) ≤ 0
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, mult * i * u.astype(jnp.float32)


def rglru_fwd(cfg: ModelConfig, p, x, *, return_state=False):
    """Full-sequence RG-LRU block. x (B,S,D) -> (B,S,D)."""
    gate = jnp.einsum("bsd,dw->bsw", x, p["wgate"])
    uraw = jnp.einsum("bsd,dw->bsw", x, p["wx"])
    u = jax.nn.silu(_causal_conv(uraw, p["conv"]))
    a, bterm = _gates(p, u)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    h = h.astype(x.dtype)
    y = h * jax.nn.gelu(gate, approximate=True)
    out = jnp.einsum("bsw,wd->bsd", y, p["wout"])
    if return_state:
        K = p["conv"].shape[0]
        return out, {"state": h[:, -1].astype(jnp.float32),
                     "conv": uraw[:, x.shape[1] - (K - 1):, :]}
    return out


def rglru_decode(cfg: ModelConfig, p, x, cache):
    """One-token step. x (B,1,D); cache {state (B,W) fp32, conv (B,K-1,W)}."""
    gate = jnp.einsum("bsd,dw->bsw", x, p["wgate"])
    uraw = jnp.einsum("bsd,dw->bsw", x, p["wx"])
    u, conv_c = _conv_step(uraw, cache["conv"], p["conv"])
    u = jax.nn.silu(u)
    a, bterm = _gates(p, u)                                      # (B,1,W)
    h = cache["state"] * a[:, 0] + bterm[:, 0]
    y = h[:, None, :].astype(x.dtype) * jax.nn.gelu(gate, approximate=True)
    out = jnp.einsum("bsw,wd->bsd", y, p["wout"])
    return out, {"state": h, "conv": conv_c}


def init_rglru_cache(cfg: ModelConfig, B: int, dtype=jnp.bfloat16):
    K = 4
    return {"state": jnp.zeros((B, cfg.lru_width), jnp.float32),
            "conv": jnp.zeros((B, K - 1, cfg.lru_width), dtype)}
