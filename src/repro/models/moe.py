"""Mixture-of-Experts layer with shard_map expert parallelism.

Design (see DESIGN.md §3/§4)
----------------------------
Experts are sharded over the ``model`` mesh axis (EP).  Activations enter the
MoE replicated over ``model`` (TP regime keeps the residual stream replicated
after each block's psum), so dispatch is a *local* capacity-bounded
gather per expert shard and combine is a single psum over ``model`` — the
TPU-native mapping of the paper's DeepEP all-to-all (tokens never move over
the wire; partial expert outputs are reduced instead).  An explicit
all-to-all variant for token-sharded (sequence-parallel) residual streams is
provided for the perf hillclimb.

Capacity follows the paper's fixed-plan model: ``C = ceil(T*k/E * cf)``
(static shape), overflowing tokens drop to the residual path — the runtime's
COMBINE primitive is the mechanism that keeps T large enough for E to be
well-fed (paper Fig. 2b).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models.api import MeshAxes, ModelConfig
from repro.models import layers


def init_moe(cfg: ModelConfig, key):
    dt = jnp.dtype(cfg.dtype)
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "wg": (jax.random.normal(ks[0], (D, E)) / math.sqrt(D)).astype(jnp.float32),
        "w1": (jax.random.normal(ks[1], (E, D, F)) / math.sqrt(D)).astype(dt),
        "w3": (jax.random.normal(ks[2], (E, D, F)) / math.sqrt(D)).astype(dt),
        "w2": (jax.random.normal(ks[3], (E, F, D)) / math.sqrt(F)
               / math.sqrt(max(cfg.num_layers, 1))).astype(dt),
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = layers.init_mlp(cfg, ks[4], d_ff=cfg.shared_d_ff)
    return p


def expert_capacity(cfg: ModelConfig, tokens: int) -> int:
    """Static per-expert slot count for a local token pool of size `tokens`."""
    c = math.ceil(tokens * cfg.experts_per_token / cfg.num_experts
                  * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8, floor of 8 slots


def _route(cfg: ModelConfig, wg, xt):
    """Router: returns (vals (T,k) fp32, ids (T,k) int32, aux fp32 scalar)."""
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), wg)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, ids = jax.lax.top_k(probs, cfg.experts_per_token)
    vals = vals / jnp.maximum(jnp.sum(vals, -1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * sum_e f_e * p_e
    E = cfg.num_experts
    f = jnp.mean(jax.nn.one_hot(ids, E, dtype=jnp.float32), axis=(0, 1)) * E
    pbar = jnp.mean(probs, axis=0)
    aux = jnp.sum(f * pbar)
    return vals, ids, aux


def _expert_mlp(cfg: ModelConfig, p, xs):
    """xs: (El, C, D) -> (El, C, D)."""
    act = jax.nn.silu if cfg.act == "silu" else (lambda u: jax.nn.gelu(u, approximate=True))
    g = act(jnp.einsum("ecd,edf->ecf", xs, p["w1"]))
    u = jnp.einsum("ecd,edf->ecf", xs, p["w3"])
    return jnp.einsum("ecf,efd->ecd", g * u, p["w2"])


def moe_fwd(cfg: ModelConfig, axes: MeshAxes, p, x):
    """Expert-parallel MoE over the current mesh. x: (B, S, D) -> (B, S, D), aux."""
    mesh = compat.get_abstract_mesh()
    if mesh.empty or axes.model is None or axes.model not in mesh.axis_names:
        y, aux = _moe_local(cfg, p, x)
    else:
        bspec = P(axes.batch, None, None)
        espec = P(axes.model, None, None)

        all_axes = tuple(mesh.axis_names)

        @partial(compat.shard_map, mesh=mesh,
                 in_specs=(bspec, P(None, None), espec, espec, espec),
                 out_specs=(bspec, P()), check_vma=False)
        def _sharded(xl, wg, w1, w3, w2):
            y, aux = _moe_shard_body(cfg, axes.model, xl, wg, w1, w3, w2,
                                     all_axes)
            return y, aux

        y, aux = _sharded(x, p["wg"], p["w1"], p["w3"], p["w2"])
    if cfg.num_shared_experts > 0:
        y = y + layers.mlp_fwd(cfg, p["shared"], x)
    return y, aux


def _moe_shard_body(cfg: ModelConfig, model_axis, xl, wg, w1, w3, w2,
                    all_axes):
    """Per-device body: local dispatch to this shard's experts, psum combine."""
    B, S, D = xl.shape
    T = B * S
    E = cfg.num_experts
    tp = jax.lax.axis_size(model_axis)
    El = E // tp
    m = jax.lax.axis_index(model_axis)
    xt = xl.reshape(T, D)

    vals, ids, aux = _route(cfg, wg, xt)              # (T,k)
    C = expert_capacity(cfg, T)

    # slot within expert via one-hot cumsum over flattened choices
    flat_ids = ids.reshape(-1)                        # (T*k,)
    oh = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)
    slots = (jnp.cumsum(oh, axis=0) - 1) * oh         # rank among same-expert
    flat_slots = jnp.sum(slots, axis=-1)              # (T*k,)

    local = (flat_ids >= m * El) & (flat_ids < (m + 1) * El) & (flat_slots < C)
    e_local = jnp.where(local, flat_ids - m * El, El)   # El = out-of-bounds drop
    s_local = jnp.where(local, flat_slots, C)

    token_idx = jnp.repeat(jnp.arange(T), cfg.experts_per_token)
    buf = jnp.zeros((El, C, D), xl.dtype)
    buf = buf.at[e_local, s_local].set(xt[token_idx], mode="drop")

    y = _expert_mlp(cfg, {"w1": w1, "w3": w3, "w2": w2}, buf)   # (El, C, D)

    gathered = y.at[e_local, s_local].get(mode="fill", fill_value=0.0)  # (T*k, D)
    w = jnp.where(local, vals.reshape(-1), 0.0).astype(xl.dtype)
    out = jnp.zeros((T, D), xl.dtype).at[token_idx].add(gathered * w[:, None])
    out = jax.lax.psum(out, model_axis)
    aux = jax.lax.pmean(aux, all_axes)
    return out.reshape(B, S, D), aux


def _moe_local(cfg: ModelConfig, p, x):
    """Single-device fallback (no model axis): dense loop over experts."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    vals, ids, aux = _route(cfg, p["wg"], xt)
    T = xt.shape[0]
    C = expert_capacity(cfg, T)
    E = cfg.num_experts
    flat_ids = ids.reshape(-1)
    oh = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)
    slots = (jnp.cumsum(oh, axis=0) - 1) * oh
    flat_slots = jnp.sum(slots, axis=-1)
    ok = flat_slots < C
    e_idx = jnp.where(ok, flat_ids, E)
    s_idx = jnp.where(ok, flat_slots, C)
    token_idx = jnp.repeat(jnp.arange(T), cfg.experts_per_token)
    buf = jnp.zeros((E, C, D), x.dtype).at[e_idx, s_idx].set(xt[token_idx], mode="drop")
    y = _expert_mlp(cfg, p, buf)
    gathered = y.at[e_idx, s_idx].get(mode="fill", fill_value=0.0)
    w = jnp.where(ok, vals.reshape(-1), 0.0).astype(x.dtype)
    out = jnp.zeros((T, D), x.dtype).at[token_idx].add(gathered * w[:, None])
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Reference (oracle) MoE: exact dense computation, no capacity drops.
# Used by tests to bound the capacity path's deviation and by kernels/ref.
# ---------------------------------------------------------------------------


def moe_ref(cfg: ModelConfig, p, x):
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    vals, ids, _ = _route(cfg, p["wg"], xt)
    outs = []
    for e in range(cfg.num_experts):
        act = jax.nn.silu if cfg.act == "silu" else (lambda u: jax.nn.gelu(u, approximate=True))
        g = act(xt @ p["w1"][e])
        u = xt @ p["w3"][e]
        outs.append((g * u) @ p["w2"][e])
    ys = jnp.stack(outs, 0)                              # (E, T, D)
    w_full = jnp.zeros((xt.shape[0], cfg.num_experts), jnp.float32)
    w_full = w_full.at[jnp.arange(xt.shape[0])[:, None], ids].set(vals)
    out = jnp.einsum("te,etd->td", w_full, ys.astype(jnp.float32))
    y = out.reshape(B, S, D).astype(x.dtype)
    if cfg.num_shared_experts > 0:
        y = y + layers.mlp_fwd(cfg, p["shared"], x)
    return y
