"""Memory-optimal chunked attention with a flash-style custom VJP.

A plain ``lax.scan`` online-softmax is memory-honest in the *forward* pass
but its transpose saves per-chunk residuals — reintroducing the O(S^2)
logits it was built to avoid (measured: +13 GiB/device on llama train_4k).
This module gives chunked attention the FlashAttention backward: residuals
are just (q, k, v, out, m, l); dq/dk/dv are accumulated chunk-by-chunk with
the standard D = rowsum(dout*out) trick.

Supports: GQA (flat q heads vs grouped kv), causal masking, sliding
window, logit softcap (tanh), cross-attention (distinct kv positions).
Used by every full-sequence attention in the framework; the Pallas
flash_attention kernel implements the same contract for the TPU hot path.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

NEG = -1e30


def _mask(opts, q_pos, pb, B, Sq, c):
    causal, window, _, _ = opts
    m = jnp.ones((B, Sq, c), bool)
    if causal:
        m &= pb[:, None, :] <= q_pos[:, :, None]
    if window > 0:
        m &= pb[:, None, :] > (q_pos[:, :, None] - window)
    return m


def _chunks(x, nc, c):
    return jnp.moveaxis(x.reshape(x.shape[0], nc, c, *x.shape[2:]), 1, 0)


def _flash_fwd_impl(opts, q, k, v, q_pos, kv_pos):
    causal, window, chunk, softcap = opts
    B, Sq, H, dh = q.shape
    Skv, Hkv, dv = k.shape[1], k.shape[2], v.shape[-1]
    G = H // Hkv
    c = min(chunk, Skv)
    nc = Skv // c
    scale = 1.0 / math.sqrt(dh)
    qf = q.astype(jnp.float32) * scale
    kc, vc, pc = _chunks(k, nc, c), _chunks(v, nc, c), _chunks(kv_pos, nc, c)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, pb = xs
        kb = jnp.repeat(kb.astype(jnp.float32), G, axis=2)
        vb = jnp.repeat(vb.astype(jnp.float32), G, axis=2)
        s = jnp.einsum("bqhd,bchd->bqhc", qf, kb)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(_mask(opts, q_pos, pb, B, Sq, c)[:, :, None, :], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bqhc,bchd->bqhd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, H), NEG, jnp.float32)
    l0 = jnp.zeros((B, Sq, H), jnp.float32)
    a0 = jnp.zeros((B, Sq, H, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    return out, (m, l)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def flash_attention(opts, q, k, v, q_pos, kv_pos):
    """opts = (causal, window, chunk, softcap); q (B,Sq,H,dh) flat heads."""
    out, _ = _flash_fwd_impl(opts, q, k, v, q_pos, kv_pos)
    return out


def _fwd(opts, q, k, v, q_pos, kv_pos):
    out, (m, l) = _flash_fwd_impl(opts, q, k, v, q_pos, kv_pos)
    return out, (q, k, v, q_pos, kv_pos, out, m, l)


def _bwd(opts, res, dout):
    causal, window, chunk, softcap = opts
    q, k, v, q_pos, kv_pos, out, m, l = res
    B, Sq, H, dh = q.shape
    Skv, Hkv, dvdim = k.shape[1], k.shape[2], v.shape[-1]
    G = H // Hkv
    c = min(chunk, Skv)
    nc = Skv // c
    scale = 1.0 / math.sqrt(dh)
    qf = q.astype(jnp.float32)
    doutf = dout.astype(jnp.float32)
    outf = out.astype(jnp.float32)
    lsafe = jnp.maximum(l, 1e-30)
    D = jnp.sum(doutf * outf, axis=-1)                   # (B,Sq,H)
    kc, vc, pc = _chunks(k, nc, c), _chunks(v, nc, c), _chunks(kv_pos, nc, c)

    def step(dq, xs):
        kb, vb, pb = xs
        kbf = jnp.repeat(kb.astype(jnp.float32), G, axis=2)   # (B,c,H,dh)
        vbf = jnp.repeat(vb.astype(jnp.float32), G, axis=2)
        s_pre = jnp.einsum("bqhd,bchd->bqhc", qf * scale, kbf)
        if softcap > 0:
            s = jnp.tanh(s_pre / softcap) * softcap
        else:
            s = s_pre
        msk = _mask(opts, q_pos, pb, B, Sq, c)[:, :, None, :]
        s = jnp.where(msk, s, NEG)
        p = jnp.exp(s - m[..., None]) / lsafe[..., None]      # (B,Sq,H,c)
        dvb = jnp.einsum("bqhc,bqhd->bchd", p, doutf)
        dp = jnp.einsum("bqhd,bchd->bqhc", doutf, vbf)
        ds = p * (dp - D[..., None])
        if softcap > 0:
            ds = ds * (1.0 - jnp.square(s / softcap))
        ds = jnp.where(msk, ds, 0.0)
        dq_new = dq + jnp.einsum("bqhc,bchd->bqhd", ds, kbf) * scale
        dkb = jnp.einsum("bqhc,bqhd->bchd", ds, qf) * scale
        # fold G q-heads back onto their kv head
        dkb = dkb.reshape(B, c, Hkv, G, dh).sum(3)
        dvb = dvb.reshape(B, c, Hkv, G, dvdim).sum(3)
        return dq_new, (dkb, dvb)

    dq0 = jnp.zeros((B, Sq, H, dh), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(step, dq0, (kc, vc, pc))
    dk = jnp.moveaxis(dk, 0, 1).reshape(B, Skv, Hkv, dh)
    dv = jnp.moveaxis(dv, 0, 1).reshape(B, Skv, Hkv, dvdim)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


flash_attention.defvjp(_fwd, _bwd)


def naive_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=0,
                    softcap=0.0):
    """O(S^2) oracle for tests (materializes full logits)."""
    B, Sq, H, dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    kf = jnp.repeat(k.astype(jnp.float32), G, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), G, axis=2)
    s = jnp.einsum("bqhd,bshd->bqhs", q.astype(jnp.float32), kf) / math.sqrt(dh)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    msk = jnp.ones((B, Sq, k.shape[1]), bool)
    if causal:
        msk &= kv_pos[:, None, :] <= q_pos[:, :, None]
    if window > 0:
        msk &= kv_pos[:, None, :] > (q_pos[:, :, None] - window)
    s = jnp.where(msk[:, :, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqhs,bshd->bqhd", p, vf).astype(q.dtype)
