"""Unified model API: configs, registry, and the functional model surface.

Every architecture in `repro.configs` produces a `ModelConfig`; the functions
in `repro.models.transformer` consume it.  All model code is purely
functional (params pytree in, tensors out) so it composes with pjit/shard_map
and with the coroutine runtime's module-granularity dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Tuple

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Static description of how mesh axes map to parallelism roles.

    batch: axes the global batch is sharded over (DP).
    model: axis used for TP/EP/sequence-split.
    """

    batch: Tuple[str, ...] = ("data",)
    model: Optional[str] = "model"

    @property
    def all(self) -> Tuple[str, ...]:
        return self.batch + ((self.model,) if self.model else ())


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention flavour ---
    attn_bias: bool = False            # qwen2-style QKV bias
    sliding_window: int = 0            # 0 = full attention
    rope_theta: float = 10000.0
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["silu", "gelu"] = "silu"
    logit_softcap: float = 0.0

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                  # per-expert hidden dim
    router_dtype: str = "float32"
    capacity_factor: float = 1.25
    num_shared_experts: int = 0
    shared_d_ff: int = 0

    # --- MLA (deepseek-style) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0

    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_groups: int = 1

    # --- hybrid (recurrentgemma): repeating unit of block kinds ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    local_window: int = 0

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0               # frontend frames (stub provides embeds)

    # --- vlm ---
    num_patches: int = 0               # vision stub patch count

    # --- numerics / misc ---
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    # ----- derived quantities -----
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (total, all experts)."""
        from repro.models import transformer

        return transformer.param_count(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        from repro.models import transformer

        return transformer.param_count(self, active_only=True)


# ---------------------------------------------------------------------------
# Input shapes assigned to every LM-family architecture.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: Literal["train", "prefill", "decode"]
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether a (arch x shape) cell is exercised; see DESIGN.md §5."""
    if shape.name == "long_500k":
        sub_quadratic = (
            cfg.family in ("ssm", "hybrid")
            or cfg.sliding_window > 0
        )
        if not sub_quadratic:
            return False, "long_500k skipped: pure full-attention arch (quadratic)"
        if cfg.family == "audio":
            return False, "long_500k skipped: enc-dec audio backbone"
    return True, ""
