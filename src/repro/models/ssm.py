"""Mamba-2 (SSD, state-space duality) block.

Train/prefill use the chunked SSD algorithm (quadratic intra-chunk term +
inter-chunk state recurrence — arXiv:2405.21060 Alg. 1); decode uses the O(1)
recurrent step.  Heads/d_inner are TP-sharded over ``model``; B/C (group)
projections are replicated (n_groups=1 ≪ 16).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.api import ModelConfig
from repro.models import layers


def init_ssm(cfg: ModelConfig, key):
    dt = jnp.dtype(cfg.dtype)
    D, W = cfg.d_model, cfg.d_inner
    H, N, K = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_conv
    GN = cfg.ssm_groups * N
    ks = jax.random.split(key, 8)
    sc = 1.0 / math.sqrt(D)
    return {
        "wz": (jax.random.normal(ks[0], (D, W)) * sc).astype(dt),
        "wx": (jax.random.normal(ks[1], (D, W)) * sc).astype(dt),
        "wB": (jax.random.normal(ks[2], (D, GN)) * sc).astype(dt),
        "wC": (jax.random.normal(ks[3], (D, GN)) * sc).astype(dt),
        "wdt": (jax.random.normal(ks[4], (D, H)) * sc).astype(dt),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D_skip": jnp.ones((H,), jnp.float32),
        "conv_x": (jax.random.normal(ks[5], (K, W)) / math.sqrt(K)).astype(dt),
        "conv_B": (jax.random.normal(ks[6], (K, GN)) / math.sqrt(K)).astype(dt),
        "conv_C": (jax.random.normal(ks[7], (K, GN)) / math.sqrt(K)).astype(dt),
        "norm_w": jnp.ones((W,), dt),
        "wout": (jax.random.normal(key, (W, D)) / math.sqrt(W)
                 / math.sqrt(max(cfg.num_layers, 1))).astype(dt),
    }


def _causal_conv(x, w):
    """Depthwise causal conv. x (B,S,C), w (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K))
    return out


def _conv_step(x, conv_cache, w):
    """x (B,1,C); conv_cache (B,K-1,C) holds the previous K-1 inputs."""
    K = w.shape[0]
    window = jnp.concatenate([conv_cache, x], axis=1)          # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", window, w)[:, None, :]
    return out, window[:, 1:, :]


def ssd_chunked(xh, dt, A, Bm, Cm, *, chunk=64):
    """Chunked SSD scan.

    xh (b,s,h,p); dt (b,s,h) fp32 post-softplus; A (h,) fp32 negative;
    Bm/Cm (b,s,g,n).  Returns (y (b,s,h,p), final_state (b,h,n,p)).
    """
    b, s, h, p = xh.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    Q = min(chunk, s)
    assert s % Q == 0
    nc = s // Q
    xf = xh.astype(jnp.float32).reshape(b, nc, Q, h, p)
    dtc = dt.reshape(b, nc, Q, h)
    Bh = jnp.repeat(Bm.astype(jnp.float32).reshape(b, nc, Q, g, n), rep, axis=3)
    Ch = jnp.repeat(Cm.astype(jnp.float32).reshape(b, nc, Q, g, n), rep, axis=3)

    dA = dtc * A                                                # (b,nc,Q,h) ≤ 0
    cums = jnp.cumsum(dA, axis=2)
    # --- intra-chunk (quadratic within chunk) ---
    scores = jnp.einsum("bcihn,bcjhn->bchij", Ch, Bh)           # (b,nc,h,Q,Q)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    # mask the EXPONENT (upper triangle is positive -> exp overflow would
    # poison gradients through where)
    delta = cums[:, :, :, None, :] - cums[:, :, None, :, :]     # (b,nc,i,j,h)
    delta = jnp.where(tri[None, None, :, :, None], delta, -60.0)
    L = jnp.exp(delta) * dtc[:, :, None, :, :]                  # dt_j
    y_intra = jnp.einsum("bchij,bcijh,bcjhp->bcihp", scores, L, xf)
    # --- chunk states ---
    decay_end = jnp.exp(cums[:, :, -1:, :] - cums)              # (b,nc,Q,h)
    states = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp", Bh, dtc * decay_end, xf)
    chunk_decay = jnp.exp(cums[:, :, -1, :])                    # (b,nc,h)

    def scanf(Hprev, inp):
        S_c, dec = inp
        return Hprev * dec[:, :, None, None] + S_c, Hprev

    H0 = jnp.zeros((b, h, n, p), jnp.float32)
    Hlast, Hprev = jax.lax.scan(
        scanf, H0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    Hprev = jnp.moveaxis(Hprev, 0, 1)                           # (b,nc,h,n,p)
    y_inter = jnp.einsum("bcihn,bcih,bchnp->bcihp", Ch, jnp.exp(cums), Hprev)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, Hlast


def ssm_fwd(cfg: ModelConfig, p, x, *, chunk=64, return_state=False):
    """Full-sequence Mamba-2 block. x (B,S,D) -> (B,S,D)."""
    B, S, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = jnp.einsum("bsd,dw->bsw", x, p["wz"])
    u = jnp.einsum("bsd,dw->bsw", x, p["wx"])
    Braw = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Craw = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    u = jax.nn.silu(_causal_conv(u, p["conv_x"]))
    Bm = jax.nn.silu(_causal_conv(Braw, p["conv_B"]))
    Cm = jax.nn.silu(_causal_conv(Craw, p["conv_C"]))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = u.reshape(B, S, H, P)
    Bm = Bm.reshape(B, S, cfg.ssm_groups, N)
    Cm = Cm.reshape(B, S, cfg.ssm_groups, N)
    y, Hlast = ssd_chunked(xh, dt, A, Bm, Cm, chunk=chunk)
    y = y + (p["D_skip"][:, None] * xh.astype(jnp.float32))
    y = y.reshape(B, S, cfg.d_inner).astype(x.dtype)
    y = layers.rms_norm(y, p["norm_w"]) * jax.nn.silu(z)
    out = jnp.einsum("bsw,wd->bsd", y, p["wout"])
    if return_state:
        # conv caches: last K-1 raw inputs of each conv branch
        K = cfg.ssm_conv
        uraw = jnp.einsum("bsd,dw->bsw", x, p["wx"])
        cc = {
            "conv_x": uraw[:, S - (K - 1):, :],
            "conv_B": Braw[:, S - (K - 1):, :],
            "conv_C": Craw[:, S - (K - 1):, :],
            "state": Hlast,
        }
        return out, cc
    return out


def ssm_decode(cfg: ModelConfig, p, x, cache):
    """One-token recurrent step. x (B,1,D); cache from init_ssm_cache."""
    B = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = jnp.einsum("bsd,dw->bsw", x, p["wz"])
    uraw = jnp.einsum("bsd,dw->bsw", x, p["wx"])
    Braw = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Craw = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    u, cx = _conv_step(uraw, cache["conv_x"], p["conv_x"])
    Bm, cB = _conv_step(Braw, cache["conv_B"], p["conv_B"])
    Cm, cC = _conv_step(Craw, cache["conv_C"], p["conv_C"])
    u, Bm, Cm = jax.nn.silu(u), jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["wdt"]).astype(jnp.float32)
        + p["dt_bias"])[:, 0]                                    # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                          # (B,H)
    xh = u[:, 0].reshape(B, H, P).astype(jnp.float32)
    Bh = jnp.repeat(Bm[:, 0].reshape(B, cfg.ssm_groups, N), H // cfg.ssm_groups, 1)
    Ch = jnp.repeat(Cm[:, 0].reshape(B, cfg.ssm_groups, N), H // cfg.ssm_groups, 1)
    state = cache["state"] * a[:, :, None, None] + jnp.einsum(
        "bhn,bh,bhp->bhnp", Bh, dt, xh)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, state) + p["D_skip"][:, None] * xh
    y = y.reshape(B, 1, cfg.d_inner).astype(x.dtype)
    y = layers.rms_norm(y, p["norm_w"]) * jax.nn.silu(z)
    out = jnp.einsum("bsw,wd->bsd", y, p["wout"])
    new_cache = {"conv_x": cx, "conv_B": cB, "conv_C": cC, "state": state}
    return out, new_cache


def init_ssm_cache(cfg: ModelConfig, B: int, dtype=jnp.bfloat16):
    H, P, N, K = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_conv
    GN = cfg.ssm_groups * N
    return {
        "conv_x": jnp.zeros((B, K - 1, cfg.d_inner), dtype),
        "conv_B": jnp.zeros((B, K - 1, GN), dtype),
        "conv_C": jnp.zeros((B, K - 1, GN), dtype),
        "state": jnp.zeros((B, H, N, P), jnp.float32),
    }
