"""Core neural layers: norms, RoPE, GQA/MLA attention, gated MLPs.

Conventions
-----------
* All functions are pure; params are plain dicts of jnp arrays.
* Activations flow in ``cfg.dtype`` (bf16 by default); softmax statistics and
  norm reductions are in fp32.
* q heads are FLAT (B, S, H, dh); k/v are grouped (B, S, Hkv, dh) and
  expanded per KV-chunk inside the attention loop.  This allows q heads to be
  TP-sharded while small GQA kv head counts stay replicated, and lets archs
  whose head count does not divide the model axis fall back to
  sequence-sharded (SP) attention (see distributed/sharding.py).
* Full-sequence attention uses a chunked online-softmax (flash-style) in pure
  JAX so dry-run lowering is memory-honest (O(S*chunk) logits, never O(S^2)).
  The Pallas kernels in ``repro.kernels`` implement the same contract for the
  TPU hot path and are validated against these functions.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.models.api import ModelConfig

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return xf.astype(dt) * w


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * w + b


def init_norm(cfg: ModelConfig, d=None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"w": jnp.ones((d,), _pdt(cfg))}
    return {"w": jnp.ones((d,), _pdt(cfg)), "b": jnp.zeros((d,), _pdt(cfg))}


def apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p["w"])
    return layer_norm(x, p["w"], p["b"])


def _pdt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# RoPE (rotate-half / neox convention)
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float, rot_dim: Optional[int] = None):
    """x: (B, S, ..., dh); positions: (B, S)."""
    dh = x.shape[-1]
    rot = rot_dim or dh
    half = rot // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) * 2.0 / rot))
    ang = positions.astype(jnp.float32)[..., None] * inv          # (B, S, half)
    shape = ang.shape[:2] + (1,) * (x.ndim - 3) + (half,)
    cos, sin = jnp.cos(ang).reshape(shape), jnp.sin(ang).reshape(shape)
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., :half], xr[..., half:]
    r = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    r = r.astype(x.dtype)
    return jnp.concatenate([r, x[..., rot:]], -1) if rot < dh else r


def sinusoid_pos(positions, d, dtype):
    """Whisper-style sinusoidal positional embedding. positions (B,S) -> (B,S,d)."""
    half = d // 2
    inv = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                  * (math.log(10000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (flash-style, pure JAX)
# ---------------------------------------------------------------------------


def chunked_attention(
    q,                      # (B, Sq, H, dh)   flat q heads
    k,                      # (B, Skv, Hkv, dh)
    v,                      # (B, Skv, Hkv, dv)
    q_positions,            # (B, Sq) int32
    kv_positions,           # (B, Skv) int32
    *,
    causal: bool = True,
    window: int = 0,        # 0 = unbounded
    chunk: int = 512,
    softcap: float = 0.0,
):
    """Flash-style chunked attention (memory-optimal fwd AND bwd).

    Delegates to models.flash: a custom-VJP online softmax whose backward
    recomputes per-chunk logits instead of saving them (DESIGN.md §6).
    """
    from repro.models import flash

    Skv = k.shape[1]
    chunk = min(chunk, Skv)
    assert Skv % chunk == 0, (Skv, chunk)
    opts = (bool(causal), int(window), int(chunk), float(softcap))
    return flash.flash_attention(opts, q, k, v, q_positions, kv_positions)


def decode_attention(
    q,                      # (B, 1, H, dh)
    k_cache,                # (B, S, Hkv, dh)  (sequence dim may be sharded)
    v_cache,
    lengths,                # (B,) number of valid cache positions
    *,
    window: int = 0,
    softcap: float = 0.0,
):
    """One-token attention against a dense cache.

    Whole-cache einsum + masked softmax: under pjit with the cache sequence
    dimension sharded over ``model`` this partitions into flash-decoding
    (partial softmax per shard + all-reduce of max/sum).
    """
    B, _, H, dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, 1, Hkv, G, dh)
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg.astype(jnp.float32) * scale,
                   k_cache.astype(jnp.float32))
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    pos = jnp.arange(S, dtype=jnp.int32)[None, :]
    mask = pos < lengths[:, None]
    if window > 0:
        mask &= pos > (lengths[:, None] - 1 - window)
    s = jnp.where(mask[:, None, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p / jnp.maximum(l, 1e-30),
                     v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, dh).astype(q.dtype)


def decode_attention_ring(
    q,                      # (B, 1, H, dh)
    k_cache,                # (B, Wc, Hkv, dh) ring buffer
    v_cache,
    pos_cache,              # (B, Wc) int32 positions stored per slot (-1 = empty)
    lengths,                # (B,) current position (inclusive of new token)
    *,
    window: int,
    softcap: float = 0.0,
):
    """Sliding-window decode against a ring-buffer cache (SWA archs)."""
    B, _, H, dh = q.shape
    Wc, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, 1, Hkv, G, dh)
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg.astype(jnp.float32) * scale,
                   k_cache.astype(jnp.float32))
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    mask = (pos_cache >= 0) & (pos_cache < lengths[:, None]) \
        & (pos_cache > (lengths[:, None] - 1 - window))
    s = jnp.where(mask[:, None, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p / jnp.maximum(l, 1e-30),
                     v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, dh).astype(q.dtype)


def attention_decode_ring(cfg: ModelConfig, p, x, k_cache, v_cache, pos_cache,
                          lengths, *, window=None, use_rope=True):
    """One-token SWA decode with ring-buffer cache of size min(window, S)."""
    w = cfg.sliding_window if window is None else window
    positions = lengths[:, None]
    q, k, v = attention_qkv(cfg, p, x, positions, use_rope=use_rope)
    Wc = k_cache.shape[1]
    slot = lengths % Wc
    k_cache = cache_update(k_cache, k, slot)
    v_cache = cache_update(v_cache, v, slot)
    oh = jax.nn.one_hot(slot, Wc, dtype=jnp.int32)
    pos_cache = pos_cache * (1 - oh) + oh * lengths[:, None]
    o = decode_attention_ring(q, k_cache, v_cache, pos_cache, lengths + 1,
                              window=w, softcap=cfg.logit_softcap)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, k_cache, v_cache, pos_cache


def cache_update(cache, new, lengths, axes=None):
    """Write ``new`` (B, 1, Hkv, dh) at position ``lengths``.

    Single-device / no-mesh: per-row ``dynamic_update_slice`` (vmapped) —
    O(1) HBM traffic per step.  This replaced the one-hot mix, which cost
    a full O(S) cache read+write per layer per step; writes whose index
    falls outside [0, S) are dropped, matching the old one-hot semantics.
    With ``axes`` (decode regime, cache sequence dim sharded over
    ``model``): shard_map + per-shard dynamic-update-slice — only the shard
    owning position ``lengths`` writes one token (§Perf D1).
    """
    if axes is not None and axes.model is not None:
        mesh = compat.get_abstract_mesh()
        if not mesh.empty and axes.model in mesh.axis_names:
            return _cache_update_dus(cache, new, lengths, axes, mesh)
    return _cache_update_dus_local(cache, new, lengths)


def _cache_update_dus_local(cache, new, lengths):
    """Per-row DUS write: cache (B, S, ...), new (B, 1, ...), lengths (B,).
    Out-of-range rows (index < 0 or >= S) are no-op writes — DUS alone
    would clamp to S-1 and clobber the last position."""
    S = cache.shape[1]

    def row(c_row, n_row, i):
        zeros = (0,) * (c_row.ndim - 1)
        inb = (i >= 0) & (i < S)
        i_c = jnp.clip(i, 0, S - 1)
        cur = jax.lax.dynamic_slice(c_row, (i_c,) + zeros, n_row.shape)
        return jax.lax.dynamic_update_slice(
            c_row, jnp.where(inb, n_row, cur), (i_c,) + zeros)

    return jax.vmap(row)(cache, new, lengths)


def _cache_update_dus(cache, new, lengths, axes, mesh):
    import math as _math
    from functools import partial
    from jax.sharding import PartitionSpec as P

    dp = _math.prod(mesh.shape[a] for a in axes.batch) if axes.batch else 1
    Bax = axes.batch if cache.shape[0] % max(dp, 1) == 0 else None
    cspec = P(Bax, axes.model, None, None)
    bspec = P(Bax, None, None, None)
    lspec = P(Bax)

    @partial(compat.shard_map, mesh=mesh,
             in_specs=(cspec, bspec, lspec), out_specs=cspec,
             check_vma=False)
    def upd(c_l, n_l, len_l):
        m = jax.lax.axis_index(axes.model)
        S_l = c_l.shape[1]
        idx = len_l - m * S_l                       # (B_l,) local position

        def row(c_row, n_row, i):
            inb = (i >= 0) & (i < S_l)
            i_c = jnp.clip(i, 0, S_l - 1)
            cur = jax.lax.dynamic_slice(c_row, (i_c, 0, 0), n_row.shape)
            n_eff = jnp.where(inb, n_row, cur)      # no-op write off-shard
            return jax.lax.dynamic_update_slice(c_row, n_eff, (i_c, 0, 0))

        return jax.vmap(row)(c_l, n_l, idx)

    return upd(cache, new, lengths)


def _cache_update_2d(cache, new, lengths):
    """cache (B, S, R), new (B, 1, R): same per-row DUS write (the MLA
    latent cache shares the O(1)-traffic path)."""
    return _cache_update_dus_local(cache, new, lengths)


# ---------------------------------------------------------------------------
# GQA attention block (flat q heads)
# ---------------------------------------------------------------------------


def init_attention(cfg: ModelConfig, key):
    dt = _pdt(cfg)
    D, H, Hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(D)
    p = {
        "wq": (jax.random.normal(ks[0], (D, H, dh)) * sc).astype(dt),
        "wk": (jax.random.normal(ks[1], (D, Hkv, dh)) * sc).astype(dt),
        "wv": (jax.random.normal(ks[2], (D, Hkv, dh)) * sc).astype(dt),
        "wo": (jax.random.normal(ks[3], (H, dh, D))
               * sc / math.sqrt(max(cfg.num_layers, 1))).astype(dt),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((H, dh), dt)
        p["bk"] = jnp.zeros((Hkv, dh), dt)
        p["bv"] = jnp.zeros((Hkv, dh), dt)
    return p


def attention_qkv(cfg: ModelConfig, p, x, positions, *, use_rope=True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def kv_from_states(cfg: ModelConfig, p, states):
    """Compute (k, v) from encoder states (cross-attention source)."""
    k = jnp.einsum("bsd,dhk->bshk", states, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", states, p["wv"])
    if cfg.attn_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


def attention_fwd(cfg: ModelConfig, p, x, positions, *, causal=True, chunk=512,
                  use_rope=True, window=None, kv=None, kv_positions=None,
                  shard_hint=None):
    """Full-sequence attention; returns (out, (k, v)) for cache capture.

    kv/kv_positions: optional precomputed cross-attention source.
    shard_hint: optional fn applied to (q, k, v) to pin sharding (SP vs TP).
    """
    if kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if cfg.attn_bias:
            q = q + p["bq"]
        k, v = kv
        kv_pos = kv_positions
    else:
        q, k, v = attention_qkv(cfg, p, x, positions, use_rope=use_rope)
        kv_pos = positions
    if shard_hint is not None:
        q, k, v = shard_hint(q, k, v)
    w = cfg.sliding_window if window is None else window
    o = chunked_attention(q, k, v, positions, kv_pos, causal=causal,
                          window=w, chunk=chunk, softcap=cfg.logit_softcap)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (k, v)


def attention_decode(cfg: ModelConfig, p, x, k_cache, v_cache, lengths, *,
                     use_rope=True, window=None, update_cache=True,
                     axes=None):
    """One-token decode; returns (out, new_k_cache, new_v_cache)."""
    positions = lengths[:, None]  # (B, 1)
    q, k, v = attention_qkv(cfg, p, x, positions, use_rope=use_rope)
    if update_cache:
        k_cache = cache_update(k_cache, k, lengths, axes=axes)
        v_cache = cache_update(v_cache, v, lengths, axes=axes)
    w = cfg.sliding_window if window is None else window
    o = decode_attention(q, k_cache, v_cache,
                         lengths + (1 if update_cache else 0),
                         window=w, softcap=cfg.logit_softcap)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-style latent KV) — used by the paper's own model
# ---------------------------------------------------------------------------


def init_mla(cfg: ModelConfig, key):
    dt = _pdt(cfg)
    D, H = cfg.d_model, cfg.num_heads
    r_q, r_kv, dr = cfg.q_lora_rank, cfg.kv_lora_rank, cfg.rope_head_dim
    dn = cfg.head_dim
    ks = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(D)
    return {
        "wq_a": (jax.random.normal(ks[0], (D, r_q)) * sc).astype(dt),
        "q_norm": jnp.ones((r_q,), dt),
        "wq_b": (jax.random.normal(ks[1], (r_q, H, dn + dr)) / math.sqrt(r_q)).astype(dt),
        "wkv_a": (jax.random.normal(ks[2], (D, r_kv + dr)) * sc).astype(dt),
        "kv_norm": jnp.ones((r_kv,), dt),
        "wk_b": (jax.random.normal(ks[3], (r_kv, H, dn)) / math.sqrt(r_kv)).astype(dt),
        "wv_b": (jax.random.normal(ks[4], (r_kv, H, dn)) / math.sqrt(r_kv)).astype(dt),
        "wo": (jax.random.normal(ks[5], (H, dn, D)) * sc
               / math.sqrt(max(cfg.num_layers, 1))).astype(dt),
    }


def mla_project(cfg: ModelConfig, p, x, positions):
    dn = cfg.head_dim
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv, k_rope = ckv[..., : cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank:]
    c_kv = rms_norm(c_kv, p["kv_norm"])
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_fwd(cfg: ModelConfig, p, x, positions, *, chunk=512):
    """Prefill/train path: decompress latent KV and run standard MHA."""
    dr = cfg.rope_head_dim
    q_nope, q_rope, c_kv, k_rope = mla_project(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"])
    q = jnp.concatenate([q_nope, q_rope], -1)                    # (B,S,H,dn+dr)
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], k_nope.shape[:3] + (dr,))
    k = jnp.concatenate([k_nope, k_rope_b], -1)
    o = chunked_attention(q, k, v, positions, positions, causal=True, chunk=chunk)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (c_kv, k_rope)


def mla_decode(cfg: ModelConfig, p, x, ckv_cache, krope_cache, lengths):
    """Absorbed decode: attention in latent space; cache = (c_kv, k_rope)."""
    q_nope, q_rope, c_kv, k_rope = mla_project(cfg, p, x, lengths[:, None])
    ckv_cache = _cache_update_2d(ckv_cache, c_kv, lengths)
    krope_cache = _cache_update_2d(krope_cache, k_rope, lengths)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"])
    scale = 1.0 / math.sqrt(cfg.head_dim + cfg.rope_head_dim)
    s = (jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(jnp.float32),
                    ckv_cache.astype(jnp.float32))
         + jnp.einsum("bqhk,bsk->bhqs", q_rope.astype(jnp.float32),
                      krope_cache.astype(jnp.float32))) * scale
    S = ckv_cache.shape[1]
    mask = jnp.arange(S, dtype=jnp.int32)[None, :] < (lengths + 1)[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    pattn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", pattn,
                       ckv_cache.astype(jnp.float32)).astype(x.dtype)
    o = jnp.einsum("bqhr,rhk->bqhk", o_lat, p["wv_b"])
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, ckv_cache, krope_cache


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------


def init_mlp(cfg: ModelConfig, key, d_ff=None):
    dt = _pdt(cfg)
    D, F = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w1": (jax.random.normal(ks[0], (D, F)) / math.sqrt(D)).astype(dt),
        "w3": (jax.random.normal(ks[1], (D, F)) / math.sqrt(D)).astype(dt),
        "w2": (jax.random.normal(ks[2], (F, D)) / math.sqrt(F)
               / math.sqrt(max(cfg.num_layers, 1))).astype(dt),
    }


def mlp_fwd(cfg: ModelConfig, p, x):
    act = jax.nn.silu if cfg.act == "silu" else (lambda u: jax.nn.gelu(u, approximate=True))
    g = act(jnp.einsum("bsd,df->bsf", x, p["w1"]))
    u = jnp.einsum("bsd,df->bsf", x, p["w3"])
    return jnp.einsum("bsf,fd->bsd", g * u, p["w2"])
