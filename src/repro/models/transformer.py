"""Unified model composition for all assigned architectures.

One scanned-stack LM covering: dense (llama/qwen/smollm/danube), MoE
(phi3.5-moe, qwen3-moe), MLA+MoE (deepseek-r1, the paper's own model), SSM
(mamba2), hybrid (recurrentgemma rec-rec-attn units), enc-dec audio
(whisper backbone) and VLM (pixtral backbone).  Layers are stacked with a
leading L dim and executed with ``jax.lax.scan`` (compact HLO, fast AOT
compile — see DESIGN.md §6); `jax.checkpoint` wraps the body for training.

The module exposes the functional surface consumed by launch/steps.py and by
the coroutine runtime:
    init_params, forward_loss, prefill, decode_step, init_cache,
    param_specs, cache_specs, batch_specs, param_count
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models.api import MeshAxes, ModelConfig
from repro.models import layers, moe as moe_lib, rglru, ssm as ssm_lib

AUX_COEF = 0.01
CE_CHUNK = 512


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def padded_vocab(cfg: ModelConfig) -> int:
    return -(-cfg.vocab_size // 16) * 16


def _pdt(cfg):
    return jnp.dtype(cfg.dtype)


def _stack_init(init_fn, key, n):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _hybrid_counts(cfg: ModelConfig):
    """(#full units, #tail rec layers) for the hybrid block pattern."""
    unit = len(cfg.block_pattern)
    return cfg.num_layers // unit, cfg.num_layers % unit


# ---------------------------------------------------------------------------
# per-kind layer init
# ---------------------------------------------------------------------------


def _init_dense_layer(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    return {"ln1": layers.init_norm(cfg), "attn": layers.init_attention(cfg, k1),
            "ln2": layers.init_norm(cfg), "mlp": layers.init_mlp(cfg, k2)}


def _init_moe_layer(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    attn = (layers.init_mla(cfg, k1) if cfg.use_mla
            else layers.init_attention(cfg, k1))
    return {"ln1": layers.init_norm(cfg), "attn": attn,
            "ln2": layers.init_norm(cfg), "moe": moe_lib.init_moe(cfg, k2)}


def _init_ssm_layer(cfg: ModelConfig, key):
    return {"ln1": layers.init_norm(cfg), "ssm": ssm_lib.init_ssm(cfg, key)}


def _init_rg_sublayer(cfg: ModelConfig, key, kind: str):
    k1, k2 = jax.random.split(key)
    if kind == "rec":
        t = rglru.init_rglru(cfg, k1)
    else:
        t = layers.init_attention(cfg, k1)
    return {"ln1": layers.init_norm(cfg), "t": t,
            "ln2": layers.init_norm(cfg), "mlp": layers.init_mlp(cfg, k2)}


def _init_rg_unit(cfg: ModelConfig, key):
    ks = jax.random.split(key, len(cfg.block_pattern))
    return {f"b{i}": _init_rg_sublayer(cfg, ks[i], kind)
            for i, kind in enumerate(cfg.block_pattern)}


def _init_enc_layer(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    return {"ln1": layers.init_norm(cfg), "attn": layers.init_attention(cfg, k1),
            "ln2": layers.init_norm(cfg), "mlp": layers.init_mlp(cfg, k2)}


def _init_dec_layer(cfg: ModelConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": layers.init_norm(cfg), "attn": layers.init_attention(cfg, k1),
            "ln2": layers.init_norm(cfg), "xattn": layers.init_attention(cfg, k2),
            "ln3": layers.init_norm(cfg), "mlp": layers.init_mlp(cfg, k3)}


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dt = _pdt(cfg)
    V, D = padded_vocab(cfg), cfg.d_model
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (V, D)) * 0.01).astype(dt),
        "lm_head": (jax.random.normal(ks[1], (D, V)) / math.sqrt(D)).astype(dt),
        "final_norm": layers.init_norm(cfg),
    }
    if cfg.family in ("dense", "vlm"):
        params["layers"] = _stack_init(partial(_init_dense_layer, cfg), ks[2],
                                       cfg.num_layers)
    elif cfg.family == "moe":
        params["layers"] = _stack_init(partial(_init_moe_layer, cfg), ks[2],
                                       cfg.num_layers)
    elif cfg.family == "ssm":
        params["layers"] = _stack_init(partial(_init_ssm_layer, cfg), ks[2],
                                       cfg.num_layers)
    elif cfg.family == "hybrid":
        n_units, n_tail = _hybrid_counts(cfg)
        params["units"] = _stack_init(partial(_init_rg_unit, cfg), ks[2], n_units)
        if n_tail:
            params["tail"] = _stack_init(
                partial(_init_rg_sublayer, cfg, kind="rec"), ks[3], n_tail)
    elif cfg.family == "audio":
        params["enc_layers"] = _stack_init(partial(_init_enc_layer, cfg), ks[2],
                                           cfg.encoder_layers)
        params["enc_norm"] = layers.init_norm(cfg)
        params["layers"] = _stack_init(partial(_init_dec_layer, cfg), ks[3],
                                       cfg.num_layers)
    if cfg.family in ("audio", "vlm"):
        params["adapter"] = (jax.random.normal(ks[4], (D, D)) / math.sqrt(D)).astype(dt)
    return params


# ---------------------------------------------------------------------------
# layer forward (full sequence) — returns (h, aux, cache_entry)
# ---------------------------------------------------------------------------


def _layer_fwd(cfg: ModelConfig, axes: MeshAxes, p, h, positions, hint,
               want_cache: bool):
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        if want_cache:
            y, cache = ssm_lib.ssm_fwd(cfg, p["ssm"], layers.apply_norm(cfg, p["ln1"], h),
                                       return_state=True)
        else:
            y = ssm_lib.ssm_fwd(cfg, p["ssm"], layers.apply_norm(cfg, p["ln1"], h))
            cache = None
        return h + y, aux, cache

    if cfg.family == "hybrid":
        raise RuntimeError("hybrid layers handled by _rg_unit_fwd")

    # attention
    xn = layers.apply_norm(cfg, p["ln1"], h)
    if cfg.use_mla:
        attn_out, kv = layers.mla_fwd(cfg, p["attn"], xn, positions)
        cache = {"ckv": kv[0], "kr": kv[1]} if want_cache else None
    else:
        attn_out, kv = layers.attention_fwd(
            cfg, p["attn"], xn, positions, use_rope=cfg.family != "audio",
            causal=True, shard_hint=hint)
        if not want_cache:
            cache = None
        elif cfg.sliding_window > 0:
            cache = _to_ring(cfg, kv[0], kv[1], positions, cfg.sliding_window)
        else:
            cache = {"k": kv[0], "v": kv[1]}
    h = h + attn_out

    # ffn
    xn = layers.apply_norm(cfg, p["ln2"], h)
    if cfg.is_moe:
        y, aux = moe_lib.moe_fwd(cfg, axes, p["moe"], xn)
    else:
        y = layers.mlp_fwd(cfg, p["mlp"], xn)
    return h + y, aux, cache


def _rg_sub_fwd(cfg, axes, p, h, positions, hint, want_cache, kind):
    xn = layers.apply_norm(cfg, p["ln1"], h)
    if kind == "rec":
        if want_cache:
            y, cache = rglru.rglru_fwd(cfg, p["t"], xn, return_state=True)
        else:
            y, cache = rglru.rglru_fwd(cfg, p["t"], xn), None
    else:
        y, kv = layers.attention_fwd(cfg, p["t"], xn, positions,
                                     window=cfg.local_window, shard_hint=hint)
        cache = None
        if want_cache:
            # convert to ring layout of size local_window
            cache = _to_ring(cfg, kv[0], kv[1], positions, cfg.local_window)
    h = h + y
    h = h + layers.mlp_fwd(cfg, p["mlp"], layers.apply_norm(cfg, p["ln2"], h))
    return h, cache


def _to_ring(cfg, k, v, positions, window):
    """Fold full (B,S,Hkv,dh) KV into a ring cache of size `window`."""
    B, S = k.shape[0], k.shape[1]
    Wc = min(window, S)
    k_r, v_r = k[:, S - Wc:], v[:, S - Wc:]
    pos_r = positions[:, S - Wc:]
    # ring layout: slot = pos % Wc
    slot = pos_r % Wc
    k_ring = jnp.zeros_like(k_r).at[jnp.arange(B)[:, None], slot].set(k_r)
    v_ring = jnp.zeros_like(v_r).at[jnp.arange(B)[:, None], slot].set(v_r)
    pos_ring = jnp.full((B, Wc), -1, jnp.int32).at[
        jnp.arange(B)[:, None], slot].set(pos_r)
    return {"k": k_ring, "v": v_ring, "pos": pos_ring}


def _rg_unit_fwd(cfg, axes, p, h, positions, hint, want_cache):
    caches = {}
    for i, kind in enumerate(cfg.block_pattern):
        h, c = _rg_sub_fwd(cfg, axes, p[f"b{i}"], h, positions, hint,
                           want_cache, kind)
        if want_cache:
            caches[f"b{i}"] = c
    return h, caches


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------


def _stack_fwd(cfg, axes, stack, h, positions, hint, want_cache, remat,
               fwd_fn=None, unroll=False):
    fwd_fn = fwd_fn or (lambda p, hh: _layer_fwd(cfg, axes, p, hh, positions,
                                                 hint, want_cache))

    def body(carry, lp):
        hh, aux = carry
        hh = _pin(axes, hh)
        out = fwd_fn(lp, hh)
        if len(out) == 3:
            hh2, a, cache = out
        else:
            hh2, cache = out
            a = jnp.zeros((), jnp.float32)
        return (hh2, aux + a), cache

    if remat:
        body = jax.checkpoint(body)
    (h, aux), caches = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                    stack, unroll=unroll)
    return h, aux, caches


def _pin(axes: MeshAxes, h):
    """Keep the residual stream sharded (batch over DP axes, replicated TP)."""
    mesh = compat.get_abstract_mesh()
    if mesh.empty:
        return h
    return jax.lax.with_sharding_constraint(h, P(axes.batch, None, None))


# ---------------------------------------------------------------------------
# embedding / head / loss
# ---------------------------------------------------------------------------


def _embed_tokens(cfg, params, tokens):
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "hybrid":          # gemma convention
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    return h


def _assemble_inputs(cfg, params, batch):
    """Merge frontend stub embeddings with token embeddings."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    h = _embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm" and "patches" in batch:
        pe = jnp.einsum("bpd,de->bpe", batch["patches"], params["adapter"])
        h = jnp.concatenate([pe.astype(h.dtype), h], axis=1)
    S = h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.family == "audio":
        h = h + layers.sinusoid_pos(positions, cfg.d_model, h.dtype)
    return h, positions


def _encode(cfg, axes, params, frames, hint, remat, unroll=False):
    """Whisper-style encoder over stub frame embeddings (B, enc_seq, D)."""
    h = jnp.einsum("bsd,de->bse", frames, params["adapter"]).astype(_pdt(cfg))
    B, S = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    h = h + layers.sinusoid_pos(positions, cfg.d_model, h.dtype)

    def enc_layer(p, hh):
        xn = layers.apply_norm(cfg, p["ln1"], hh)
        a, _ = layers.attention_fwd(cfg, p["attn"], xn, positions, causal=False,
                                    use_rope=False, shard_hint=hint)
        hh = hh + a
        hh = hh + layers.mlp_fwd(cfg, p["mlp"],
                                 layers.apply_norm(cfg, p["ln2"], hh))
        return hh, None

    h, _, _ = _stack_fwd(cfg, axes, params["enc_layers"], h, positions, hint,
                         False, remat, fwd_fn=enc_layer, unroll=unroll)
    return layers.apply_norm(cfg, params["enc_norm"], h), positions


def _dec_layer_fwd(cfg, axes, p, h, positions, enc, enc_pos, hint, want_cache):
    xn = layers.apply_norm(cfg, p["ln1"], h)
    a, kv = layers.attention_fwd(cfg, p["attn"], xn, positions, causal=True,
                                 use_rope=False, shard_hint=hint)
    h = h + a
    xk, xv = layers.kv_from_states(cfg, p["xattn"], enc)
    xn = layers.apply_norm(cfg, p["ln2"], h)
    a, _ = layers.attention_fwd(cfg, p["xattn"], xn, positions, causal=False,
                                use_rope=False, kv=(xk, xv), kv_positions=enc_pos,
                                shard_hint=None)
    h = h + a
    h = h + layers.mlp_fwd(cfg, p["mlp"], layers.apply_norm(cfg, p["ln3"], h))
    cache = {"k": kv[0], "v": kv[1], "xk": xk, "xv": xv} if want_cache else None
    return h, cache


def logits_fn(cfg, params, h):
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"]).astype(jnp.float32)
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


def _chunked_ce(cfg, params, h, labels, unroll=False):
    """Cross-entropy without materializing (B,S,V): scan over S chunks."""
    B, S, D = h.shape
    V = padded_vocab(cfg)
    c = min(CE_CHUNK, S)
    nc = S // c
    hc = jnp.moveaxis(h.reshape(B, nc, c, D), 1, 0)
    yc = jnp.moveaxis(labels.reshape(B, nc, c), 1, 0)

    def step(carry, xs):
        tot, cnt = carry
        hh, yy = xs
        logits = logits_fn(cfg, params, hh)                     # (B,c,V) f32
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        oh = jax.nn.one_hot(jnp.maximum(yy, 0), V, dtype=jnp.float32)
        ll = jnp.sum(logits * oh, axis=-1)
        valid = (yy >= 0).astype(jnp.float32)
        return (tot + jnp.sum((lse - ll) * valid), cnt + jnp.sum(valid)), None

    # checkpoint: CE backward recomputes per-chunk logits instead of
    # stashing (B,c,V) fp32 per chunk (DESIGN.md §6).
    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(step),
                                 (jnp.zeros((), jnp.float32),
                                  jnp.zeros((), jnp.float32)), (hc, yc),
                                 unroll=unroll)
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# public: forward_loss / prefill / decode_step
# ---------------------------------------------------------------------------


def _backbone(cfg, axes, params, batch, hint, want_cache, remat,
              unroll=False):
    """Shared trunk: inputs -> final hidden states (+aux, +caches)."""
    h, positions = _assemble_inputs(cfg, params, batch)
    enc = None
    if cfg.family == "audio":
        enc, enc_pos = _encode(cfg, axes, params, batch["frames"], hint, remat,
                               unroll=unroll)

        def dec_fn(p, hh):
            return _dec_layer_fwd(cfg, axes, p, hh, positions, enc, enc_pos,
                                  hint, want_cache)

        h, aux, caches = _stack_fwd(cfg, axes, params["layers"], h, positions,
                                    hint, want_cache, remat, fwd_fn=dec_fn,
                                    unroll=unroll)
    elif cfg.family == "hybrid":
        def unit_fn(p, hh):
            return _rg_unit_fwd(cfg, axes, p, hh, positions, hint, want_cache)

        h, _, ucaches = _stack_fwd(cfg, axes, params["units"], h, positions,
                                   hint, want_cache, remat, fwd_fn=unit_fn,
                                   unroll=unroll)
        caches = {"units": ucaches}
        aux = jnp.zeros((), jnp.float32)
        if "tail" in params:
            def tail_fn(p, hh):
                return _rg_sub_fwd(cfg, axes, p, hh, positions, hint,
                                   want_cache, "rec")

            h, _, tcaches = _stack_fwd(cfg, axes, params["tail"], h, positions,
                                       hint, want_cache, remat, fwd_fn=tail_fn,
                                       unroll=unroll)
            caches["tail"] = tcaches
    else:
        h, aux, caches = _stack_fwd(cfg, axes, params["layers"], h, positions,
                                    hint, want_cache, remat, unroll=unroll)
    h = layers.apply_norm(cfg, params["final_norm"], h)
    return h, aux, caches


def forward_loss(cfg: ModelConfig, axes: MeshAxes, params, batch, *,
                 hint=None, remat=True, unroll=False):
    """Training loss (chunked CE + MoE aux)."""
    h, aux, _ = _backbone(cfg, axes, params, batch, hint, False, remat,
                          unroll=unroll)
    loss = _chunked_ce(cfg, params, h, batch["labels"], unroll=unroll)
    if cfg.is_moe:
        loss = loss + AUX_COEF * aux / max(cfg.num_layers, 1)
    return loss


def prefill(cfg: ModelConfig, axes: MeshAxes, params, batch, *, hint=None,
            unroll=False):
    """Prefill: returns (last-position logits, cache pytree)."""
    h, _, caches = _backbone(cfg, axes, params, batch, hint, True, False,
                             unroll=unroll)
    logits = logits_fn(cfg, params, h[:, -1:, :])
    return logits, caches


def decode_step(cfg: ModelConfig, axes: MeshAxes, params, cache, tokens,
                lengths, unroll=False):
    """One greedy decode step.  tokens (B,), lengths (B,) ->
    (next_tokens, cache)."""
    logits, new_cache = decode_step_logits(cfg, axes, params, cache, tokens,
                                           lengths, unroll=unroll)
    next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tokens, new_cache


def decode_step_logits(cfg: ModelConfig, axes: MeshAxes, params, cache,
                       tokens, lengths, unroll=False):
    """One decode step returning the raw next-token logits (B, V) so the
    caller picks the token (argmax or the sampling pipeline)."""
    B = tokens.shape[0]
    h = _embed_tokens(cfg, params, tokens[:, None])
    if cfg.family == "audio":
        h = h + layers.sinusoid_pos(lengths[:, None], cfg.d_model, h.dtype)

    if cfg.family == "hybrid":
        def unit_dec(hh, xs):
            p, c = xs
            newc = {}
            for i, kind in enumerate(cfg.block_pattern):
                hh, newc[f"b{i}"] = _rg_sub_decode(cfg, p[f"b{i}"], hh,
                                                   c[f"b{i}"], lengths, kind)
            return hh, newc

        h, new_units = jax.lax.scan(unit_dec, h,
                                    (params["units"], cache["units"]),
                                    unroll=unroll)
        new_cache = {"units": new_units}
        if "tail" in cache:
            def tail_dec(hh, xs):
                p, c = xs
                hh, nc = _rg_sub_decode(cfg, p, hh, c, lengths, "rec")
                return hh, nc

            h, new_tail = jax.lax.scan(tail_dec, h,
                                       (params["tail"], cache["tail"]),
                                       unroll=unroll)
            new_cache["tail"] = new_tail
    else:
        def body(hh, xs):
            p, c = xs
            return _layer_decode(cfg, axes, p, c, hh, lengths)

        h, new_cache = jax.lax.scan(body, h, (params["layers"], cache),
                                    unroll=unroll)

    h = layers.apply_norm(cfg, params["final_norm"], h)
    logits = logits_fn(cfg, params, h)                           # (B,1,V)
    return logits[:, 0, :], new_cache


def pack_logprob_block(tokens, logits, lp_k: int):
    """Pack one decode step's (tokens, raw logits) into a single f32 row
    block so the whole page still moves in ONE device->host transfer.

    Layout along the last axis (width 2 + 2*lp_k):
      [0]                 tokens, int32 bitcast to f32 (exact round-trip)
      [1]                 log-softmax(logits)[token] — chosen-token logprob
      [2 : 2+K]           top-K logprob values (descending)
      [2+K : 2+2K]        top-K token ids, int32 bitcast to f32
    Unpacked host-side by ``unpack_logprob_block``."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    chosen = jnp.take_along_axis(lp, tokens[:, None].astype(jnp.int32),
                                 axis=1)
    parts = [jax.lax.bitcast_convert_type(tokens.astype(jnp.int32),
                                          jnp.float32)[:, None], chosen]
    if lp_k > 0:
        vals, idx = jax.lax.top_k(lp, lp_k)
        parts += [vals, jax.lax.bitcast_convert_type(idx, jnp.float32)]
    return jnp.concatenate(parts, axis=-1)


def pack_plane_from_lanes(tokens, lanes):
    """Assemble the ``pack_logprob_block`` layout from the lanes dict
    ``repro.sampling.sample_step`` returns (chosen_lp + top-K vals/ids),
    so the sampled megastep reuses the single fused-sampling pass for the
    transfer plane instead of paying a second full-vocab log_softmax +
    top_k.  Layout-identical to ``pack_logprob_block``."""
    parts = [jax.lax.bitcast_convert_type(tokens.astype(jnp.int32),
                                          jnp.float32)[:, None],
             lanes["chosen_lp"][:, None]]
    if lanes["top_vals"] is not None:
        parts += [lanes["top_vals"],
                  jax.lax.bitcast_convert_type(
                      lanes["top_idx"].astype(jnp.int32), jnp.float32)]
    return jnp.concatenate(parts, axis=-1)


def unpack_logprob_block(block_np):
    """Inverse of ``pack_logprob_block`` for a (steps, B, 2+2K) host array.
    Returns (tokens (steps,B) i32, chosen_lp (steps,B) f32,
    topk_vals (steps,B,K) f32 | None, topk_ids (steps,B,K) i32 | None)."""
    import numpy as np
    K = (block_np.shape[-1] - 2) // 2
    tokens = np.ascontiguousarray(block_np[..., 0]).view(np.int32)
    chosen = block_np[..., 1]
    if K == 0:
        return tokens, chosen, None, None
    vals = block_np[..., 2:2 + K]
    ids = np.ascontiguousarray(block_np[..., 2 + K:]).view(np.int32)
    return tokens, chosen, vals, ids


def decode_page(cfg: ModelConfig, axes: MeshAxes, params, cache, tokens,
                lengths, remaining, steps: int, unroll=False,
                sampling=None, lp_k=None, flags=None):
    """Fused decode megastep: `steps` decode steps in ONE program.

    A ``lax.scan`` over ``decode_step`` that keeps tokens/lengths/KV on
    device, self-feeds the sampled token, and masks all per-slot updates
    once ``remaining`` hits zero (mid-page finishes).  Slots whose
    ``remaining`` starts at zero (empty or already-finished) never advance:
    their KV writes land at position ``lengths`` — one past their valid
    region — and are overwritten/ignored, exactly as in the per-step loop.

    tokens/lengths/remaining: (B,) int32.  Returns
    ``(token_block, tokens, lengths, remaining, cache)`` where
    ``token_block`` is (steps, B) — row t is the slot's token after step t
    (rows past a slot's remaining repeat its last token and must be
    discarded by the caller).  One host transfer of ``token_block``
    replaces ``steps`` per-token round-trips.

    With ``sampling=(sp, state)`` (pack_params row arrays + the per-slot
    PRNG/penalty state from repro.sampling) each step runs the full logit
    pipeline and a Gumbel-max categorical draw instead of argmax, the
    state rides the scan carry (split-free fold_in keys, so masked steps
    never perturb a live slot's stream), and stop-token hits zero the
    slot's ``remaining`` on device.  Returns the same tuple plus the
    advanced ``state`` appended.

    With ``lp_k`` set (0 = chosen-token only, K > 0 = also the top-K
    alternatives) each step's output row is the packed
    ``pack_logprob_block`` plane — (steps, B, 2+2K) f32 — built from the
    RAW (pre-sampling-pipeline) model logits, so logprobs ride the
    page's one transfer and report pre-filter values even under
    top-k/top-p sampling.

    ``flags`` (a static :class:`repro.sampling.SampleFlags`, sampled
    path only) bakes the host-decided sampling plan into the executable:
    XLA shared-sort tier vs the Pallas fused kernel, and whether the
    penalty state ops run at all.  On the sampled+logprobs path the
    logprob lanes come out of the same fused-sampling pass.
    """
    if sampling is None:
        if lp_k is None:
            def body(carry, _):
                cache, tokens, lengths, remaining = carry
                nxt, cache = decode_step(cfg, axes, params, cache, tokens,
                                         lengths, unroll=unroll)
                live = remaining > 0
                tokens = jnp.where(live, nxt, tokens)
                lengths = lengths + live.astype(jnp.int32)
                remaining = remaining - live.astype(jnp.int32)
                return (cache, tokens, lengths, remaining), tokens
        else:
            def body(carry, _):
                cache, tokens, lengths, remaining = carry
                logits, cache = decode_step_logits(cfg, axes, params, cache,
                                                   tokens, lengths,
                                                   unroll=unroll)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                live = remaining > 0
                tokens = jnp.where(live, nxt, tokens)
                lengths = lengths + live.astype(jnp.int32)
                remaining = remaining - live.astype(jnp.int32)
                return (cache, tokens, lengths, remaining), \
                    pack_logprob_block(tokens, logits, lp_k)

        (cache, tokens, lengths, remaining), block = jax.lax.scan(
            body, (cache, tokens, lengths, remaining), None, length=steps)
        return block, tokens, lengths, remaining, cache

    from repro.sampling import DEFAULT_FLAGS, sample_step
    sp, state = sampling
    flags = flags or DEFAULT_FLAGS

    def body(carry, _):
        cache, tokens, lengths, remaining, state = carry
        logits, cache = decode_step_logits(cfg, axes, params, cache, tokens,
                                           lengths, unroll=unroll)
        if lp_k is None:
            nxt, live, remaining, state = sample_step(logits, remaining,
                                                      state, sp, flags)
            lanes = None
        else:
            nxt, live, remaining, state, lanes = sample_step(
                logits, remaining, state, sp, flags, lp_k=lp_k)
        tokens = jnp.where(live, nxt, tokens)
        lengths = lengths + live.astype(jnp.int32)
        out = (tokens if lp_k is None
               else pack_plane_from_lanes(tokens, lanes))
        return (cache, tokens, lengths, remaining, state), out

    (cache, tokens, lengths, remaining, state), block = jax.lax.scan(
        body, (cache, tokens, lengths, remaining, state), None, length=steps)
    return block, tokens, lengths, remaining, cache, state


def _layer_decode(cfg, axes, p, c, h, lengths):
    if cfg.family == "ssm":
        y, nc = ssm_lib.ssm_decode(cfg, p["ssm"],
                                   layers.apply_norm(cfg, p["ln1"], h), c)
        return h + y, nc

    xn = layers.apply_norm(cfg, p["ln1"], h)
    if cfg.use_mla:
        a, ckv, kr = layers.mla_decode(cfg, p["attn"], xn, c["ckv"], c["kr"],
                                       lengths)
        nc = {"ckv": ckv, "kr": kr}
    elif cfg.sliding_window > 0 and "pos" in c:
        a, k, v, pos = layers.attention_decode_ring(
            cfg, p["attn"], xn, c["k"], c["v"], c["pos"], lengths)
        nc = {"k": k, "v": v, "pos": pos}
    else:
        a, k, v = layers.attention_decode(cfg, p["attn"], xn, c["k"], c["v"],
                                          lengths,
                                          use_rope=cfg.family != "audio",
                                          axes=axes)
        nc = {"k": k, "v": v}
    h = h + a

    if cfg.family == "audio":
        xn = layers.apply_norm(cfg, p["ln2"], h)
        q = jnp.einsum("bsd,dhk->bshk", xn, p["xattn"]["wq"])
        enc_len = jnp.full((h.shape[0],), c["xk"].shape[1], jnp.int32)
        o = layers.decode_attention(q, c["xk"], c["xv"], enc_len)
        h = h + jnp.einsum("bshk,hkd->bsd", o, p["xattn"]["wo"])
        nc.update({"xk": c["xk"], "xv": c["xv"]})
        xn = layers.apply_norm(cfg, p["ln3"], h)
        h = h + layers.mlp_fwd(cfg, p["mlp"], xn)
        return h, nc

    xn = layers.apply_norm(cfg, p["ln2"], h)
    if cfg.is_moe:
        y, _ = moe_lib.moe_fwd(cfg, axes, p["moe"], xn)
    else:
        y = layers.mlp_fwd(cfg, p["mlp"], xn)
    return h + y, nc


def _rg_sub_decode(cfg, p, h, c, lengths, kind):
    xn = layers.apply_norm(cfg, p["ln1"], h)
    if kind == "rec":
        y, nc = rglru.rglru_decode(cfg, p["t"], xn, c)
    else:
        y, k, v, pos = layers.attention_decode_ring(
            cfg, p["t"], xn, c["k"], c["v"], c["pos"], lengths,
            window=cfg.local_window)
        nc = {"k": k, "v": v, "pos": pos}
    h = h + y
    h = h + layers.mlp_fwd(cfg, p["mlp"], layers.apply_norm(cfg, p["ln2"], h))
    return h, nc


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, B: int, max_len: int):
    """Decode cache sized for `max_len` context (SWA archs: ring of window)."""
    dt = _pdt(cfg)
    L = cfg.num_layers
    Hkv, dh = cfg.num_kv_heads, cfg.head_dim
    if cfg.family == "ssm":
        one = ssm_lib.init_ssm_cache(cfg, B, dt)
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape), one)
    if cfg.family == "hybrid":
        n_units, n_tail = _hybrid_counts(cfg)
        Wc = min(cfg.local_window, max_len)

        def unit_cache():
            d = {}
            for i, kind in enumerate(cfg.block_pattern):
                if kind == "rec":
                    d[f"b{i}"] = rglru.init_rglru_cache(cfg, B, dt)
                else:
                    d[f"b{i}"] = {"k": jnp.zeros((B, Wc, Hkv, dh), dt),
                                  "v": jnp.zeros((B, Wc, Hkv, dh), dt),
                                  "pos": jnp.full((B, Wc), -1, jnp.int32)}
            return d

        cache = {"units": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_units,) + x.shape), unit_cache())}
        if n_tail:
            one = rglru.init_rglru_cache(cfg, B, dt)
            cache["tail"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_tail,) + x.shape), one)
        return cache
    if cfg.use_mla:
        return {"ckv": jnp.zeros((L, B, max_len, cfg.kv_lora_rank), dt),
                "kr": jnp.zeros((L, B, max_len, cfg.rope_head_dim), dt)}
    if cfg.family == "audio":
        enc = cfg.encoder_seq
        return {"k": jnp.zeros((L, B, max_len, Hkv, dh), dt),
                "v": jnp.zeros((L, B, max_len, Hkv, dh), dt),
                "xk": jnp.zeros((L, B, enc, Hkv, dh), dt),
                "xv": jnp.zeros((L, B, enc, Hkv, dh), dt)}
    if cfg.sliding_window > 0:
        Wc = min(cfg.sliding_window, max_len)
        return {"k": jnp.zeros((L, B, Wc, Hkv, dh), dt),
                "v": jnp.zeros((L, B, Wc, Hkv, dh), dt),
                "pos": jnp.full((L, B, Wc), -1, jnp.int32)}
    return {"k": jnp.zeros((L, B, max_len, Hkv, dh), dt),
            "v": jnp.zeros((L, B, max_len, Hkv, dh), dt)}


# ---------------------------------------------------------------------------
# parameter counting (for MODEL_FLOPS)
# ---------------------------------------------------------------------------


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    key = jax.random.PRNGKey(0)
    shapes = jax.eval_shape(lambda: init_params(cfg, key))
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = math.prod(leaf.shape)
        names = [getattr(k, "key", getattr(k, "idx", "")) for k in path]
        if active_only and "moe" in names:
            name = names[-1]
            if name in ("w1", "w2", "w3"):
                n = n // cfg.num_experts * cfg.experts_per_token
        total += n
    return total
