"""AdamW with mixed precision and ZeRO-1 optimizer-state sharding.

Production layout (DESIGN.md §8):
* params live in model dtype (bf16), sharded per the TP rules;
* the optimizer keeps a flat fp32 master copy + Adam moments per leaf,
  each padded and sharded over *all* mesh axes (ZeRO-1) — under pjit the
  param->flat reshard lowers to a reduce-scatter and flat->param to an
  all-gather, exactly the ZeRO-1 wire pattern;
* grads arrive in compute dtype (bf16) — 2x all-reduce compression vs fp32
  (the "gradient compression" knob; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    zero1: bool = True          # shard master/m/v over all mesh axes
    max_grad_norm: float = 1.0


def _flat_pad(x, n_dev):
    f = x.reshape(-1).astype(jnp.float32)
    pad = (-f.size) % n_dev
    return jnp.pad(f, (0, pad))


def _unflat(f, shape, dtype):
    import math
    n = math.prod(shape)
    return f[:n].reshape(shape).astype(dtype)


def init_opt_state(params, n_dev: int):
    """Flat fp32 master + moments per leaf."""
    def make(x):
        f = _flat_pad(x, n_dev)
        return {"master": f, "m": jnp.zeros_like(f), "v": jnp.zeros_like(f)}

    return {"leaves": jax.tree.map(make, params),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_specs(param_specs_tree, all_axes: Tuple[str, ...], zero1: bool):
    flat_spec = P(all_axes) if zero1 else P()

    def make(_):
        return {"master": flat_spec, "m": flat_spec, "v": flat_spec}

    return {"leaves": jax.tree.map(make, param_specs_tree,
                                   is_leaf=lambda x: isinstance(x, P)),
            "step": P()}


def apply_updates(cfg: AdamWConfig, params, grads, opt_state, n_dev: int,
                  flat_sharding=None, param_shardings=None):
    """One AdamW step.  Returns (new_params, new_opt_state, grad_norm)."""
    step = opt_state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    # global grad-norm clip (fp32 accumulation)
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, cfg.max_grad_norm / jnp.maximum(gnorm, 1e-12))

    def upd(g, st, p):
        gf = _flat_pad(g, n_dev) * scale
        if flat_sharding is not None:
            gf = jax.lax.with_sharding_constraint(gf, flat_sharding)
        m = cfg.b1 * st["m"] + (1 - cfg.b1) * gf
        v = cfg.b2 * st["v"] + (1 - cfg.b2) * gf * gf
        upd_ = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        master = st["master"] * (1 - cfg.lr * cfg.weight_decay) - cfg.lr * upd_
        new_p = _unflat(master, p.shape, p.dtype)
        return new_p, {"master": master, "m": m, "v": v}

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_s = treedef.flatten_up_to(opt_state["leaves"])
    outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_params = treedef.unflatten([o[0] for o in outs])
    if param_shardings is not None:
        new_params = jax.lax.with_sharding_constraint(new_params, param_shardings)
    new_leaves = treedef.unflatten([o[1] for o in outs])
    return new_params, {"leaves": new_leaves, "step": step}, gnorm
