"""JAX version-compatibility shims.

The codebase is written against the explicit-mesh API (``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``, ``jax.shard_map``); older jaxlib
builds (e.g. the 0.4.x line this container ships) expose the same
functionality under different names.  Everything version-dependent goes
through this module so the rest of the tree stays on the modern spelling.
"""
from __future__ import annotations

import contextlib
from functools import partial

import jax


class _EmptyMesh:
    """Stand-in for an absent mesh context (matches AbstractMesh surface)."""
    empty = True
    axis_names = ()


def get_abstract_mesh():
    """Current mesh context: AbstractMesh on new JAX, the thread-local
    physical mesh (entered via ``with mesh:`` / ``use_mesh``) on 0.4.x."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as _mesh_lib
    m = _mesh_lib.thread_resources.env.physical_mesh
    return m if m is not None else _EmptyMesh()


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with the ``axis_types`` kwarg bridged.

    New JAX spells non-explicit axes ``axis_types=(AxisType.Auto, ...)``;
    0.4.x predates ``jax.sharding.AxisType`` entirely (every axis is
    implicitly auto) and raises on the kwarg.  Falls back to
    ``jax.sharding.Mesh`` over a reshaped ``jax.devices()`` grid for
    builds older than ``jax.make_mesh`` itself."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    mk = getattr(jax, "make_mesh", None)
    if mk is None:
        import numpy as np
        devs = np.asarray(jax.devices()[: int(np.prod(axis_shapes))])
        return jax.sharding.Mesh(devs.reshape(axis_shapes), axis_names)
    if axis_type is None:
        return mk(axis_shapes, axis_names)
    return mk(axis_shapes, axis_names,
              axis_types=(axis_type.Auto,) * len(axis_names))


def use_mesh(mesh):
    """Context manager activating `mesh` for closed-over jitted code."""
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh            # 0.4.x: Mesh is itself a context manager


def copy_to_host_async(arr):
    """Start an async device→host copy of ``arr`` and return it.

    ``jax.Array.copy_to_host_async`` exists on every supported line
    (0.4.x ArrayImpl included), but jit tracing hands out Tracers and
    some alternate backends return bare numpy — both lack the method, so
    a missing attribute degrades to a no-op (the later blocking
    materialization is then the copy).  The runtime's pipelined KV
    staging (runtime/engine.py ``stage_appends``) funnels through here."""
    fn = getattr(arr, "copy_to_host_async", None)
    if fn is not None:
        fn()
    return arr


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (new) / ``pltpu.TPUCompilerParams`` (0.4.x)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


def shard_map(f=None, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` with the pre-0.5 ``check_rep`` spelling bridged."""
    if f is None:
        return partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=check_vma)
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)
