"""Streaming million-sequence job driver (paper §6.4 'Production
deployment' at job scale).

The batch API (`runtime/api.py`) answers "run THIS list of requests";
this package answers "run this FILE of a million requests on whatever
capacity I have right now":

* ``JsonlRequestSource`` streams the input file — requests are parsed
  lazily under a bounded in-flight window, never the whole job.
* ``ReplicaHandle`` wraps one data-parallel replica: a ``BatchMaster``
  + ``CoroutineScheduler`` over its own node group, fed through the
  incremental ``open``/``append``/``pump`` surface.
* ``StreamingJobDriver`` owns the loop: fill window → dispatch to
  replicas → pump → journal finished rows into a segment-rotated
  ``SegmentedJobLedger`` (crash-resumable, O(tail-segment) replay) →
  finally merge to an input-order jsonl output file.  Replicas are
  elastic: ``scale_up()`` adds one mid-job, ``drain()`` retires one
  with zero lost requests, and a replica that dead-letters is drained
  automatically (first-wins ledger makes the requeue race benign).
"""
from repro.driver.driver import (DriverConfig, DriverResult,
                                 StreamingJobDriver)
from repro.driver.replica import ReplicaHandle
from repro.driver.source import JsonlRequestSource, iter_custom_ids

__all__ = ["DriverConfig", "DriverResult", "StreamingJobDriver",
           "ReplicaHandle", "JsonlRequestSource", "iter_custom_ids"]
