"""One elastic data-parallel replica: a ``BatchMaster`` over its own
node group, driven through the incremental batch surface.

Replica virtual time: every SimEngine keeps its own vclock, and clocks
of different replicas are never comparable (a replica spawned mid-job
starts near zero).  The handle therefore tracks a *join offset* — the
driver-timeline instant the replica joined — and reports
``now() = join_offset + (engine clock - clock at join)``.  The driver's
makespan is the max over replicas, which is exactly the wall-clock a
real deployment would see.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

from repro.core.events import RuntimeRecord
from repro.runtime.api import BatchMaster, BatchRequest


@dataclasses.dataclass
class ReplicaHandle:
    rid: int
    master: BatchMaster
    bid: str
    join_offset: float = 0.0
    clock0: float = 0.0
    draining: bool = False      # admissions stopped; in-flight may finish
    closed: bool = False
    final_now: float = 0.0
    admitted: int = 0
    completed: int = 0

    @classmethod
    def spawn(cls, rid: int, engines: Sequence, *, sched_cfg=None,
              oversubscribe: float = 4.0, policy=None, fault_plan=None,
              join_offset: float = 0.0) -> "ReplicaHandle":
        master = BatchMaster(engines, sched_cfg,
                             oversubscribe=oversubscribe,
                             policy=policy, fault_plan=fault_plan)
        bid = master.open()
        clock0 = max((e.clock() for e in engines), default=0.0)
        return cls(rid=rid, master=master, bid=bid,
                   join_offset=join_offset, clock0=clock0)

    # ------------------------------------------------------------- dispatch
    def headroom(self) -> int:
        if self.closed or self.draining:
            return 0
        return max(self.master.capacity(self.bid) - self.in_flight(), 0)

    def in_flight(self) -> int:
        return 0 if self.closed else self.master.in_flight(self.bid)

    def admit(self, requests: Sequence[BatchRequest]) -> List[int]:
        ids = self.master.append(self.bid, requests)
        self.admitted += len(ids)
        return ids

    def pump(self) -> List[RuntimeRecord]:
        return self.master.pump(self.bid)

    def pop_row(self, seq_id: int) -> Optional[Dict[str, Any]]:
        row = self.master.pop_row(self.bid, seq_id)
        if row is not None:
            self.completed += 1
        return row

    # ---------------------------------------------------------------- state
    def now(self) -> float:
        if self.closed:
            return self.final_now
        clk = max((e.clock()
                   for e in self.master.live_engines(self.bid)),
                  default=self.clock0)
        return self.join_offset + (clk - self.clock0)

    def host_over_budget(self) -> bool:
        """True while any live engine's host store remains over its byte
        budget even after its prefix-LRU eviction cascade — the driver's
        signal to throttle this replica's admissions (instead of letting
        host spill grow unbounded) until decode drains the store."""
        if self.closed:
            return False
        for e in self.master.live_engines(self.bid):
            store = getattr(e, "host_store", None)
            over = getattr(store, "over_budget", None)
            if callable(over) and over():
                return True
        return False

    def healthy(self) -> bool:
        """False once the replica's scheduler has dead-lettered a node or
        lost every engine — the driver's auto-drain trigger."""
        if self.closed:
            return False
        sched = self.master.scheduler(self.bid)
        return bool(sched.engines) and sched.dead_letter_failovers == 0

    def report(self) -> Dict[str, Any]:
        return self.master.report(self.bid)

    # ------------------------------------------------------------ lifecycle
    def cancel(self) -> List[BatchRequest]:
        """Tear down now; returns every request without a captured row."""
        self.final_now = self.now()
        left = self.master.cancel(self.bid)
        self.closed = True
        return left

    def close(self) -> None:
        """Finalize a fully-consumed replica (graceful drain completion)."""
        self.final_now = self.now()
        self.master.close(self.bid)
        self.closed = True
