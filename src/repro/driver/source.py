"""Bounded streaming reader for jsonl batch-input files.

A million-request job must never materialize a million ``BatchRequest``
objects: the driver pulls from this source only when its in-flight
window has room, so resident parsed requests stay O(window).  On resume
the source *peeks* each line's ``custom_id`` (cheap dict access, no
request materialization) and skips anything the ledger already holds.
"""
from __future__ import annotations

import json
from typing import Callable, Iterator, List, Optional

from repro.runtime.api import BatchRequest


def iter_custom_ids(path: str) -> Iterator[str]:
    """Yield ``custom_id`` of every well-formed input line, in input
    order — the merge key for the final output file.  Skips blank and
    malformed lines exactly like ``JsonlRequestSource`` does, so the
    merged file and the request stream agree on the id sequence."""
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except ValueError:
                continue
            cid = d.get("custom_id")
            if cid is not None:
                yield cid


class JsonlRequestSource:
    """Lazy jsonl request stream with resume-skip.

    ``take(n)`` parses at most ``n`` fresh requests; the driver calls it
    with exactly its window headroom.  ``skip`` (typically
    ``ledger.has``) filters finished ids before a ``BatchRequest`` is
    ever built."""

    def __init__(self, path: str,
                 skip: Optional[Callable[[str], bool]] = None):
        self.path = path
        self._skip = skip or (lambda cid: False)
        self._fh = None
        self.exhausted = False
        self.lines_read = 0       # non-blank lines consumed
        self.bad_lines = 0        # unparseable json (counted, skipped)
        self.skipped = 0          # resume-skip / duplicate-skip hits
        self.emitted = 0          # requests handed to the driver

    def open(self) -> "JsonlRequestSource":
        if self._fh is None:
            self._fh = open(self.path, "r", encoding="utf-8")
        return self

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def take(self, n: int) -> List[BatchRequest]:
        if self._fh is None:
            self.open()
        out: List[BatchRequest] = []
        while len(out) < n and not self.exhausted:
            line = self._fh.readline()
            if not line:
                self.exhausted = True
                break
            line = line.strip()
            if not line:
                continue
            self.lines_read += 1
            try:
                d = json.loads(line)
            except ValueError:
                self.bad_lines += 1
                continue
            cid = d.get("custom_id")
            if cid is not None and self._skip(cid):
                self.skipped += 1
                continue
            out.append(BatchRequest.from_dict(d))
        self.emitted += len(out)
        return out
