"""Streaming job driver: bounded-window jsonl → elastic replicas →
segment-rotated ledger → input-order merged output.

The loop each round:

1. **fill** — pull from the input file only up to
   ``window - resident`` (resident = undispatched buffer + in-flight on
   every replica), so parsed requests in memory never exceed the bound;
2. **dispatch** — hand each non-draining replica up to its
   oversubscribed capacity;
3. **pump** — one scheduler round per replica; every finished row is
   journaled into the ``SegmentedJobLedger`` the moment it appears
   (write-ahead: a crash after the fsync costs nothing, a crash before
   it costs one re-decode);
4. **health** — a replica whose scheduler dead-lettered a node (or lost
   all engines) is drained automatically: its unfinished requests go
   back to the window and another replica recomputes them.  The
   first-wins ledger makes the drain/finish race benign — if the dying
   replica did finish a request, the recompute's duplicate row is
   refused, not double-written.

Straggler awareness (driver tier): the loop keeps a per-replica EWMA of
completion throughput on each replica's own timeline.  Dispatch is
rate-ordered (fast replicas admit first) and a replica below the fleet
median gets its admissions capped proportionally to its rate; one that
stays below ``slow_replica_fraction`` x median for
``slow_replica_rounds`` consecutive rounds is auto-drained with requeue
— the driver-level mirror of the scheduler's NODE_SLOW shedding.

``run()`` is crash-resumable end to end: on restart the ledger replays
only its index + tail segment, the source skips finished ids, and the
final merged output (input order, atomic rename) is byte-identical to
an uninterrupted run — SimEngine/NodeEngine decode is a pure function
of the request, never of which replica or scheduler slot ran it.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence

import collections

from repro.core.events import SeqFinishedEvent, TokenBlockEvent
from repro.driver.replica import ReplicaHandle
from repro.driver.source import JsonlRequestSource, iter_custom_ids
from repro.runtime.api import BatchRequest
from repro.runtime.ledger import SegmentedJobLedger


@dataclasses.dataclass
class DriverConfig:
    window: int = 4096          # max parsed requests resident (buffer+flight)
    replicas: int = 1           # initial replica count
    oversubscribe: float = 4.0  # dispatch depth per replica (§6.4)
    max_rounds: int = 10_000_000
    rotate_records: int = 50_000
    rotate_bytes: int = 64 << 20
    fsync_every: int = 64
    timeline_every: int = 1     # sample (now, completed) every N rounds
    # ---- replica-tier straggler mitigation -------------------------------
    rebalance: bool = True          # rate-aware dispatch + slow auto-drain
    rebalance_alpha: float = 0.3    # per-replica throughput EWMA smoothing
    slow_replica_fraction: float = 0.5   # slow when below this x median
    slow_replica_rounds: int = 25   # consecutive rounds before auto-drain


@dataclasses.dataclass
class DriverResult:
    status: str                 # "completed" | "exhausted"
    completed: int              # rows journaled by THIS run
    skipped_resume: int         # input lines already in the ledger
    requeued: int               # requests recycled through drains
    auto_drained: int           # replicas retired by the health trigger
    slow_drained: int           # replicas retired by the throughput trigger
    scale_ups: int
    peak_resident: int          # max parsed requests alive at once
    rounds: int
    makespan_s: float           # driver-timeline makespan (virtual)
    merged_path: str
    merged_records: int
    report: Dict[str, Any]


class StreamingJobDriver:
    """See module docstring.  ``engine_factory(rid)`` must return a fresh
    engine group per call — replicas never share engines."""

    def __init__(self, input_path: str, output_path: str, ledger_root: str,
                 engine_factory: Callable[[int], Sequence], *,
                 cfg: Optional[DriverConfig] = None, sched_cfg=None,
                 policy=None,
                 fault_plan_factory: Optional[Callable[[int], Any]] = None):
        self.input_path = input_path
        self.output_path = output_path
        self.cfg = cfg or DriverConfig()
        self._engine_factory = engine_factory
        self._sched_cfg = sched_cfg
        self._policy = policy
        self._fault_plan_factory = fault_plan_factory or (lambda rid: None)
        self.ledger = SegmentedJobLedger(
            ledger_root, rotate_records=self.cfg.rotate_records,
            rotate_bytes=self.cfg.rotate_bytes,
            fsync_every=self.cfg.fsync_every)
        self.source = JsonlRequestSource(input_path, skip=self.ledger.has)
        self.replicas: List[ReplicaHandle] = []
        self._window: Deque[BatchRequest] = collections.deque()
        self._next_rid = 0
        self.completed = 0
        self.partials_journaled = 0
        self.requeued = 0
        self.auto_drained = 0
        self.slow_drained = 0
        self.scale_ups = 0
        self.budget_throttled = 0   # dispatch rounds skipped: host budget
        # per-replica throughput EWMA (completions / replica-second on the
        # replica's own timeline) — the driver-tier straggler detector
        self._rep_rate: Dict[int, float] = {}
        self._rep_last: Dict[int, tuple] = {}   # rid -> (completed, now)
        self._rep_slow: Dict[int, int] = {}     # rid -> consecutive rounds
        self.peak_resident = 0
        self.rounds = 0
        self.timeline: List[Dict[str, float]] = []
        self.log: List[str] = []

    # ------------------------------------------------------------ elasticity
    def _spawn(self, join_offset: float = 0.0) -> ReplicaHandle:
        rid = self._next_rid
        self._next_rid += 1
        r = ReplicaHandle.spawn(
            rid, self._engine_factory(rid), sched_cfg=self._sched_cfg,
            oversubscribe=self.cfg.oversubscribe, policy=self._policy,
            fault_plan=self._fault_plan_factory(rid),
            join_offset=join_offset)
        self.replicas.append(r)
        self.log.append(f"spawn replica={rid} at t={join_offset:.3f}")
        return r

    def scale_up(self) -> int:
        """Add one replica mid-job, joined at the current driver time; it
        starts admitting on the next dispatch."""
        r = self._spawn(join_offset=self.sim_now())
        self.scale_ups += 1
        return r.rid

    def drain(self, rid: int, *, requeue: bool = True) -> int:
        """Retire a replica.  ``requeue=True`` (default): cancel now and
        recycle every unfinished request through the window — another
        replica recomputes it (MIGRATE across replicas is impossible;
        first-wins journaling makes the recompute race benign).
        ``requeue=False``: stop admissions and let in-flight finish
        (graceful scale-down; closed by the run loop when empty).
        Returns the number of requests requeued."""
        r = self._replica(rid)
        if r is None or r.closed:
            return 0
        if not requeue:
            r.draining = True
            self.log.append(f"drain replica={rid} graceful")
            return 0
        left = r.cancel()
        self._window.extendleft(reversed(left))
        self.requeued += len(left)
        self.log.append(f"drain replica={rid} requeued={len(left)}")
        return len(left)

    def _replica(self, rid: int) -> Optional[ReplicaHandle]:
        for r in self.replicas:
            if r.rid == rid:
                return r
        return None

    def _open_replicas(self) -> List[ReplicaHandle]:
        return [r for r in self.replicas if not r.closed]

    # -------------------------------------------------------------- run loop
    def resident(self) -> int:
        return len(self._window) + sum(r.in_flight()
                                       for r in self._open_replicas())

    def sim_now(self) -> float:
        return max((r.now() for r in self.replicas), default=0.0)

    def _fill(self) -> None:
        budget = self.cfg.window - self.resident()
        if budget > 0 and not self.source.exhausted:
            self._window.extend(self.source.take(budget))

    def _update_rates(self) -> None:
        """Refresh each replica's throughput EWMA from this round's
        (completed, now) delta on ITS OWN timeline.  An idle replica
        (nothing in flight) contributes no evidence — idle is not slow."""
        for r in self._open_replicas():
            now = r.now()
            prev = self._rep_last.get(r.rid)
            self._rep_last[r.rid] = (r.completed, now)
            if prev is None:
                continue
            dc, dt = r.completed - prev[0], now - prev[1]
            if dt <= 0 or (dc == 0 and r.in_flight() == 0):
                continue
            rate = dc / dt
            old = self._rep_rate.get(r.rid)
            a = self.cfg.rebalance_alpha
            self._rep_rate[r.rid] = rate if old is None else (
                a * rate + (1.0 - a) * old)

    def _rate_median(self) -> Optional[float]:
        rates = sorted(self._rep_rate[r.rid] for r in self._open_replicas()
                       if r.rid in self._rep_rate)
        if len(rates) < 2:
            return None     # one replica has no peers to lag
        mid = len(rates) // 2
        return (rates[mid] if len(rates) % 2
                else 0.5 * (rates[mid - 1] + rates[mid]))

    def _dispatch(self) -> None:
        """Rate-ordered admission: fast replicas pull from the window
        first, and a below-median replica's admissions are capped
        proportionally to its rate — new work flows away from stragglers
        without starving them entirely."""
        reps = self._open_replicas()
        med = self._rate_median() if self.cfg.rebalance else None
        if med is not None:
            # unknown-rate replicas (just spawned) sort as fast: they get
            # a full share until they produce evidence
            reps = sorted(reps, key=lambda r: -self._rep_rate.get(
                r.rid, float("inf")))
        for r in reps:
            if not self._window:
                break
            if r.host_over_budget():
                # the replica's host-spill budget is exhausted and the
                # prefix-LRU cascade could not clear it: stop admitting
                # here until decode drains the store — throttle, not die
                self.budget_throttled += 1
                continue
            n = min(r.headroom(), len(self._window))
            if med is not None and med > 0 and n > 0:
                rate = self._rep_rate.get(r.rid)
                if rate is not None and rate < med:
                    n = max(1, int(n * rate / med))
            if n > 0:
                r.admit([self._window.popleft() for _ in range(n)])

    def _pump_all(self) -> int:
        done = 0
        for r in self._open_replicas():
            if r.in_flight() == 0:
                if r.draining:
                    r.close()
                    self.log.append(f"drained replica={r.rid} empty")
                continue
            for rec in r.pump():
                if isinstance(rec, TokenBlockEvent) \
                        and rec.custom_id is not None:
                    # flush the partial block to the journal the moment
                    # the page lands — a tailing consumer streams tokens
                    # while the row is in flight; a recompute's replayed
                    # prefix is refused by offset, not double-written
                    if self.ledger.record_partial(rec.custom_id,
                                                  rec.offset, rec.tokens):
                        self.partials_journaled += 1
                elif isinstance(rec, SeqFinishedEvent):
                    row = r.pop_row(rec.seq_id)
                    if row is not None and self.ledger.record_output(
                            row["custom_id"], row):
                        self.completed += 1
                        done += 1
        return done

    def _health_sweep(self) -> None:
        for r in self._open_replicas():
            if not r.healthy():
                self.auto_drained += 1
                self.log.append(f"auto-drain replica={r.rid} (unhealthy)")
                self.drain(r.rid, requeue=True)
        if not self.cfg.rebalance:
            return
        med = self._rate_median()
        if med is None or med <= 0:
            return
        for r in self._open_replicas():
            rate = self._rep_rate.get(r.rid)
            if rate is None or r.draining:
                continue
            if rate < self.cfg.slow_replica_fraction * med:
                self._rep_slow[r.rid] = self._rep_slow.get(r.rid, 0) + 1
                if (self._rep_slow[r.rid] >= self.cfg.slow_replica_rounds
                        and len(self._open_replicas()) > 1):
                    self.slow_drained += 1
                    self._rep_slow.pop(r.rid, None)
                    self._rep_rate.pop(r.rid, None)
                    self.log.append(f"auto-drain replica={r.rid} (slow: "
                                    f"{rate:.1f} vs median {med:.1f})")
                    self.drain(r.rid, requeue=True)
            else:
                self._rep_slow[r.rid] = 0

    def run(self, on_round: Optional[Callable[["StreamingJobDriver", int],
                                              None]] = None) -> DriverResult:
        """Drive the job to completion.  ``on_round(driver, round)`` runs
        after each round — the hook tests and benchmarks use to trigger a
        mid-job ``scale_up()``/``drain()`` or to kill the process."""
        self.ledger.open()
        self.source.open()
        while len(self._open_replicas()) < self.cfg.replicas:
            self._spawn(join_offset=self.sim_now())
        status = "exhausted"
        while self.rounds < self.cfg.max_rounds:
            self.rounds += 1
            self._fill()
            self.peak_resident = max(self.peak_resident, self.resident())
            if not self._open_replicas() and (self._window
                                              or not self.source.exhausted):
                # every replica died/drained with work left: respawn one
                self.log.append("respawn: no open replicas, work remains")
                self.scale_up()
            if self.cfg.rebalance:
                self._update_rates()
            self._dispatch()
            self._pump_all()
            self._health_sweep()
            if self.cfg.timeline_every > 0 \
                    and self.rounds % self.cfg.timeline_every == 0:
                self.timeline.append({"round": self.rounds,
                                      "t": self.sim_now(),
                                      "completed": self.completed,
                                      "replicas":
                                          len(self._open_replicas())})
            if on_round is not None:
                on_round(self, self.rounds)
            if self.source.exhausted and not self._window \
                    and all(r.in_flight() == 0 for r in self._open_replicas()):
                status = "completed"
                break
        for r in self._open_replicas():
            r.close()
        merged = self._write_merged()
        rep = self.report()
        self.ledger.close()
        self.source.close()
        return DriverResult(
            status=status, completed=self.completed,
            skipped_resume=self.source.skipped, requeued=self.requeued,
            auto_drained=self.auto_drained, slow_drained=self.slow_drained,
            scale_ups=self.scale_ups,
            peak_resident=self.peak_resident, rounds=self.rounds,
            makespan_s=self.sim_now(), merged_path=self.output_path,
            merged_records=merged, report=rep)

    # ---------------------------------------------------------------- output
    def _write_merged(self) -> int:
        """Input-order merged jsonl, atomic via tmp + rename.  Row bytes
        come straight from the ledger segments (locator pread), so two
        runs that journaled the same rows — e.g. a clean run and a
        SIGKILL+resume run — produce byte-identical files."""
        tmp = self.output_path + ".tmp"
        with open(tmp, "w", encoding="utf-8", newline="\n") as fh:
            n = self.ledger.write_merged(iter_custom_ids(self.input_path),
                                         fh)
        os.replace(tmp, self.output_path)
        return n

    # ---------------------------------------------------------------- report
    def report(self) -> Dict[str, Any]:
        """Driver-level view: per-replica scheduler reports plus merged
        robustness/transfer counters (sums across replicas, node lists
        keyed by replica so ids never alias)."""
        per = {r.rid: r.report() for r in self.replicas}
        rob = {"health_failovers": 0, "dead_letter_failovers": 0,
               "failed_nodes": {}, "drained_nodes": {},
               "transfer": {"retries": 0, "timeouts": 0, "dead_letters": 0},
               "slow_flags": 0, "sheds": 0, "shed_migrations": 0,
               "hedges_launched": 0, "hedges_won": 0,
               "governor": {"preempts": 0, "restores": 0,
                            "host_spill_bytes": 0, "restore_stages": 0,
                            "restore_stalls": 0, "restore_wait_s": 0.0,
                            "restore_stage_hidden_s": 0.0,
                            "budget_evictions": 0}}
        for rid, rep in per.items():
            rb = rep.get("robustness", {})
            gv = rb.get("governor", {})
            for k in rob["governor"]:
                rob["governor"][k] += gv.get(k, 0)
            rob["health_failovers"] += rb.get("health_failovers", 0)
            rob["dead_letter_failovers"] += rb.get("dead_letter_failovers", 0)
            rob["slow_flags"] += rb.get("slow_flags", 0)
            rob["sheds"] += rb.get("sheds", 0)
            rob["shed_migrations"] += rb.get("shed_migrations", 0)
            rob["hedges_launched"] += rb.get("hedges", {}).get("launched", 0)
            rob["hedges_won"] += rb.get("hedges", {}).get("won", 0)
            if rb.get("failed_nodes"):
                rob["failed_nodes"][rid] = rb["failed_nodes"]
            if rb.get("drained_nodes"):
                rob["drained_nodes"][rid] = rb["drained_nodes"]
            for k in rob["transfer"]:
                rob["transfer"][k] += rb.get("transfer", {}).get(k, 0)
        return {
            "completed": self.completed,
            "skipped_resume": self.source.skipped,
            "requeued": self.requeued,
            "auto_drained": self.auto_drained,
            "slow_drained": self.slow_drained,
            "budget_throttled": self.budget_throttled,
            "replica_rates": {rid: round(v, 3)
                              for rid, v in self._rep_rate.items()},
            "scale_ups": self.scale_ups,
            "peak_resident": self.peak_resident,
            "window": self.cfg.window,
            "rounds": self.rounds,
            "makespan_s": self.sim_now(),
            "replicas": {rid: {"completed": r.completed,
                               "admitted": r.admitted,
                               "closed": r.closed}
                         for rid, r in ((x.rid, x) for x in self.replicas)},
            "robustness": rob,
            "ledger": {"finished": len(self.ledger),
                       "sealed_segments": self.ledger.sealed_segments,
                       "live_segment": self.ledger.live_segment,
                       "replayed_segments": self.ledger.replayed_segments,
                       "torn_records": self.ledger.torn_records,
                       "duplicates_refused": self.ledger.duplicates_refused,
                       "partials_journaled": self.partials_journaled,
                       "partial_duplicates_refused":
                           self.ledger.partial_duplicates_refused,
                       "partial_gaps": self.ledger.partial_gaps},
            "scheduler_reports": per,
            "log_tail": self.log[-20:],
        }
