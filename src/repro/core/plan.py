"""Scheduling-plan optimization (paper §5.4).

Three pieces, exactly as the paper structures them:

1. **Module-level performance model** — roofline-style cost per module as a
   function of batch size (profiled on real hardware in the paper; here the
   model is analytic over the TPU v5e constants and validated against the
   dry-run cost_analysis in benchmarks/roofline.py).
2. **Execution DAG** — one layer's forward as nodes (compute / transfer)
   with dependency edges; COMBINE cannot run before its inputs' attention
   sub-batches; a module cannot run before its parameters are staged.
3. **Configuration search** — enumerate (B_attn, B_moe, buffer sizes), build
   the DAG, take the critical path (O(V+E) topological DP), pick the
   shortest.

The same PerfModel drives the cluster simulator (runtime/cluster.py) and
the paper-table benchmarks.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.models.api import ModelConfig


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu_v5e"
    peak_flops: float = 197e12          # bf16 / chip
    hbm_bw: float = 819e9               # bytes/s
    hbm_bytes: float = 16 * 2**30
    ici_bw: float = 50e9                # bytes/s/link
    host_link_bw: float = 32e9          # host<->device staging (PCIe-class)
    host_bytes: float = 2 * 2**40       # 2 TB host per node (paper testbed)
    chips_per_node: int = 8

    def with_(self, **kw):
        return dataclasses.replace(self, **kw)


# A5000-class memory-constrained accelerator for §6.5 experiments
A5000 = Hardware(name="a5000", peak_flops=27.8e12, hbm_bw=768e9,
                 hbm_bytes=24 * 2**30, ici_bw=0.0, host_link_bw=16e9,
                 host_bytes=1 * 2**40, chips_per_node=1)


@dataclasses.dataclass
class ModuleCost:
    flops: float
    hbm_bytes: float
    ici_bytes: float = 0.0

    def time(self, hw: Hardware) -> float:
        t = max(self.flops / hw.peak_flops, self.hbm_bytes / hw.hbm_bw)
        if self.ici_bytes and hw.ici_bw:
            t = max(t, self.ici_bytes / hw.ici_bw)
        return t


# ---------------------------------------------------------------------------
# module-level roofline model
# ---------------------------------------------------------------------------


def _attn_param_bytes(cfg: ModelConfig) -> float:
    H, Hkv, dh, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    return 2.0 * (D * H * dh + 2 * D * Hkv * dh + H * dh * D)


def _expert_param_bytes(cfg: ModelConfig) -> float:
    return 2.0 * 3 * cfg.d_model * cfg.moe_d_ff


def _mlp_param_bytes(cfg: ModelConfig) -> float:
    return 2.0 * 3 * cfg.d_model * cfg.d_ff


def attention_cost(cfg: ModelConfig, batch: int, ctx: int, new_tokens: int,
                   *, params_resident: bool = True) -> ModuleCost:
    """One layer's attention for `batch` sequences with `ctx` history,
    processing `new_tokens` positions each (decode: 1; prefill: S)."""
    H, Hkv, dh, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    toks = batch * new_tokens
    eff_ctx = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
    proj = 2.0 * toks * D * (H * dh + 2 * Hkv * dh + H * dh)
    attn = 4.0 * batch * new_tokens * eff_ctx * H * dh
    if new_tokens > 1:  # causal prefill: half the rectangle
        attn *= 0.5
    flops = proj + attn
    kv_bytes = 2.0 * batch * eff_ctx * Hkv * dh * 2
    act_bytes = 2.0 * toks * D * 4
    pbytes = 0.0 if params_resident else _attn_param_bytes(cfg)
    return ModuleCost(flops, kv_bytes + act_bytes + pbytes)


def moe_cost(cfg: ModelConfig, tokens: int, *, experts_resident: bool = True,
             ep_degree: int = 1) -> ModuleCost:
    """One layer's MoE for a combined batch of `tokens`.

    Per-expert batch = tokens*k/E — the quantity COMBINE inflates (Fig. 2b).
    Weight traffic counts every *activated* expert's weights once (the
    memory-bound regime when per-expert batches are small)."""
    E, k, F, D = cfg.num_experts, cfg.experts_per_token, cfg.moe_d_ff, cfg.d_model
    flops = 2.0 * 3 * tokens * k * D * F
    activated = E * (1.0 - (1.0 - k / E) ** max(tokens, 1))
    w_bytes = activated / max(ep_degree, 1) * _expert_param_bytes(cfg)
    if experts_resident and tokens * k / E >= 1:
        w_bytes = min(w_bytes, E / max(ep_degree, 1) * _expert_param_bytes(cfg))
    act_bytes = 2.0 * tokens * D * 2 * k
    ici = 2.0 * tokens * D * 2 if ep_degree > 1 else 0.0   # dispatch+combine
    return ModuleCost(flops, w_bytes + act_bytes, ici)


def mlp_cost(cfg: ModelConfig, tokens: int, *, params_resident=True) -> ModuleCost:
    flops = 2.0 * 3 * tokens * cfg.d_model * cfg.d_ff
    pb = 0.0 if params_resident else _mlp_param_bytes(cfg)
    return ModuleCost(flops, pb + 2.0 * tokens * cfg.d_model * 4)


def saturation_tokens(cfg: ModelConfig, hw: Hardware) -> int:
    """Tokens needed at the MoE gate so every expert's GEMM becomes
    compute-bound (the paper's 16384-token example, §7)."""
    if not cfg.is_moe:
        return 1
    # per-expert batch b*: 2*b*D*F/peak >= 3*2*D*F/bw  =>  b* = 3*peak/bw...
    b_star = math.ceil(hw.peak_flops / hw.hbm_bw)  # ~240 on v5e
    return math.ceil(b_star * cfg.num_experts / cfg.experts_per_token)


# ---------------------------------------------------------------------------
# execution DAG + critical path
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Node:
    name: str
    cost_s: float
    deps: List[str] = dataclasses.field(default_factory=list)
    resource: str = "compute"     # compute | host_link | ici


class DAG:
    def __init__(self):
        self.nodes: Dict[str, Node] = {}

    def add(self, name, cost_s, deps=(), resource="compute"):
        self.nodes[name] = Node(name, cost_s, list(deps), resource)
        return name

    def critical_path(self) -> Tuple[float, List[str]]:
        """Longest path via topological DP — O(V+E)."""
        finish: Dict[str, float] = {}
        parent: Dict[str, Optional[str]] = {}

        def visit(n: str) -> float:
            if n in finish:
                return finish[n]
            node = self.nodes[n]
            best, bp = 0.0, None
            for d in node.deps:
                t = visit(d)
                if t > best:
                    best, bp = t, d
            finish[n] = best + node.cost_s
            parent[n] = bp
            return finish[n]

        end, end_n = 0.0, None
        for n in self.nodes:
            t = visit(n)
            if t > end:
                end, end_n = t, n
        path = []
        while end_n is not None:
            path.append(end_n)
            end_n = parent[end_n]
        return end, list(reversed(path))


@dataclasses.dataclass(frozen=True)
class Plan:
    b_attn: int                   # attention sub-batch (COMBINE on attention)
    b_moe: int                    # combined MoE batch (COMBINE on MoE)
    offload_kv: bool
    offload_params: bool
    # staging-buffer budget: capacity of the transient host<->device ring
    # (memory/buffers.py).  Not just a model input any more — SimEngine's
    # pipelined stage_appends meters its in-flight KV bytes against this
    # (the real NodeEngine sizes its gate from the cache leaves directly).
    ring_buffer_bytes: int
    layer_time_s: float
    notes: str = ""


def build_layer_dag(cfg: ModelConfig, hw: Hardware, b_attn: int, b_moe: int,
                    ctx: int, new_tokens: int, *, offload_kv: bool,
                    offload_params: bool, ep_degree: int = 1) -> DAG:
    """One layer of Algorithm 1 under the memory plan (§5.2 Figure 7)."""
    dag = DAG()
    n_sub = max(b_moe // max(b_attn, 1), 1)
    attn = attention_cost(cfg, b_attn, ctx, new_tokens,
                          params_resident=not offload_params)
    prev = None
    sub_names = []
    for g in range(n_sub):
        deps = [prev] if prev else []
        if offload_params:
            pf = dag.add(f"prefetch_attn_{g}",
                         _attn_param_bytes(cfg) / hw.host_link_bw if g == 0 else 0.0,
                         deps=[], resource="host_link")
            deps.append(pf)
        a = dag.add(f"attn_{g}", attn.time(hw), deps)
        # async KV checkpoint fully overlaps (paper Table 2: <5us + overlap)
        if offload_kv:
            dag.add(f"kv_offload_{g}",
                    2.0 * b_attn * new_tokens * cfg.num_kv_heads
                    * cfg.head_dim * 2 / hw.host_link_bw,
                    [a], resource="host_link")
        sub_names.append(a)
        prev = a
    tokens = b_moe * new_tokens
    comb = dag.add("combine", 0.0, sub_names)
    if cfg.is_moe:
        deps = [comb]
        if offload_params:
            deps.append(dag.add(
                "prefetch_experts",
                moe_cost(cfg, tokens, ep_degree=ep_degree).hbm_bytes
                / hw.host_link_bw, [], resource="host_link"))
        m = moe_cost(cfg, tokens, experts_resident=not offload_params,
                     ep_degree=ep_degree)
        dag.add("moe", m.time(hw), deps)
    else:
        dag.add("mlp", mlp_cost(cfg, tokens,
                                params_resident=not offload_params).time(hw),
                [comb])
    return dag


def search_plan(cfg: ModelConfig, hw: Hardware, *, ctx: int, new_tokens: int,
                max_active: int, offload_kv: bool = False,
                offload_params: bool = False, ep_degree: int = 1) -> Plan:
    """Enumerate (B_attn, B_moe) and pick the shortest critical path per
    token (paper §5.4 'Configuration search')."""
    best: Optional[Plan] = None
    b_moe = max_active
    b = 1
    cands = []
    while b <= b_moe:
        cands.append(b)
        b *= 2
    if b_moe not in cands:
        cands.append(b_moe)
    for b_attn in cands:
        dag = build_layer_dag(cfg, hw, b_attn, b_moe, ctx, new_tokens,
                              offload_kv=offload_kv,
                              offload_params=offload_params,
                              ep_degree=ep_degree)
        t, _ = dag.critical_path()
        per_tok = t / max(b_moe * new_tokens, 1)
        if best is None or per_tok < best.layer_time_s:
            best = Plan(b_attn, b_moe, offload_kv, offload_params,
                        ring_buffer_bytes=int(2 * _expert_param_bytes(cfg))
                        if cfg.is_moe else int(2 * _mlp_param_bytes(cfg)),
                        layer_time_s=per_tok,
                        notes=f"critical-path {t*1e3:.3f} ms/layer")
    return best


def step_time(cfg: ModelConfig, hw: Hardware, plan: Plan, batch: int,
              ctx: int, new_tokens: int, ep_degree: int = 1) -> float:
    """End-to-end forward time for `batch` sequences under `plan`."""
    dag = build_layer_dag(cfg, hw, min(plan.b_attn, batch), batch, ctx,
                          new_tokens, offload_kv=plan.offload_kv,
                          offload_params=plan.offload_params,
                          ep_degree=ep_degree)
    t, _ = dag.critical_path()
    L = cfg.num_layers + cfg.encoder_layers
    # embedding + head
    toks = batch * new_tokens
    head = 2.0 * toks * cfg.d_model * cfg.vocab_size / hw.peak_flops
    return t * L + head
