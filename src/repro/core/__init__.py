"""The paper's primary contribution: the event-driven sequence coroutine
compute model.

- coroutine.py    SequenceCoroutine state machine (Fig. 4a)
- primitives.py   YIELD / COMBINE / PARTITION / MIGRATE (§4.2)
- backend.py      formal ExecutionBackend protocol (slot contract) +
                  validate_backend
- forward.py      Algorithm 1 — module-granularity forward with
                  intra-forward yields and MoE batch COMBINE
- scheduler.py    Algorithm 2 — event-driven scheduling: SchedulerPolicy
                  handler table draining the priority EventQueue, §5.3
                  dynamic sequence management, stream-first results
- events.py       priority event queue + typed stream records
- plan.py         §5.4 — module roofline model, execution DAG,
                  critical-path configuration search
"""
from repro.core.backend import ExecutionBackend, validate_backend  # noqa
from repro.core.coroutine import Phase, SequenceCoroutine, Status  # noqa
from repro.core.events import (EventKind, EventQueue, PrimitiveEvent,  # noqa
                               SeqFinishedEvent, TokenBlockEvent)
from repro.core.primitives import combine, migrate, partition, yield_  # noqa
from repro.core.scheduler import (CoroutineScheduler, SchedulerConfig,  # noqa
                                  SchedulerPolicy)
