"""Event-driven coroutine scheduler: Algorithm 2 + §5.3 dynamic sequence
management, driven by a real event loop.

The scheduler is generic over execution backends implementing the formal
slot protocol (``core/backend.py``).  Both the real mini-engine
(runtime/engine.py — actually executes a JAX model on CPU) and the cluster
simulator (runtime/cluster.py — virtual clocks from the §5.4 performance
model) plug in here, so the scheduling logic benchmarked at 128 GPUs is the
same code that decodes real tokens in the examples.

Event loop
----------
Every phase is a handler registered on a pluggable ``SchedulerPolicy``
table keyed by ``EventKind``; ``step()`` seeds one round of per-node work
and then drains ``self.queue`` in EventKind priority order
(SYNC < SYNC_DRAIN < SEQ_DONE < SEQ_PREEMPT < PAGE_BOUNDARY <
MODULE_READY < REFILL < LONG_TAIL < NODE_SLOW < MIGRATE < NODE_FAILURE <
NODE_DRAIN).  Decode
completion *enqueues* its
follow-up phases instead of inline-calling them, so custom policies can
reorder, drop or wrap any phase, and cluster-sim / real-engine runs share
one code path.  Per decode *page* (P tokens, §5.3) the default policy
dispatches:

  REFILL(tick)   — pre-decode ON_REFILL_NODE, then enqueue MODULE_READY
  MODULE_READY   — decode one page; enqueue SYNC/SYNC_DRAIN/SEQ_DONE/
                   PAGE_BOUNDARY/REFILL/LONG_TAIL for the node
  SYNC           — ISSUE the page's KV gather + async device→host copy
                   (``stage_appends``; host = source of truth)
  SYNC_DRAIN     — land in-flight KV blobs, keeping the newest staged
                   (this page's) in flight so its PCIe copy rides behind
                   the NEXT page's megastep — the two-stage pipeline that
                   hides the sync transfer (§5.2/§5.3 overlap)
  SEQ_DONE       — YIELD finished sequences, release pages (forces a full
                   drain first: eviction consumes host-store state)
  SEQ_PREEMPT    — memory-pressure governor: occupancy crossed the
                   allocator's high watermark — checkpoint least-progress
                   sequences to host, freeing device pages until
                   occupancy drains under the low watermark; they
                   re-admit via COMBINE as the watermark budget re-opens
  PAGE_BOUNDARY  — extend page allocation or YIELD (most-progress-first);
                   an injected ``FaultPlan.oom`` fails the extension
                   alloc itself and preempts through the same path
  REFILL         — COMBINE waiting sequences into the active batch,
                   capped by the governor's watermark admission budget;
                   prefetches h2d restores through the ring buffer
  LONG_TAIL      — PARTITION stragglers over idle devices
  NODE_SLOW      — straggler mitigation: shed a deficit-proportional
                   fraction of a persistently slow (but alive) node's
                   sequences to fast survivors (checkpoint + MIGRATE,
                   the NODE_DRAIN machinery applied partially)
  MIGRATE        — rebalance suspended sequences across nodes (FIFO;
                   ``prim.migrate`` drains the source engine first)
  NODE_FAILURE   — §5.6 recovery: land the failed node's in-flight blobs,
                   migrate checkpointed sequences to the least-loaded
                   survivor, recompute the rest
  NODE_DRAIN     — elastic scale-down: YIELD (fresh checkpoint) + MIGRATE
                   every live sequence to a survivor, then retire the
                   node — the zero-recompute handoff a graceful drain
                   gets that a failure cannot

Health-driven recovery (§5.6)
-----------------------------
Each round starts by arming the engines' injected fault views
(``FaultPlan`` ticks are scheduler rounds — chaos runs replay from a
seed) and collecting one ``heartbeat()`` per engine into the
``HealthMonitor``; ``dead_after`` consecutive missed beats enqueue
NODE_FAILURE from inside the loop — no external monitor process.  A
transfer that dead-letters out of its retry budget (``engine.
dead_lettered``) escalates the node to NODE_FAILURE *inline*,
immediately after the dispatch that tripped it, so a node with a corrupt
slot never decodes another page.  ``policy.recovery_choice`` hooks the
migrate-vs-recompute cost model into the failure handler.

Straggler mitigation (detect → shed → hedge)
--------------------------------------------
Heartbeats also carry cumulative progress counters; a ``ProgressTracker``
turns them into per-node EWMA throughput on each node's own clock.  A
node below ``slow_fraction`` x the fleet median for ``slow_rounds``
consecutive rounds raises NODE_SLOW (never NODE_FAILURE — its beats
still arrive).  ``default_node_slow`` sheds a deficit-proportional
fraction of its sequences to the fastest underloaded survivors
(``policy.shed_choice`` can veto per sequence); a node still flagged
``hedge_deadline_s`` later gets every remaining resident sequence
*hedged* — a speculative clone launched on a fast node, pinned to the
original's token-addressable seed so it reproduces the stream bitwise.
First finisher wins (the result always surfaces under the ORIGINAL
seq_id); the loser is cancelled and retired.

Stream-first results
--------------------
``stream()`` / ``events()`` yield typed records (``TokenBlockEvent`` /
``SeqFinishedEvent`` / ``PrimitiveEvent``) as pages complete; ``run()`` is
a thin wrapper that drains the stream and returns the BCT report.  The
report carries ``status`` = ``"completed" | "exhausted"`` so callers can
detect batches truncated by ``max_ticks``.

Page-block contract (fused decode): ``engine.decode_page`` executes the
whole page as one fused device program capped at ``min(P, max remaining)``
steps (the on-device done mask absorbs mid-page finishes — that cap IS the
early page exit) and applies the returned ``(P, max_active)`` token block
to the coroutines before returning.  The page-boundary handlers therefore
see fully updated coroutine state; ``stage_appends`` issues the block's
KV as one batched gather + async host copy per page, and the next round's
``SYNC_DRAIN`` lands it after the following megastep has been dispatched.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import (Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple, Type, Union)

from repro.core import primitives as prim
from repro.core.backend import validate_backend
from repro.core.coroutine import Phase, SequenceCoroutine, Status
from repro.core.events import (Event, EventKind, EventQueue, HealthEvent,
                               PrimitiveEvent, RuntimeRecord,
                               SeqFinishedEvent, TokenBlockEvent)
from repro.runtime.failure import HealthMonitor, ProgressTracker
from repro.runtime.faults import FaultPlan, TransferDeadLetter
from repro.sampling.params import SamplingParams, derive_fork_seed

logger = logging.getLogger(__name__)

_TICK = "tick"      # payload marking the round-seeding REFILL event


@dataclasses.dataclass
class SchedulerConfig:
    page_size: int = 64              # P — decode tokens between checks
    refill_threshold: float = 0.75   # refill when active < thr * slots
    longtail_active: int = 2         # ON_LONG_TAIL when active <= this
    longtail_min_remaining: int = 64
    migrate_imbalance: int = 2       # min queue difference to migrate
    max_partition_group: int = 8
    # ---- straggler mitigation (detect -> shed -> hedge) ------------------
    mitigate_stragglers: bool = True
    slow_fraction: float = 0.5       # flag below this x fleet-median EWMA
    slow_rounds: int = 3             # K consecutive deficient rounds
    slow_cooldown: int = 10          # rounds before a shed node re-flags
    slow_recover_fraction: float = 0.8   # hysteresis: unflag above this
    slow_ewma_alpha: float = 0.5
    max_shed_fraction: float = 0.75  # cap on the shed fraction
    hedge_deadline_s: float = 5.0    # slow-node clock wait before hedging
    # ---- memory-pressure governor (OOM-safe admission/eviction) ----------
    govern_memory: bool = True       # watermark-driven preempt / re-admit
    high_watermark: Optional[float] = None   # None = keep the allocators'
    low_watermark: Optional[float] = None    # own watermark pair
    preempt_min_active: int = 1      # never preempt the node below this
    restore_stage_depth: int = 4     # h2d restores prefetched per round


# ---------------------------------------------------------------------------
# default policy handlers — each is handler(sched, event) and free to push
# follow-up events; replace any of them via SchedulerPolicy to customize
# ---------------------------------------------------------------------------


def _admit_budget(sched: "CoroutineScheduler", eng) -> int:
    """Sequences the governor lets this node admit right now: the page
    headroom under the allocator's high watermark, two pages per admission
    (the §5.2 reservation).  Admission stops BEFORE the pool saturates —
    the watermark gap is what the governor preempts into — instead of at
    exhaustion, which is what ungoverned ``can_admit`` would do.

    Ungoverned pools (``allocator.governed`` False — a modelling artifact,
    not a configured byte budget) keep the legacy unbounded admission."""
    alloc = eng.allocator
    if not sched.cfg.govern_memory or not getattr(alloc, "governed", True):
        return eng.max_active
    headroom = int(alloc.high_watermark * alloc.total) - alloc.used
    return max(headroom // 2, 0)


def _restore_drained(sched: "CoroutineScheduler", eng, co) -> bool:
    """Admission gate for spilled sequences under the governor: admit
    when the sequence needs no h2d restore, or its staged restore has
    drained (a decode page overlapped the copy).  A spilled sequence
    with no prefetch in flight is staged NOW and deferred one round —
    the stage/drain discipline that turns the evict→re-admit round trip
    from a synchronous PCIe stall into a hidden transfer.  If the ring
    cannot take the prefetch at all, admit synchronously rather than
    starve."""
    ready = getattr(eng, "restore_ready", None)
    stage = getattr(eng, "stage_restore", None)
    if (not callable(ready) or not callable(stage)
            or sched.cfg.restore_stage_depth <= 0):
        return True
    if not eng.host_store.has(co.seq_id):
        return True
    if ready(co.seq_id):
        return True
    return not stage(co)    # staged/in flight -> defer; ring full -> sync


def _refill_node(sched: "CoroutineScheduler", node: int, eng) -> None:
    """COMBINE suspended sequences, then prefill INITs into free slots.
    Both admission paths are capped by the governor's watermark budget."""
    budget = _admit_budget(sched, eng)
    waiting = sched.pending(node, Status.INACTIVE)
    if waiting and budget > 0:
        waiting.sort(key=lambda c: c.submitted_t)     # FIFO fairness
        # no hard re-admission gate: the watermark budget IS the
        # hysteresis — preemption drains occupancy to the LOW watermark,
        # so the budget re-opens a whole high-low band of admissions at
        # once instead of oscillating one-in-one-out at the boundary
        if _governing(sched, eng):
            # spilled sequences wait for their staged restore to drain
            # behind live decode work; an idle node bootstraps by letting
            # the FIRST spill through synchronously — there is nothing to
            # overlap yet — and the rest hide behind its decode
            have_active = bool(sched.pending(node, Status.ACTIVE))
            kept = []
            for co in waiting:
                if not have_active:
                    kept.append(co)
                    have_active = True
                elif _restore_drained(sched, eng, co):
                    kept.append(co)
            waiting = kept
        admitted = prim.combine(waiting[:budget], eng)
        budget -= len(admitted)
        for co in admitted:
            if co.seq_id in sched._preempted:
                sched._preempted.discard(co.seq_id)
                sched.gov_restores += 1
                sched.emit(PrimitiveEvent(co.seq_id, node,
                                          primitive="combine",
                                          detail="restore"))
            else:
                sched.emit(PrimitiveEvent(co.seq_id, node,
                                          primitive="combine"))
    inits = sched.pending(node, Status.INIT)
    if inits:
        free_slots = min(
            eng.max_active - len(sched.pending(node, Status.ACTIVE)),
            budget)
        if free_slots > 0:
            batch = inits[:free_slots]
            # keep fork groups whole across the cut: siblings must prefill
            # in one batch so the engine runs the group's prompt forward
            # once and binds every sibling to the lead's span pages
            if len(batch) < len(inits) and batch[-1].fork_group is not None:
                g = batch[-1].fork_group
                for co in inits[len(batch):]:
                    if co.fork_group != g:
                        break
                    batch.append(co)
            eng.prefill(batch)          # leaves them INACTIVE on host
            for co in batch:            # prefill emits the first token
                sched.emit_token_block(co, 0)
                if co.prefix_hit_tokens:
                    # PREFIX_HIT-aware refill: these prompt tokens were
                    # served from the prefix index, not the model forward
                    sched.emit(PrimitiveEvent(co.seq_id, node,
                                              primitive="prefix_hit",
                                              detail=co.prefix_hit_tokens))
            for co in prim.combine(batch, eng, handoff=True):
                sched.emit(PrimitiveEvent(co.seq_id, node,
                                          primitive="combine",
                                          detail="prefill"))


def _governing(sched: "CoroutineScheduler", eng) -> bool:
    """True when the memory-pressure governor steers this engine: the
    feature is on AND the pool is a real configured budget (ungoverned
    soft pools keep legacy scheduling untouched)."""
    return (sched.cfg.govern_memory
            and getattr(eng.allocator, "governed", True))


def _stage_restores(sched: "CoroutineScheduler", node: int, eng) -> None:
    """Prefetch host→device restores for the node's next admission
    candidates through the ring buffer (the h2d mirror of the d2h sync
    pipeline): ``stage_restore`` issues the async ``device_put`` now, it
    rides behind the upcoming decode page, and the later COMBINE's
    ``take_restore`` installs without waiting on PCIe.  Same stage/drain
    discipline as ``stage_appends``: a restore only stages when the ring
    has room, so prefetch can never starve the sync pipeline."""
    stage = getattr(eng, "stage_restore", None)
    if not callable(stage) or sched.cfg.restore_stage_depth <= 0:
        return
    # no watermark gate here: a staged restore lands in the h2d ring, not
    # the page pool, so the ring's byte budget is the backpressure — and
    # a tight pool (above the high mark most rounds) is exactly when the
    # next admission's restore must already be in flight to be hidden
    waiting = sched.pending(node, Status.INACTIVE)
    waiting.sort(key=lambda c: c.submitted_t)     # the refill order
    staged = 0
    for co in waiting:
        if staged >= sched.cfg.restore_stage_depth:
            break
        if eng.host_store.has(co.seq_id) and stage(co):
            staged += 1


def default_refill(sched: "CoroutineScheduler", ev: Event) -> None:
    """ON_REFILL_NODE (Alg. 2 lines 7-11).  The round-seeding variant
    (payload ``"tick"``) polls the allocator's watermark pair (enqueueing
    SEQ_PREEMPT when occupancy crossed the high watermark — it dispatches
    before this node's MODULE_READY decode), refills only when decode
    under-fills the node, prefetches h2d restores for the next refill's
    candidates, and then enqueues the node's MODULE_READY decode work;
    the post-decode variant refills unconditionally."""
    eng = sched.engine(ev.node)
    if eng is None:
        return
    if ev.payload == _TICK:
        if _governing(sched, eng) and eng.allocator.above_high():
            sched.queue.push(EventKind.SEQ_PREEMPT, ev.node)
        n_active = len(sched.pending(ev.node, Status.ACTIVE))
        if n_active < sched.cfg.refill_threshold * eng.max_active:
            _refill_node(sched, ev.node, eng)
        _stage_restores(sched, ev.node, eng)
        sched.queue.push(EventKind.MODULE_READY, ev.node)
    else:
        _refill_node(sched, ev.node, eng)


def default_module_ready(sched: "CoroutineScheduler", ev: Event) -> None:
    """Decode one page on the node, then ENQUEUE the page-boundary phases
    (sync -> evict -> extend -> refill -> longtail) instead of inline-
    calling them — the queue's priority order sequences them."""
    eng = sched.engine(ev.node)
    if eng is None:
        return
    active = sched.pending(ev.node, Status.ACTIVE)
    if not active:
        eng.drain_appends()     # idle node: land any leftover pipeline
        eng.idle_tick()
        return
    before = {c.seq_id: len(c.generated) for c in active}
    eng.decode_page(active, sched.cfg.page_size)
    for co in active:
        sched.emit_token_block(co, before[co.seq_id])
    for kind in (EventKind.SYNC, EventKind.SYNC_DRAIN, EventKind.SEQ_DONE,
                 EventKind.PAGE_BOUNDARY, EventKind.REFILL,
                 EventKind.LONG_TAIL):
        sched.queue.push(kind, ev.node)


def default_sync(sched: "CoroutineScheduler", ev: Event) -> None:
    """(i) Sync — ISSUE the page's KV gather + async host copy (§5.3 i).
    The blob lands at a later SYNC_DRAIN (pipelined behind the next
    megastep); the host store stays the single source of truth because
    every consumer of it drains first."""
    eng = sched.engine(ev.node)
    if eng is None:
        return
    active = sched.pending(ev.node, Status.ACTIVE)
    if active:
        eng.stage_appends(active)


def default_sync_drain(sched: "CoroutineScheduler", ev: Event) -> None:
    """Land in-flight KV blobs, keeping the just-staged page in flight —
    its device→host copy overlaps the next megastep and is drained by the
    NEXT round's SYNC_DRAIN (or force-drained by any host-store
    consumer).  Priority-ordered before SEQ_DONE/MIGRATE/NODE_FAILURE so
    a queued drain can never be outrun by a queued consumer."""
    eng = sched.engine(ev.node)
    if eng is None:
        return
    eng.drain_appends(keep_newest=1)


def default_seq_done(sched: "CoroutineScheduler", ev: Event) -> None:
    """(ii) Eviction — finished sequences release device + host pages.
    Dropping host-store state consumes it: land every in-flight blob
    first so a staged window can never resurrect an evicted sequence.

    Also sweeps per-request deadlines (graceful degradation: a sequence
    past ``sampling.deadline_s`` finishes with whatever it has,
    ``finish_reason="deadline"``) and resolves hedge races — a finishing
    clone surfaces through its ORIGINAL's seq_id; a finishing original
    cancels its clone (first finisher wins)."""
    eng = sched.engine(ev.node)
    if eng is None:
        return
    sched._check_deadlines(ev.node)
    finished = [co for co in sched.pending(ev.node, Status.ACTIVE)
                if co.remaining == 0]
    if not finished:
        return
    eng.drain_appends()
    for co in finished:
        if co.done:
            continue        # resolved as a hedge loser earlier this loop
        eng.allocator.free_seq(co.seq_id)
        eng.free_slot(co)
        co.slot = None
        eng.host_store.drop(co.seq_id)
        co.finish()
        winner = sched._resolve_hedge(co)
        if winner is not None:
            sched.emit(SeqFinishedEvent(winner.seq_id, winner.node,
                                        finish_reason=winner.finish_reason,
                                        n_generated=len(winner.generated),
                                        sct_s=winner.sct()))


def default_seq_preempt(sched: "CoroutineScheduler", ev: Event) -> None:
    """Memory-pressure governor (SEQ_PREEMPT): device-page occupancy
    crossed the allocator's high watermark — checkpoint sequences to the
    host store (YIELD) and free their device pages until occupancy drains
    under the LOW watermark.

    Draining the whole high→low band (not just back under high) is the
    hysteresis: the next refill's watermark budget re-opens a band of
    admissions at once, instead of oscillating one-in-one-out at the
    high-watermark boundary every round.  Victim order is LEAST progress
    first (deterministic tie-break by seq_id) — the inverse of the §5.3
    page-exhaustion eviction: a sequence near completion will free its
    pages on its own shortly, while the youngest would hold device pages
    longest.  Preempted sequences re-admit through the ordinary COMBINE
    refill as budget re-opens.  ``policy.preempt_choice`` can veto
    individual victims, mirroring ``recovery_choice`` / ``shed_choice``."""
    eng = sched.engine(ev.node)
    if eng is None or not _governing(sched, eng):
        return
    alloc = eng.allocator
    if not alloc.above_high():
        return
    active = sched.pending(ev.node, Status.ACTIVE)
    n_active = len(active)
    if n_active <= sched.cfg.preempt_min_active:
        return
    drained = False
    choose = sched.policy.preempt_choice
    for co in sorted(active, key=lambda c: (c.length, c.seq_id)):
        if alloc.below_low():
            break       # drained the whole high→low band
        if n_active <= sched.cfg.preempt_min_active:
            break
        if co.done or co.status != Status.ACTIVE:
            continue
        if choose is not None and choose(sched, co, eng) != "preempt":
            continue
        if not drained:
            eng.drain_appends()     # checkpoints consume host-store state
            drained = True
        sched._preempt(co, eng, "preempt")
        n_active -= 1


def default_page_boundary(sched: "CoroutineScheduler", ev: Event) -> None:
    """(iii) Extension — two-page reservation; evict most-progress-first.

    An injected ``FaultPlan.oom`` makes the page-extension alloc itself
    fail mid-decode (not just admission), and recovers through the one
    event-loop path: the sequence is preempted (checkpoint → host store
    → free pages) exactly like a watermark preemption and re-admits via
    COMBINE when pressure clears.  Token output is bitwise-unchanged:
    preemption is pure rescheduling.  REAL pool exhaustion is tolerated
    as a soft budget here — sustained pressure is the governor's job
    (watermark SEQ_PREEMPT keeps occupancy below the high mark before
    extension ever fails), so a transient failed extension must not
    thrash the batch with preempt/re-admit churn."""
    eng = sched.engine(ev.node)
    if eng is None:
        return
    active = sched.pending(ev.node, Status.ACTIVE)
    lengths = {c.seq_id: c.length for c in active}
    for victim_id in eng.allocator.ensure_two_pages(lengths):
        co = sched.cos[victim_id]
        if co.status == Status.ACTIVE:
            prim.yield_(co, eng)
            sched.log.append(f"yield(evict) seq={victim_id}")
            sched.emit(PrimitiveEvent(victim_id, ev.node, primitive="yield",
                                      detail="evict"))
    faults = getattr(eng, "faults", None)
    oom = faults is not None and faults.oom_active()
    drained = False
    for co in active:
        if not co.done and co.status == Status.ACTIVE:
            got = None if oom else eng.allocator.alloc(co.seq_id, 1)
            if got is not None or not oom:
                # real exhaustion: soft budget — the governor's watermark
                # preemption owns sustained pressure
                continue
            eng.oom_rejections = getattr(eng, "oom_rejections", 0) + 1
            if not drained:
                eng.drain_appends()
                drained = True
            sched._preempt(co, eng, "oom")


def default_long_tail(sched: "CoroutineScheduler", ev: Event) -> None:
    """ON_LONG_TAIL (Alg. 2 lines 12-14) -> PARTITION one straggler.

    Only THIS node's live sequences count: a busy neighbour node must not
    suppress PARTITION for a node already down to stragglers."""
    eng = sched.engine(ev.node)
    if eng is None:
        return
    cfg = sched.cfg
    live = [c for c in sched.cos.values()
            if c.node == ev.node and not c.done]
    active = [c for c in live if c.status == Status.ACTIVE]
    others = [c for c in live if c.status != Status.ACTIVE]
    if (len(active) <= cfg.longtail_active and not others and active
            and max(c.remaining for c in active) >= cfg.longtail_min_remaining
            and not any(c.partition_group for c in active)):
        # wait for yield (checkpoint), then PARTITION over idle devices
        group = list(range(min(eng.num_devices, cfg.max_partition_group)))
        for co in sorted(active, key=lambda c: -c.remaining):
            prim.yield_(co, eng)
            prim.partition(co, eng, group)
            sched.log.append(f"partition seq={co.seq_id} group={len(group)}")
            sched.emit(PrimitiveEvent(co.seq_id, ev.node,
                                      primitive="partition", detail=group))
            prim.combine([co], eng, handoff=True)
            break


def default_migrate(sched: "CoroutineScheduler", ev: Event) -> None:
    """Opportunistic load balancing: move one suspended sequence from the
    most- to the least-loaded node (FIFO)."""
    if len(sched.engines) < 2:
        return
    nids = [e.node_id for e in sched.engines]
    loads = {n: len(sched.pending(n, Status.INACTIVE))
             + len(sched.pending(n, Status.INIT)) for n in nids}
    hi = max(nids, key=loads.__getitem__)
    lo = min(nids, key=loads.__getitem__)
    if loads[hi] - loads[lo] >= sched.cfg.migrate_imbalance:
        movable = (sched.pending(hi, Status.INACTIVE)
                   or sched.pending(hi, Status.INIT))
        if movable:
            co = movable[0]
            try:
                prim.migrate(co, sched.engine(hi), sched.engine(lo))
            except TransferDeadLetter:
                # the blob never moved (host stores are consistent); the
                # post-dispatch dead-letter sweep escalates node `hi`
                sched.log.append(f"migrate dead-letter seq={co.seq_id}")
                return
            sched.log.append(f"migrate seq={co.seq_id} {hi}->{lo}")
            sched.emit(PrimitiveEvent(co.seq_id, lo, primitive="migrate",
                                      detail=(hi, lo)))


def default_node_slow(sched: "CoroutineScheduler", ev: Event) -> None:
    """Straggler shedding: a live node fell below ``slow_fraction`` x the
    fleet-median throughput for ``slow_rounds`` rounds (ProgressTracker).
    Checkpoint (YIELD) and MIGRATE a fraction of its resident sequences —
    proportional to the throughput deficit, capped at
    ``max_shed_fraction`` — to the fastest underloaded survivors.  This is
    the NODE_DRAIN machinery applied *partially*: the node stays in
    rotation with a lighter load, and a post-shed cooldown keeps its
    still-polluted EWMA from re-flagging it immediately.
    ``policy.shed_choice`` can veto individual moves (mirror of
    ``recovery_choice``)."""
    eng = sched.engine(ev.node)
    if eng is None or len(sched.engines) < 2:
        return
    tr = sched.progress
    survivors = [e for e in sched.engines
                 if e.node_id != ev.node and not tr.is_flagged(e.node_id)]
    if not survivors:
        sched.log.append(f"node_slow node={ev.node} refused: no fast "
                         "survivor")
        return
    # arm the hedge deadline on the slow node's own clock — if shedding
    # does not clear the flag by then, the stragglers get cloned
    sched._slow_since.setdefault(ev.node, eng.clock())
    live = [c for c in sched.cos.values()
            if c.node == ev.node and not c.done and c.remaining > 0]
    deficit = tr.deficit(ev.node)
    frac = min(deficit, sched.cfg.max_shed_fraction)
    n_shed = min(int(round(len(live) * frac)), len(live))
    if n_shed <= 0:
        tr.start_cooldown(ev.node, sched.ticks)
        return
    eng.drain_appends()     # land in-flight KV before checkpoints move

    def load(e):
        return sum(1 for c in sched.cos.values()
                   if c.node == e.node_id and not c.done)

    choose = sched.policy.shed_choice
    moved = 0
    # most-remaining-first: the longest tails gain the most from
    # finishing on a fast node
    for co in sorted(live, key=lambda c: -c.remaining):
        if moved >= n_shed:
            break
        dst = max(survivors,
                  key=lambda e: tr.rate(e.node_id) / (1.0 + load(e)))
        if choose is not None and choose(sched, co, eng, dst) != "shed":
            continue
        if co.status == Status.ACTIVE:
            prim.yield_(co, eng)
            sched.emit(PrimitiveEvent(co.seq_id, ev.node, primitive="yield",
                                      detail="shed"))
        co.partition_group = None
        try:
            prim.migrate(co, eng, dst)
        except TransferDeadLetter:
            # the blob never moved; the post-dispatch dead-letter sweep
            # escalates this node to NODE_FAILURE, which supersedes a shed
            sched.log.append(f"shed migrate dead-letter seq={co.seq_id}")
            return
        moved += 1
        sched.emit(PrimitiveEvent(co.seq_id, dst.node_id,
                                  primitive="migrate", detail="shed"))
    sched.sheds += 1
    sched.shed_moved += moved
    tr.start_cooldown(ev.node, sched.ticks)
    sched.log.append(f"node_slow node={ev.node} shed={moved}/{len(live)} "
                     f"deficit={deficit:.2f}")


def default_node_failure(sched: "CoroutineScheduler", ev: Event) -> None:
    """§5.6 recovery: drop the failed engine from rotation; sequences with
    a host checkpoint MIGRATE to the least-loaded survivor, everything
    whose state died with the node recomputes from the prompt.

    ``policy.recovery_choice`` (the migrate-vs-recompute cost model —
    ``Cluster`` plugs in the §5.4 performance-model version) can demote an
    eligible migrate to a recompute; it can never promote an ineligible
    one — only INACTIVE/INIT sequences with a host checkpoint have state
    that is safe to move."""
    failed = sched.engine(ev.node)
    if failed is None:
        return
    # Land the failed node's in-flight KV blobs before deciding migrate-
    # vs-recompute: the copies were issued before the failure (§5.6 "the
    # host tier survives"), and an undrained window would make a migrated
    # checkpoint lag co.generated.  (A deployment whose DMA died with the
    # node re-gathers instead — here the staged arrays are still live.)
    failed.drain_appends()
    # a dead-letter raised during that drain is already handled (the blob
    # was abandoned and its sequences will recompute below) — this node is
    # being recovered right now, so clear the escalation flag
    failed.dead_lettered = False
    ring = getattr(failed, "ring", None)
    if ring is not None:
        ring.reset()    # abandoned blobs must not hold staging space
    discard_restores = getattr(failed, "discard_restores", None)
    if callable(discard_restores):
        discard_restores()      # staged h2d restores died with the devices
    sched.health.mark_failed(ev.node)
    sched.engines = [e for e in sched.engines if e.node_id != ev.node]
    sched.log.append(f"node_failure node={ev.node}")
    if not sched.engines:
        logger.warning("node %d failed with no survivors; %d sequences "
                       "stranded", ev.node,
                       sum(1 for c in sched.cos.values()
                           if c.node == ev.node and not c.done))
        return

    def load(e):
        return sum(1 for c in sched.cos.values()
                   if c.node == e.node_id and not c.done)

    choose = sched.policy.recovery_choice
    for co in sched.cos.values():
        if co.node != ev.node or co.done:
            continue
        dst = min(sched.engines, key=load)
        co.partition_group = None       # the failed node's devices are gone
        migrated = False
        if (co.status in (Status.INACTIVE, Status.INIT)
                and failed.host_store.has(co.seq_id)
                and (choose is None
                     or choose(sched, co, failed, dst) == "migrate")):
            try:
                prim.migrate(co, failed, dst)
                migrated = True
            except TransferDeadLetter:
                failed.dead_lettered = False    # already recovering
                sched.log.append(
                    f"failover migrate dead-letter seq={co.seq_id}")
        if migrated:
            sched.emit(PrimitiveEvent(co.seq_id, dst.node_id,
                                      primitive="migrate", detail="failover"))
        else:
            # device state (or an unsynced checkpoint) died with the node
            if failed.host_store.has(co.seq_id):
                failed.host_store.drop(co.seq_id)
            co.generated.clear()
            co.token_logprobs.clear()
            co.top_token_logprobs.clear()
            co.length = 0
            co.prefix_hit_tokens = 0    # the re-prefill starts from scratch
            co.slot = None
            co.last_token = 0
            co.stopped = False
            co.phase = Phase.PREFILL
            co.status = Status.INIT
            co.node = dst.node_id
            sched.emit(PrimitiveEvent(co.seq_id, dst.node_id,
                                      primitive="recompute",
                                      detail="failover"))


def default_node_drain(sched: "CoroutineScheduler", ev: Event) -> None:
    """Elastic scale-down: gracefully drain one node and hand its work
    off.  Unlike NODE_FAILURE the node is alive, so every ACTIVE sequence
    YIELDs first (fresh host checkpoint) and then MIGRATEs to the
    least-loaded survivor — zero recompute by construction.  With no
    survivor inside this scheduler the drain is refused (the node stays
    in rotation); a replica-level drain (driver) requeues instead."""
    eng = sched.engine(ev.node)
    if eng is None:
        return
    survivors = [e for e in sched.engines if e.node_id != ev.node]
    if not survivors:
        sched.log.append(f"node_drain node={ev.node} refused: no survivor")
        return
    eng.drain_appends()     # land in-flight KV before the state moves

    def load(e):
        return sum(1 for c in sched.cos.values()
                   if c.node == e.node_id and not c.done)

    for co in [c for c in sched.cos.values()
               if c.node == ev.node and not c.done]:
        if co.status == Status.ACTIVE:
            prim.yield_(co, eng)
            sched.emit(PrimitiveEvent(co.seq_id, ev.node, primitive="yield",
                                      detail="drain"))
        co.partition_group = None       # the drained node's devices leave
        dst = min(survivors, key=load)
        try:
            prim.migrate(co, eng, dst)
        except TransferDeadLetter:
            # the blob never moved; the post-dispatch dead-letter sweep
            # escalates this node to NODE_FAILURE, whose handler replays
            # the handoff with its migrate-vs-recompute fallback
            sched.log.append(f"drain migrate dead-letter seq={co.seq_id}")
            return
        sched.emit(PrimitiveEvent(co.seq_id, dst.node_id,
                                  primitive="migrate", detail="drain"))
    sched.engines = survivors
    sched.drained_nodes.append(ev.node)
    sched.log.append(f"node_drain node={ev.node}")


Handler = Callable[["CoroutineScheduler", Event], None]


@dataclasses.dataclass
class SchedulerPolicy:
    """Pluggable per-EventKind handler table (the §3 event-driven runtime).

    Replace any field to customize one phase without forking the loop —
    handlers receive ``(scheduler, event)`` and may push follow-up events
    onto ``scheduler.queue`` and emit stream records via
    ``scheduler.emit``.

    ``recovery_choice`` is the §5.6 migrate-vs-recompute cost-model hook
    consulted by ``default_node_failure`` for every eligible sequence:
    ``(sched, co, failed_engine, dst_engine) -> "migrate" | "recompute"``
    (None = always migrate when eligible).  ``shed_choice`` is its
    straggler-shedding mirror, consulted by ``default_node_slow`` per
    candidate move: ``(sched, co, slow_engine, dst_engine) -> "shed" |
    "keep"`` (None = always shed up to the deficit fraction).
    ``preempt_choice`` is the memory-pressure mirror, consulted by
    ``default_seq_preempt`` per watermark-preemption victim:
    ``(sched, co, engine) -> "preempt" | "keep"`` (None = always preempt
    least-progress-first until occupancy clears the high watermark)."""
    sync: Handler = default_sync
    sync_drain: Handler = default_sync_drain
    seq_done: Handler = default_seq_done
    seq_preempt: Handler = default_seq_preempt
    page_boundary: Handler = default_page_boundary
    module_ready: Handler = default_module_ready
    refill: Handler = default_refill
    long_tail: Handler = default_long_tail
    node_slow: Handler = default_node_slow
    migrate: Handler = default_migrate
    node_failure: Handler = default_node_failure
    node_drain: Handler = default_node_drain
    recovery_choice: Optional[Callable] = None
    shed_choice: Optional[Callable] = None
    preempt_choice: Optional[Callable] = None

    def table(self) -> Dict[EventKind, Handler]:
        t = {EventKind.SYNC: self.sync,
             EventKind.SYNC_DRAIN: self.sync_drain,
             EventKind.SEQ_DONE: self.seq_done,
             EventKind.SEQ_PREEMPT: self.seq_preempt,
             EventKind.PAGE_BOUNDARY: self.page_boundary,
             EventKind.MODULE_READY: self.module_ready,
             EventKind.REFILL: self.refill,
             EventKind.LONG_TAIL: self.long_tail,
             EventKind.NODE_SLOW: self.node_slow,
             EventKind.MIGRATE: self.migrate,
             EventKind.NODE_FAILURE: self.node_failure,
             EventKind.NODE_DRAIN: self.node_drain}
        missing = set(EventKind) - set(t)
        assert not missing, f"EventKinds without a handler: {missing}"
        return t


class CoroutineScheduler:
    def __init__(self, engines: Sequence, config: SchedulerConfig = None,
                 policy: SchedulerPolicy = None,
                 fault_plan: Optional[FaultPlan] = None,
                 health: Optional[HealthMonitor] = None):
        self.engines = [validate_backend(e) for e in engines]
        self.cfg = config or SchedulerConfig()
        self.policy = policy or SchedulerPolicy()
        self._handlers = self.policy.table()
        self.queue = EventQueue()
        self.cos: Dict[int, SequenceCoroutine] = {}
        self._next_id = 0
        self.retired = 0            # DONE coroutines dropped via retire()
        self.drained_nodes: List[int] = []      # NODE_DRAIN scale-downs
        self.log: List[str] = []
        self.ticks = 0
        self._t0: Optional[float] = None
        self._outbox: List[RuntimeRecord] = []
        # ---- §5.6 robustness: fault plan + live health monitoring --------
        self.fault_plan = fault_plan
        if fault_plan is not None:
            for e in self.engines:
                if getattr(e, "faults", None) is None:
                    e.faults = fault_plan.node_view(e.node_id)
        # default monitor counts missed beats per scheduler round
        # (interval_s=None: per-node clocks — SimEngine vclocks, wall
        # time — are never compared against each other)
        self.health = health or HealthMonitor(0, interval_s=None,
                                              dead_after=3)
        self.health.on_failure = self._on_health_failure
        # every engine ever in rotation — failed nodes keep contributing
        # their transfer/fault counters to report()
        self._all_engines: List = list(self.engines)
        self.health_failovers = 0       # NODE_FAILUREs from missed beats
        self.dead_letter_failovers = 0  # NODE_FAILUREs from dead letters
        # ---- straggler mitigation: detect -> shed -> hedge ---------------
        self.progress = ProgressTracker(
            slow_fraction=self.cfg.slow_fraction,
            slow_rounds=self.cfg.slow_rounds,
            cooldown=self.cfg.slow_cooldown,
            recover_fraction=self.cfg.slow_recover_fraction,
            ewma_alpha=self.cfg.slow_ewma_alpha)
        self._slow_since: Dict[int, float] = {}   # node -> clock at flag
        self.hedged: Dict[int, int] = {}          # original -> live clone
        self.hedge_origin: Dict[int, int] = {}    # live clone -> original
        self.sheds = 0                  # NODE_SLOW sheds executed
        self.shed_moved = 0             # sequences moved off slow nodes
        self.hedges_launched = 0
        self.hedges_won = 0             # clone finished before original
        self.hedges_lost = 0            # original beat its clone
        self.hedges_resolved = 0        # clones retired (won or lost)
        # ---- memory-pressure governor ------------------------------------
        # seq_ids preempted for memory pressure (or mid-flight oom) that
        # have not re-admitted yet (their next COMBINE is a restore)
        self._preempted: set = set()
        self.gov_preempts = 0           # watermark + oom preemptions
        self.gov_restores = 0           # preempted seqs re-admitted
        self.gov_host_spill_bytes = 0   # KV bytes checkpointed by preempts
        if (self.cfg.high_watermark is not None
                or self.cfg.low_watermark is not None):
            for e in self.engines:
                alloc = getattr(e, "allocator", None)
                if alloc is None:
                    continue
                if self.cfg.high_watermark is not None:
                    alloc.high_watermark = self.cfg.high_watermark
                if self.cfg.low_watermark is not None:
                    alloc.low_watermark = self.cfg.low_watermark
                assert (0.0 < alloc.low_watermark
                        <= alloc.high_watermark <= 1.0), (
                    alloc.low_watermark, alloc.high_watermark)

    # ------------------------------------------------------------------ API
    def submit(self, prompts: Sequence[Sequence[int]],
               max_out: Sequence[int],
               sampling: Union[None, SamplingParams,
                               Sequence[SamplingParams]] = None,
               logprobs: Union[bool, Sequence[bool]] = False,
               top_logprobs: Union[int, Sequence[int]] = 0,
               n: int = 1) -> List[int]:
        """Distribute S_global evenly over nodes (Alg. 2 line 1).

        ``sampling``: None (greedy), one SamplingParams broadcast to every
        sequence, or one per sequence.  The params ride the coroutine, so
        every later COMBINE/MIGRATE/PARTITION keeps them with it.
        ``logprobs`` / ``top_logprobs`` (scalar or per-sequence) request
        the chosen-token logprob (and the top-K alternatives) for every
        generated token — computed on device inside the fused megastep and
        returned through the same single per-page transfer.

        ``n`` > 1 fans each prompt out into n forked siblings
        (``prim.fork``): the whole group lands on one node, the engine
        prefills the prompt ONCE and every sibling shares the prompt's KV
        span copy-on-write.  Per-sequence lists (``max_out``, ``sampling``,
        ...) may be given per prompt (broadcast over the group) or per
        sibling (length ``len(prompts) * n``).  With ``seed=None`` each
        sibling streams off its own seq_id (PR 2 token-addressable
        seeding), so the fan-out is bitwise-identical to n independent
        submissions; an explicit group-level seed is split per sibling via
        ``derive_fork_seed`` so forks actually diverge."""
        if n < 1:
            raise ValueError(f"n must be >= 1, got {n}")
        n_groups = len(prompts)
        total = n_groups * n
        group_sampling = True       # sampling given per prompt, not sibling
        if sampling is None or isinstance(sampling, SamplingParams):
            sps = [sampling or SamplingParams()] * total
        else:
            sps = list(sampling)
            if len(sps) == n_groups and n > 1:
                sps = [sp for sp in sps for _ in range(n)]
            else:
                group_sampling = n == 1
            if len(sps) != total:
                raise ValueError(
                    f"sampling list length {len(sps)} != {total} sequences")
        mos = list(max_out)
        if len(mos) == n_groups and n > 1:
            mos = [mo for mo in mos for _ in range(n)]
        if len(mos) != total:
            raise ValueError(
                f"max_out list length {len(mos)} != {total} sequences")
        lps = self._broadcast(logprobs, n_groups, n, "logprobs")
        tlps = self._broadcast(top_logprobs, n_groups, n, "top_logprobs")
        ids = []
        for g, p in enumerate(prompts):
            base = g * n
            lead = SequenceCoroutine(
                seq_id=self._next_id, prompt=list(p), max_out=int(mos[base]),
                sampling=sps[base],
                logprobs=bool(lps[base]) or int(tlps[base]) > 0,
                top_logprobs=int(tlps[base]))
            lead.node = self.engines[g % len(self.engines)].node_id
            if n > 1:
                lead.fork_group = lead.seq_id
            self.cos[lead.seq_id] = lead
            ids.append(lead.seq_id)
            self._next_id += 1
            for k in range(1, n):
                j = base + k
                sp = sps[j]
                if group_sampling and sp.seed is not None:
                    sp = dataclasses.replace(
                        sp, seed=derive_fork_seed(sp.seed, k))
                sib = prim.fork(lead, self._next_id, sampling=sp)
                sib.max_out = int(mos[j])
                sib.logprobs = bool(lps[j]) or int(tlps[j]) > 0
                sib.top_logprobs = int(tlps[j])
                self.cos[sib.seq_id] = sib
                ids.append(sib.seq_id)
                self._next_id += 1
                self.emit(PrimitiveEvent(sib.seq_id, lead.node,
                                         primitive="fork",
                                         detail=lead.seq_id))
        return ids

    @staticmethod
    def _broadcast(val, n_groups: int, n: int, name: str) -> List:
        total = n_groups * n
        if isinstance(val, (bool, int)):
            return [val] * total
        vals = list(val)
        if len(vals) == n_groups and n > 1:
            vals = [v for v in vals for _ in range(n)]
        if len(vals) != total:
            raise ValueError(f"{name} list length {len(vals)} != {total}")
        return vals

    def retire(self, seq_id: int) -> bool:
        """Drop one DONE coroutine from the pool.  A streaming driver that
        feeds a scheduler hundreds of thousands of requests over its
        lifetime must not let ``cos`` (and every ``pending()`` scan over
        it) grow with the whole job — a finished sequence whose result has
        been consumed carries no further scheduling state.  Refuses (and
        returns False) for live sequences."""
        co = self.cos.get(seq_id)
        if co is None or not co.done:
            return False
        del self.cos[seq_id]
        # normally SEQ_DONE already dropped the host state (releasing any
        # shared-prefix span reference); this sweep guarantees the release
        # for teardown paths that skipped it
        for e in self._all_engines:
            store = getattr(e, "host_store", None)
            if store is not None and store.has(seq_id):
                store.drop(seq_id)
        self.retired += 1
        return True

    def pending(self, node: int, status: Status) -> List[SequenceCoroutine]:
        return [c for c in self.cos.values()
                if c.node == node and c.status == status and not c.done]

    def all_done(self) -> bool:
        return all(c.done for c in self.cos.values())

    def engine(self, node: int):
        for e in self.engines:
            if e.node_id == node:
                return e
        return None

    # ------------------------------------------------------- stream records
    def emit(self, rec: RuntimeRecord) -> None:
        """Handlers publish stream records here; ``events()`` yields them
        in emission order after each dispatched event."""
        self._outbox.append(rec)

    def emit_token_block(self, co: SequenceCoroutine, offset: int) -> None:
        """Emit the tokens (and logprobs) ``co`` gained since ``offset``."""
        if len(co.generated) <= offset:
            return
        lps = tops = None
        if co.logprobs:
            lps = [float(x) for x in co.token_logprobs[offset:]]
            if co.top_logprobs:
                tops = [list(row) for row in co.top_token_logprobs[offset:]]
        self.emit(TokenBlockEvent(co.seq_id, co.node,
                                  tokens=list(co.generated[offset:]),
                                  offset=offset, logprobs=lps,
                                  top_logprobs=tops))

    # ------------------------------------------------------------ event core
    def dispatch(self, ev: Event) -> List[RuntimeRecord]:
        """Run the policy handler for one event; returns records emitted."""
        handler = self._handlers.get(ev.kind)
        if handler is None:
            raise KeyError(f"no handler registered for {ev.kind!r}")
        handler(self, ev)
        out, self._outbox = self._outbox, []
        return out

    def _seed_round(self) -> None:
        """Enqueue one round of per-node work: a round-seeding REFILL per
        node (whose handler chains the node's MODULE_READY decode) and one
        MIGRATE rebalance check."""
        for e in list(self.engines):
            self.queue.push(EventKind.REFILL, e.node_id, payload=_TICK)
        if len(self.engines) > 1:
            self.queue.push(EventKind.MIGRATE)

    def _advance_faults(self) -> None:
        """Arm every engine's injected faults scheduled at this round —
        the event boundary the FaultPlan is keyed to."""
        for e in list(self.engines):
            f = getattr(e, "faults", None)
            if f is not None:
                f.advance(self.ticks)

    def _collect_heartbeats(self) -> None:
        """Once per round: every engine in rotation reports to the health
        monitor (§5.6).  A missing beat (dead/suppressed node) counts a
        miss; ``dead_after`` consecutive misses fire ``_on_health_failure``
        which enqueues NODE_FAILURE itself.  Collection never dispatches —
        the failure event rides the normal priority drain.

        The same beats feed the ``ProgressTracker``: a beat that still
        ARRIVES but shows lagging progress raises NODE_SLOW (shedding),
        never NODE_FAILURE — slow is not dead."""
        mitigate = self.cfg.mitigate_stragglers
        for e in list(self.engines):
            if e not in self._all_engines:
                self._all_engines.append(e)     # elastic scale-up
            self.health.ensure_node(e.node_id)
            if self.health.failed[e.node_id]:
                continue
            hb = e.heartbeat()
            if hb is None:
                self.health.miss(e.node_id)
            else:
                self.health.report(hb)
                if mitigate:
                    self.progress.observe(hb)
        if not mitigate:
            return
        for node in self.progress.evaluate(
                self.ticks, [e.node_id for e in self.engines]):
            self.log.append(f"slow_flag node={node} "
                            f"rate={self.progress.rate(node):.1f}")
            self.emit(HealthEvent(-1, node, reason="slow",
                                  detail=self.progress.rate(node)))
            self.queue.push(EventKind.NODE_SLOW, node, payload="progress")
        self._sweep_hedges()

    def _on_health_failure(self, node: int) -> None:
        """HealthMonitor callback: a node stopped heartbeating — escalate
        to the §5.6 NODE_FAILURE recovery path."""
        self.health_failovers += 1
        self.log.append(f"health_failure node={node}")
        self.emit(HealthEvent(-1, node, reason="heartbeat",
                              detail="missed heartbeats"))
        self.queue.push(EventKind.NODE_FAILURE, node, payload="health")

    # -------------------------------------------- memory-pressure governor
    def _preempt(self, co: SequenceCoroutine, eng, detail: str) -> None:
        """One governor preemption: YIELD (checkpoint → host store → free
        device pages), account the spilled bytes, and mark the sequence
        for low-watermark re-admission.  Callers drain the engine's
        append pipeline first."""
        b0 = eng.stats.bytes_moved["yield"]
        prim.yield_(co, eng)
        spilled = eng.stats.bytes_moved["yield"] - b0
        if spilled == 0:
            # SimEngine checkpoints metadata only — account the modeled
            # KV footprint instead
            spilled = int(getattr(eng, "kv_bytes_per_token", 0) * co.length)
        self.gov_preempts += 1
        self.gov_host_spill_bytes += spilled
        self._preempted.add(co.seq_id)
        self.log.append(f"yield({detail}) seq={co.seq_id} "
                        f"occ={eng.allocator.occupancy:.2f}")
        self.emit(PrimitiveEvent(co.seq_id, co.node, primitive="yield",
                                 detail=detail))

    # -------------------------------------------- deadlines + hedged tails
    def _check_deadlines(self, node: int) -> None:
        """Graceful degradation: mark sequences past their per-request
        ``deadline_s`` (wall clock since submit) as deadlined — their
        ``remaining`` collapses to 0 and the normal SEQ_DONE eviction
        finishes them with ``finish_reason="deadline"``.  A sequence that
        has not produced a single token yet is spared: the deadline
        truncates output, it never returns an empty success."""
        now = time.monotonic()
        for co in self.cos.values():
            if (co.node != node or co.done or co.deadlined or co.stopped
                    or not co.generated):
                continue
            dl = co.sampling.deadline_s
            if dl is not None and now - co.submitted_t >= dl:
                co.deadlined = True
                self.log.append(f"deadline seq={co.seq_id} "
                                f"n={len(co.generated)}")

    def _sweep_hedges(self) -> None:
        """Launch speculative clones for sequences stuck on a node that
        has stayed slow-flagged past ``hedge_deadline_s`` on its own
        clock.  The clone restarts from the prompt on a fast node with
        the original's token-addressable seed pinned, so both race toward
        the SAME token stream — whichever finishes first wins through
        ``_resolve_hedge`` and the loser is cancelled."""
        cfg = self.cfg
        for node in list(self._slow_since):
            eng = self.engine(node)
            if eng is None or not self.progress.is_flagged(node):
                self._slow_since.pop(node, None)    # recovered or gone
                continue
            if eng.clock() - self._slow_since[node] < cfg.hedge_deadline_s:
                continue
            fast = [e for e in self.engines if e.node_id != node
                    and not self.progress.is_flagged(e.node_id)]
            if not fast:
                continue

            def load(e):
                return sum(1 for c in self.cos.values()
                           if c.node == e.node_id and not c.done)

            for co in [c for c in self.cos.values()
                       if c.node == node and not c.done
                       and c.remaining > 0]:
                if (co.seq_id in self.hedged
                        or co.seq_id in self.hedge_origin):
                    continue        # already hedged / is itself a clone
                dst = max(fast, key=lambda e: self.progress.rate(e.node_id)
                          / (1.0 + load(e)))
                self._launch_hedge(co, dst)

    def _launch_hedge(self, co: SequenceCoroutine, dst) -> None:
        sp = co.sampling
        if sp.seed is None:
            # pin the clone to the original's token-addressable stream:
            # with seed=None each stream keys off its own seq_id, and the
            # clone has a different one
            sp = dataclasses.replace(sp, seed=sp.effective_seed(co.seq_id))
        clone = SequenceCoroutine(
            seq_id=self._next_id, prompt=list(co.prompt),
            max_out=co.max_out, sampling=sp, logprobs=co.logprobs,
            top_logprobs=co.top_logprobs, node=dst.node_id)
        self._next_id += 1
        self.cos[clone.seq_id] = clone
        self.hedge_origin[clone.seq_id] = co.seq_id
        self.hedged[co.seq_id] = clone.seq_id
        self.hedges_launched += 1
        self.log.append(f"hedge seq={co.seq_id} clone={clone.seq_id} "
                        f"-> node={dst.node_id}")
        self.emit(PrimitiveEvent(clone.seq_id, dst.node_id,
                                 primitive="hedge", detail=co.seq_id))

    def _resolve_hedge(self, co: SequenceCoroutine
                       ) -> Optional[SequenceCoroutine]:
        """Called for every finishing sequence: returns the coroutine
        whose SeqFinishedEvent should surface, or None to suppress.

        A finishing CLONE transplants its (bitwise-identical) result into
        the original — BatchMaster/ledger only know the original's seq_id,
        and the ledger's first-wins journal then dedupes exactly as for
        any other finish.  A finishing ORIGINAL cancels its live clone."""
        orig_id = self.hedge_origin.get(co.seq_id)
        if orig_id is not None:             # a clone crossed the line first
            self.hedged.pop(orig_id, None)
            orig = self.cos.get(orig_id)
            if orig is None or orig.done:
                # original already surfaced (or was cancelled upstream):
                # the clone's output is a duplicate — swallow it
                self._drop_hedge_clone(co)
                return None
            before = len(orig.generated)
            orig.generated = list(co.generated)
            orig.token_logprobs = list(co.token_logprobs)
            orig.top_token_logprobs = [list(r)
                                       for r in co.top_token_logprobs]
            orig.stopped = co.stopped
            orig.deadlined = co.deadlined
            self._release_residency(orig)
            orig.node = co.node
            orig.length = len(orig.prompt) + len(orig.generated)
            orig.finish()
            # the clone streamed under its own seq_id (ignored by batch
            # consumers); re-emit the original's missing tail so ITS
            # stream is complete before the finish record
            self.emit_token_block(orig, before)
            self.hedges_won += 1
            self.log.append(f"hedge win clone={co.seq_id} orig={orig_id}")
            self._drop_hedge_clone(co)
            return orig
        clone_id = self.hedged.pop(co.seq_id, None)
        if clone_id is not None:            # original beat its hedge
            clone = self.cos.get(clone_id)
            if clone is not None and not clone.done:
                self._cancel_clone(clone)
            self.hedges_lost += 1
        return co

    def _release_residency(self, co: SequenceCoroutine) -> None:
        """Free a losing racer's device slot, pages, and host checkpoint
        on its current node (tolerates a node that already left
        rotation)."""
        eng = self.engine(co.node)
        if eng is not None:
            if co.status == Status.ACTIVE:
                eng.drain_appends()
            eng.allocator.free_seq(co.seq_id)
            eng.free_slot(co)
            discard = getattr(eng, "discard_restore", None)
            if callable(discard):
                discard(co.seq_id)      # a staged h2d prefetch is now moot
            if eng.host_store.has(co.seq_id):
                eng.host_store.drop(co.seq_id)
        co.slot = None
        co.partition_group = None

    def _cancel_clone(self, clone: SequenceCoroutine) -> None:
        self._release_residency(clone)
        clone.stopped = True
        clone.status = Status.DONE
        self.log.append(f"hedge cancel clone={clone.seq_id}")
        self._drop_hedge_clone(clone)

    def _drop_hedge_clone(self, clone: SequenceCoroutine) -> None:
        """Retire a resolved clone immediately — clones never linger in
        the pool (and are excluded from report() counts via the
        ``hedges_resolved`` ledger)."""
        if not clone.done:
            clone.status = Status.DONE
        self.hedge_origin.pop(clone.seq_id, None)
        self.hedges_resolved += 1
        self.retire(clone.seq_id)

    def _escalate_dead_letters(self) -> Iterator[RuntimeRecord]:
        """A transfer exhausted its retry budget during the last dispatch:
        escalate the owning node to NODE_FAILURE IMMEDIATELY (inline
        dispatch, not a queue push) — a node with a corrupt slot or a lost
        KV blob must not decode another page, or a garbage sequence could
        hit a stop token and finish before a queued low-priority
        NODE_FAILURE gets dispatched."""
        for e in list(self.engines):
            if getattr(e, "dead_lettered", False):
                e.dead_lettered = False
                self.dead_letter_failovers += 1
                self.health.mark_failed(e.node_id)
                self.log.append(f"dead_letter node={e.node_id}")
                self.emit(HealthEvent(-1, e.node_id, reason="dead_letter",
                                      detail=dict(e.transfer_stats)))
                yield from self.dispatch(Event(kind=EventKind.NODE_FAILURE,
                                               node=e.node_id,
                                               payload="dead_letter"))

    def _drain_queue(self) -> Iterator[RuntimeRecord]:
        while self.queue:
            yield from self.dispatch(self.queue.pop())
            yield from self._escalate_dead_letters()

    def _step_events(self) -> Iterator[RuntimeRecord]:
        if self._t0 is None:
            self._t0 = min((e.clock() for e in self.engines), default=0.0)
        self._advance_faults()
        self._collect_heartbeats()
        # Externally-pushed events (NODE_FAILURE from a health monitor,
        # custom policy work) drain BEFORE this round's work is seeded —
        # a failed node must not be refilled/decoded one last time just
        # because NODE_FAILURE's dispatch priority trails the others.
        yield from self._drain_queue()
        self._seed_round()
        yield from self._drain_queue()
        self.ticks += 1

    def step(self) -> List[RuntimeRecord]:
        """One scheduler round: seed per-node work, then drain the event
        queue in priority order.  Returns the records emitted."""
        return list(self._step_events())

    def events(self, max_ticks: int = 100000) -> Iterator[RuntimeRecord]:
        """Core generator: run rounds until batch completion (or the tick
        budget), yielding typed records as handlers emit them."""
        start = self.ticks
        while not self.all_done() and self.ticks - start < max_ticks:
            yield from self._step_events()
        if not self.all_done():
            done = sum(c.done for c in self.cos.values())
            logger.warning(
                "scheduler exhausted max_ticks=%d with %d/%d sequences "
                "unfinished — results are truncated", max_ticks,
                len(self.cos) - done, len(self.cos))

    def stream(self, max_ticks: int = 100000,
               kinds: Union[None, Type[RuntimeRecord],
                            Tuple[Type[RuntimeRecord], ...]] = None
               ) -> Iterator[RuntimeRecord]:
        """Stream-first result surface: yields ``TokenBlockEvent`` /
        ``SeqFinishedEvent`` / ``PrimitiveEvent`` records as pages
        complete.  ``kinds`` filters to the given record type(s).  New
        sequences may be submitted while the stream is live; the next
        round's REFILL picks them up."""
        for rec in self.events(max_ticks):
            if kinds is None or isinstance(rec, kinds):
                yield rec

    # ------------------------------------------------------------- main loop
    def run(self, max_ticks: int = 100000) -> Dict:
        """Run until batch completion; returns BCT stats.  Thin wrapper
        over ``events()`` — identical token output to consuming
        ``stream()`` yourself."""
        self._t0 = None                  # fresh BCT window per run() call
        for _ in self.events(max_ticks):
            pass
        return self.report()

    def _node_tick(self, node: int, eng=None) -> List[RuntimeRecord]:
        """Compat shim (tests/tools): one node's full
        refill -> decode -> page-boundary cycle through the event queue."""
        self.queue.push(EventKind.REFILL, node, payload=_TICK)
        return list(self._drain_queue())

    # ------------------------------------------------------------- reporting
    def report(self) -> Dict:
        """Current batch report.  ``status`` is derived from live state —
        "completed" only when every sequence is done, "exhausted" for any
        truncation (max_ticks hit OR an abandoned stream), so a normal-
        looking report can't hide unfinished sequences."""
        t1 = max((e.clock() for e in self.engines), default=0.0)
        t0 = self._t0 if self._t0 is not None else t1
        # hedge clones are speculative duplicates, not workload: exclude
        # live ones from the pool counts and retired ones from `retired`
        clones = set(self.hedge_origin)
        scts = [c.sct() for i, c in self.cos.items()
                if c.sct() is not None and i not in clones]
        stats = {}
        for i, e in enumerate(self.engines):
            stats[f"node{i}"] = {"counts": dict(e.stats.counts),
                                 "bytes": dict(e.stats.bytes_moved)}
        xfer = {"retries": 0, "timeouts": 0, "dead_letters": 0}
        for e in self._all_engines:
            for k in xfer:
                xfer[k] += getattr(e, "transfer_stats", {}).get(k, 0)
        prefix = {"hits": 0, "hit_tokens": 0, "inserted_pages": 0,
                  "evicted_pages": 0, "cow_copies": 0, "live_refs": 0,
                  "prefill_tokens_saved": 0}
        for e in self._all_engines:
            store = getattr(e, "host_store", None)
            if store is not None:
                prefix["cow_copies"] += getattr(store, "cow_copies", 0)
                idx = getattr(store, "prefix_index", None)
                if idx is not None:
                    for k in ("hits", "hit_tokens", "inserted_pages",
                              "evicted_pages"):
                        prefix[k] += idx.stats[k]
                    prefix["live_refs"] += idx.live_refs()
            prefix["prefill_tokens_saved"] += getattr(
                e, "prefill_tokens_saved", 0)
        governor = {
            "preempts": self.gov_preempts,
            "restores": self.gov_restores,
            "host_spill_bytes": self.gov_host_spill_bytes,
            "restore_stages": 0,
            "restore_stalls": 0,
            "restore_wait_s": 0.0,
            "restore_stage_hidden_s": 0.0,
            "budget_evictions": 0,
        }
        for e in self._all_engines:
            governor["restore_stages"] += getattr(e, "restore_stages", 0)
            governor["restore_stalls"] += getattr(e, "restore_stalls", 0)
            governor["restore_wait_s"] += getattr(e, "restore_wait_s", 0.0)
            governor["restore_stage_hidden_s"] += getattr(
                e, "restore_stage_hidden_s", 0.0)
            store = getattr(e, "host_store", None)
            if store is not None:
                governor["budget_evictions"] += getattr(
                    store, "budget_evictions", 0)
        robustness = {
            "health_failovers": self.health_failovers,
            "dead_letter_failovers": self.dead_letter_failovers,
            "failed_nodes": sorted(n for n, f in self.health.failed.items()
                                   if f),
            "drained_nodes": list(self.drained_nodes),
            "transfer": xfer,
            "slow_flags": self.progress.flags_raised,
            "slow_recoveries": self.progress.flags_cleared,
            "sheds": self.sheds,
            "shed_migrations": self.shed_moved,
            "hedges": {"launched": self.hedges_launched,
                       "won": self.hedges_won,
                       "lost": self.hedges_lost},
            "governor": governor,
        }
        return {
            "bct_s": t1 - t0,
            "ticks": self.ticks,
            "status": "completed" if self.all_done() else "exhausted",
            "completed": (sum(c.done for i, c in self.cos.items()
                              if i not in clones)
                          + self.retired - self.hedges_resolved),
            "total": (len(self.cos) - len(clones)
                      + self.retired - self.hedges_resolved),
            "mean_sct_s": sum(scts) / len(scts) if scts else 0.0,
            "primitives": stats,
            "prefix": prefix,
            "robustness": robustness,
            "log_tail": self.log[-20:],
        }
