"""Event-driven coroutine scheduler: Algorithm 2 + §5.3 dynamic sequence
management.

The scheduler is generic over "engines" (one per node) implementing the
slot protocol (see primitives.py).  Both the real mini-engine
(runtime/engine.py — actually executes a JAX model on CPU) and the cluster
simulator (runtime/cluster.py — virtual clocks from the §5.4 performance
model) plug in here, so the scheduling logic benchmarked at 128 GPUs is the
same code that decodes real tokens in the examples.

Loop structure per decode *page* (P tokens, §5.3):
  i.   Sync      — flush pending async KV appends (host = source of truth)
  ii.  Eviction  — YIELD finished sequences, release pages
  iii. Extension — extend page allocation or YIELD (most-progress-first)
  iv.  Refill    — COMBINE waiting sequences into the active batch

Page-block contract (fused decode): ``engine.decode_page`` executes the
whole page as one fused device program capped at ``min(P, max remaining)``
steps (the on-device done mask absorbs mid-page finishes — that cap IS the
early page exit) and applies the returned ``(P, max_active)`` token block
to the coroutines before returning.  The page-boundary phases below
therefore see fully updated coroutine state and ``sync_appends`` moves the
block's KV to the host store with one batched gather per page.
Callbacks:
  ON_REFILL_NODE — trigger prefill when decode under-fills the node
  ON_LONG_TAIL   — PARTITION stragglers over idle devices
  MIGRATE        — rebalance suspended sequences across nodes (FIFO)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

from repro.core import primitives as prim
from repro.core.coroutine import Phase, SequenceCoroutine, Status
from repro.core.events import EventKind, EventQueue
from repro.sampling.params import SamplingParams


@dataclasses.dataclass
class SchedulerConfig:
    page_size: int = 64              # P — decode tokens between checks
    refill_threshold: float = 0.75   # refill when active < thr * slots
    longtail_active: int = 2         # ON_LONG_TAIL when active <= this
    longtail_min_remaining: int = 64
    migrate_imbalance: int = 2       # min queue difference to migrate
    max_partition_group: int = 8


class CoroutineScheduler:
    def __init__(self, engines: Sequence, config: SchedulerConfig = None):
        self.engines = list(engines)
        self.cfg = config or SchedulerConfig()
        self.queue = EventQueue()
        self.cos: Dict[int, SequenceCoroutine] = {}
        self._next_id = 0
        self.log: List[str] = []

    # ------------------------------------------------------------------ API
    def submit(self, prompts: Sequence[Sequence[int]],
               max_out: Sequence[int],
               sampling: Union[None, SamplingParams,
                               Sequence[SamplingParams]] = None
               ) -> List[int]:
        """Distribute S_global evenly over nodes (Alg. 2 line 1).

        ``sampling``: None (greedy), one SamplingParams broadcast to every
        sequence, or one per sequence.  The params ride the coroutine, so
        every later COMBINE/MIGRATE/PARTITION keeps them with it."""
        if sampling is None or isinstance(sampling, SamplingParams):
            sps = [sampling or SamplingParams()] * len(prompts)
        else:
            sps = list(sampling)
            if len(sps) != len(prompts):
                raise ValueError(
                    f"sampling list length {len(sps)} != "
                    f"{len(prompts)} prompts")
        ids = []
        for i, (p, mo, sp) in enumerate(zip(prompts, max_out, sps)):
            co = SequenceCoroutine(seq_id=self._next_id, prompt=list(p),
                                   max_out=int(mo), sampling=sp)
            co.node = self.engines[i % len(self.engines)].node_id
            self.cos[co.seq_id] = co
            ids.append(co.seq_id)
            self._next_id += 1
        return ids

    def pending(self, node: int, status: Status) -> List[SequenceCoroutine]:
        return [c for c in self.cos.values()
                if c.node == node and c.status == status and not c.done]

    def all_done(self) -> bool:
        return all(c.done for c in self.cos.values())

    # ------------------------------------------------------------- main loop
    def run(self, max_ticks: int = 100000) -> Dict:
        """Run until batch completion; returns BCT stats."""
        t0 = min(e.clock() for e in self.engines)
        ticks = 0
        while not self.all_done() and ticks < max_ticks:
            for eng in self.engines:
                self._node_tick(eng.node_id, eng)
            self._global_balance()
            ticks += 1
        t1 = max(e.clock() for e in self.engines)
        return self._report(t1 - t0, ticks)

    # ------------------------------------------------------------ node logic
    def _node_tick(self, node: int, eng):
        active = [c for c in self.cos.values()
                  if c.node == node and c.status == Status.ACTIVE]
        # ON_REFILL_NODE: prefill when under-filled (Alg. 2 lines 7-11)
        if len(active) < self.cfg.refill_threshold * eng.max_active:
            self._refill(node, eng)
            active = [c for c in self.cos.values()
                      if c.node == node and c.status == Status.ACTIVE]
        if not active:
            eng.idle_tick()
            return
        # decode one page of tokens (P steps), then page-boundary phases
        eng.decode_page(active, self.cfg.page_size)
        self._page_boundary(node, eng, active)

    def _page_boundary(self, node: int, eng, active):
        # (i) Sync — async KV appends -> host store
        eng.sync_appends(active)
        # (ii) Eviction — finished sequences release device + host pages
        for co in list(active):
            if co.remaining == 0:
                eng.allocator.free_seq(co.seq_id)
                eng.free_slot(co)
                co.slot = None
                eng.host_store.drop(co.seq_id)
                co.finish()
        active = [c for c in active if not c.done]
        # (iii) Extension — two-page reservation; evict most-progress-first
        lengths = {c.seq_id: c.length for c in active}
        for victim_id in eng.allocator.ensure_two_pages(lengths):
            co = self.cos[victim_id]
            if co.status == Status.ACTIVE:
                prim.yield_(co, eng)
                self.log.append(f"yield(evict) seq={victim_id}")
        for co in active:
            if not co.done and co.status == Status.ACTIVE:
                eng.allocator.alloc(co.seq_id, 1)
        # (iv) Refill — COMBINE suspended/prefilled sequences
        self._refill(node, eng)
        # ON_LONG_TAIL (Alg. 2 lines 12-14)
        self._check_longtail(node, eng)

    def _refill(self, node: int, eng):
        waiting = self.pending(node, Status.INACTIVE)
        if waiting:
            waiting.sort(key=lambda c: c.submitted_t)     # FIFO fairness
            prim.combine(waiting, eng)
        # prefill new sequences if slots remain
        inits = self.pending(node, Status.INIT)
        if inits:
            free_slots = eng.max_active - len(
                [c for c in self.cos.values()
                 if c.node == node and c.status == Status.ACTIVE])
            if free_slots > 0:
                batch = inits[: max(free_slots, 0)]
                if batch:
                    eng.prefill(batch)          # leaves them INACTIVE on host
                    prim.combine(batch, eng)

    def _check_longtail(self, node: int, eng):
        # only THIS node's live sequences: a busy neighbour node must not
        # suppress PARTITION for a node that is already down to stragglers
        live = [c for c in self.cos.values()
                if c.node == node and not c.done]
        active = [c for c in live if c.status == Status.ACTIVE]
        others = [c for c in live if c.status != Status.ACTIVE]
        if (len(active) <= self.cfg.longtail_active and not others
                and active
                and max(c.remaining for c in active)
                >= self.cfg.longtail_min_remaining
                and not any(c.partition_group for c in active)):
            # wait for yield (checkpoint), then PARTITION over idle devices
            group = list(range(min(eng.num_devices,
                                   self.cfg.max_partition_group)))
            for co in sorted(active, key=lambda c: -c.remaining):
                prim.yield_(co, eng)
                prim.partition(co, eng, group)
                self.log.append(
                    f"partition seq={co.seq_id} group={len(group)}")
                prim.combine([co], eng)
                break

    # ----------------------------------------------------------- migration
    def _global_balance(self):
        if len(self.engines) < 2:
            return
        nids = [e.node_id for e in self.engines]
        loads = {n: len(self.pending(n, Status.INACTIVE))
                 + len(self.pending(n, Status.INIT)) for n in nids}
        hi = max(nids, key=loads.__getitem__)
        lo = min(nids, key=loads.__getitem__)
        if loads[hi] - loads[lo] >= self.cfg.migrate_imbalance:
            movable = (self.pending(hi, Status.INACTIVE)
                       or self.pending(hi, Status.INIT))
            if movable:
                co = movable[0]
                by_id = {e.node_id: e for e in self.engines}
                prim.migrate(co, by_id[hi], by_id[lo])
                self.log.append(f"migrate seq={co.seq_id} {hi}->{lo}")

    # ------------------------------------------------------------- reporting
    def _report(self, bct: float, ticks: int) -> Dict:
        scts = [c.sct() for c in self.cos.values() if c.sct() is not None]
        stats = {}
        for i, e in enumerate(self.engines):
            stats[f"node{i}"] = {"counts": dict(e.stats.counts),
                                 "bytes": dict(e.stats.bytes_moved)}
        return {
            "bct_s": bct,
            "ticks": ticks,
            "completed": sum(c.done for c in self.cos.values()),
            "total": len(self.cos),
            "mean_sct_s": sum(scts) / len(scts) if scts else 0.0,
            "primitives": stats,
            "log_tail": self.log[-20:],
        }
