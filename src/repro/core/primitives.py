"""The four coroutine primitives: YIELD / COMBINE / PARTITION / MIGRATE.

These are engine-agnostic: any object implementing the formal
``ExecutionBackend`` protocol (core/backend.py — extract_slot /
install_slot / free_slot / ..., .host_store, .allocator, .stats) can host
coroutines — the real mini-engine (runtime/engine.py) and the cluster
simulator (runtime/cluster.py) both declare conformance.

Semantics (paper §4.2):
* yield_  — suspend at a module boundary: checkpoint state to the host
            store, release the device slot, mark INACTIVE.  Control returns
            to the scheduler.
* combine — merge inactive coroutines into the active batch; resume is
            implicit (there is no separate resume primitive).
* partition — split one straggler's computation across a device group
            (TP for a single sequence, DP for several); requires the
            coroutine to have yielded first so its state is checkpointed.
* migrate — move a coroutine's host-resident state to another node.
* fork    — clone a submitted coroutine into a sibling that shares the
            prompt (and, once prefilled, the prompt's KV span pages
            copy-on-write); siblings diverge at their first sampled token.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import numpy as np

from repro.core.coroutine import Phase, SequenceCoroutine, Status


class PrimitiveStats:
    def __init__(self):
        self.counts = {"yield": 0, "combine": 0, "partition": 0,
                       "migrate": 0, "fork": 0}
        self.seconds = {k: 0.0 for k in self.counts}
        self.bytes_moved = {"yield": 0, "combine": 0, "migrate": 0}

    def record(self, kind: str, dt: float, nbytes: int = 0):
        self.counts[kind] += 1
        self.seconds[kind] += dt
        if kind in self.bytes_moved:
            self.bytes_moved[kind] += nbytes


def yield_(co: SequenceCoroutine, engine, *, keep_device: bool = False) -> None:
    """Suspend `co`: checkpoint its device state to the host store and free
    the slot.  With keep_device=True only the metadata transition happens
    (intra-forward yield: hidden states stay on device per Alg. 1)."""
    assert co.status == Status.ACTIVE, co.status
    t0 = time.monotonic()
    nbytes = 0
    if not keep_device and co.slot is not None:
        slices = engine.extract_slot(co)
        nbytes = sum(int(np.asarray(v).nbytes) for v in slices.values())
        engine.host_store.checkpoint(co.seq_id, slices, co.length)
        engine.allocator.free_seq(co.seq_id)
        engine.free_slot(co)
        co.slot = None
    co.status = Status.INACTIVE
    co.yields += 1
    co.fire("on_yield", None)
    engine.stats.record("yield", time.monotonic() - t0, nbytes)


def combine(cos: Sequence[SequenceCoroutine], engine, *,
            handoff: bool = False) -> List[SequenceCoroutine]:
    """Resume-by-combination: restore each coroutine's state into a free
    device slot and mark ACTIVE.  Returns the coroutines that were actually
    admitted (slot/page budget permitting).

    A host→device restore staged earlier through the ring buffer
    (``engine.stage_restore``, the h2d mirror of the d2h sync pipeline) is
    consumed via ``engine.take_restore`` — its PCIe copy already rode
    behind a decode page, so the install here pays no transfer wait.
    ``handoff=True`` marks the prefill→decode handoff (the sequence was
    never spilled mid-flight): it installs directly without touching the
    restore pipeline or its wait accounting."""
    admitted = []
    t0 = time.monotonic()
    nbytes = 0
    take = None if handoff else getattr(engine, "take_restore", None)
    for co in cos:
        if co.status not in (Status.INACTIVE, Status.INIT):
            continue
        slot = engine.acquire_slot(co)
        if slot is None:
            break
        co.slot = slot
        if engine.host_store.has(co.seq_id):
            slices = take(co.seq_id) if callable(take) else None
            if slices is None:
                slices = engine.host_store.restore(co.seq_id, engine.max_len)
            nbytes += sum(int(np.asarray(v).nbytes)
                          for v in slices.values())
            engine.install_slot(co, slices)
        co.status = Status.ACTIVE
        admitted.append(co)
    engine.stats.record("combine", time.monotonic() - t0, nbytes)
    return admitted


def partition(co: SequenceCoroutine, engine, device_group: List[int]) -> None:
    """Straggler acceleration: assign `co` to a tensor-parallel device
    group.  The engine reconfigures its decode step for the group (on TPU:
    re-lower with the group mesh; KV split across heads for GQA, latent
    replicated for MLA, sequence-split otherwise — DESIGN.md §3)."""
    assert co.status == Status.INACTIVE, "partition requires a prior yield"
    t0 = time.monotonic()
    co.partition_group = list(device_group)
    engine.reconfigure_partition(co, device_group)
    co.fire("on_partition", device_group)
    engine.stats.record("partition", time.monotonic() - t0)


def fork(co: SequenceCoroutine, seq_id: int,
         sampling=None) -> SequenceCoroutine:
    """Clone a not-yet-prefilled coroutine into a fan-out sibling.

    The sibling shares the prompt; both carry the lead's seq_id as their
    ``fork_group`` so the engine prefills the prompt once and binds every
    sibling to the same span pages (COW).  Divergence comes from sampling:
    with ``seed=None`` the PR 2 token-addressable seeding keys each stream
    off its own seq_id, so fork(n) is bitwise-identical to n independent
    submissions."""
    assert co.status == Status.INIT, "fork requires a not-yet-prefilled lead"
    sib = SequenceCoroutine(
        seq_id=seq_id, prompt=list(co.prompt), max_out=co.max_out,
        max_in=co.max_in, sampling=sampling if sampling is not None
        else co.sampling, logprobs=co.logprobs,
        top_logprobs=co.top_logprobs, node=co.node)
    co.fork_group = co.fork_group if co.fork_group is not None else co.seq_id
    sib.fork_group = co.fork_group
    co.fire("on_fork", sib.seq_id)
    return sib


def migrate(co: SequenceCoroutine, src_engine, dst_engine) -> None:
    """Move host-resident state between nodes.  Asynchronous on a real
    deployment (overlapped with compute); here the copy is immediate and
    the overhead is accounted by the caller's clock model."""
    assert co.status in (Status.INACTIVE, Status.INIT)
    t0 = time.monotonic()
    # a staged-but-undrained KV blob (pipelined sync) must land before the
    # host state crosses nodes — otherwise the moved checkpoint would lag
    # the coroutine's generated tokens
    src_engine.drain_appends()
    # a restore staged toward the source's devices is now pointed at the
    # wrong node — drop it (and release its ring reservation) before the
    # state moves
    discard = getattr(src_engine, "discard_restore", None)
    if callable(discard):
        discard(co.seq_id)
    nbytes = 0
    if src_engine.host_store.has(co.seq_id):
        src_store = src_engine.host_store
        moved = {"n": src_store.seqs[co.seq_id].nbytes()}

        def _move():
            # pop + release on the source index FIRST (while prefix_node
            # still names the source chain), then adopt on the destination:
            # shared span pages cross once per span — a sibling that
            # migrated earlier makes this sequence's span free
            st = src_store.pop_state(co.seq_id)
            src_node = st.prefix_node
            moved["n"] = dst_engine.host_store.adopt(co.seq_id, st)
            if src_node is not None and src_store.prefix_index is not None:
                src_store.prefix_index.release(src_node)
        # the inter-node blob move is a guarded transfer when the backend
        # provides the envelope (retry/backoff; a dead-letter propagates —
        # the scheduler's failure handlers fall back to recompute)
        xfer = getattr(src_engine, "transfer", None)
        if callable(xfer):
            xfer("migrate", _move)
        else:
            _move()
        nbytes = moved["n"]
    co.node = dst_engine.node_id
    co.migrations += 1
    co.fire("on_migrate", dst_engine.node_id)
    src_engine.stats.record("migrate", time.monotonic() - t0, nbytes)
