"""Formal execution-backend contract for the coroutine runtime.

The scheduler is generic over "engines": anything that exposes the slot
protocol below can host sequence coroutines.  Historically the contract
was implicit (whatever ``CoroutineScheduler`` happened to call); this
module makes it a ``typing.Protocol`` so

* the real mini-engine (``runtime/engine.py``) and the virtual-clock
  cluster simulator (``runtime/cluster.py``) *declare* conformance
  (module-level ``validate_backend(cls)`` at import time), and
* ``CoroutineScheduler`` *checks* conformance at construction
  (``validate_backend(instance)``), so a backend missing one protocol
  member fails loudly with the member's name instead of mid-batch with
  an ``AttributeError``.

Contract summary (see each engine for semantics):

========================  ==================================================
member                    role
========================  ==================================================
``node_id``               stable id the scheduler routes events by
``max_active``            device slot count (refill / admission ceiling)
``num_devices``           devices per node (PARTITION group sizing)
``host_store``            paged host KV store — single source of truth
``allocator``             two-page lazy page allocator
``stats``                 ``PrimitiveStats`` (yield/combine/... accounting)
``clock()``               node time (wall clock or virtual clock)
``idle_tick()``           called when a tick finds no runnable work
``acquire_slot(co)``      bind a coroutine to a free device slot (or None)
``free_slot(co)``         release the coroutine's slot
``extract_slot(co)``      device state -> host arrays (YIELD checkpoint)
``install_slot(co, sl)``  host arrays -> device slot (COMBINE resume)
``reconfigure_partition`` re-lower decode over a device group (PARTITION)
``decode_page(act, P)``   decode up to P tokens for the active batch
``sync_appends(act)``     flush freshly decoded KV to the host store
                          (blocking: stage + drain in one call)
``stage_appends(act)``    issue the dirty-window KV gather and start the
                          async device→host copy; snapshot per-slot
                          [synced, length) metadata at issue time
``drain_appends()``       land staged blobs in the host store.  Accepts
                          ``keep_newest=n`` to leave the n most recently
                          staged blobs in flight (the SYNC_DRAIN handler
                          keeps 1 so it rides behind the next megastep);
                          every consumer of host-store state (evict,
                          migrate, failure recovery) must force a full
                          drain first
``prefill(cos)``          prefill INIT coroutines, checkpoint, leave INACTIVE
``stage_restore(co)``     issue an async host→device restore for a
                          suspended sequence through the ring buffer (the
                          h2d mirror of ``stage_appends``): the copy rides
                          behind the next decode page so a later COMBINE
                          installs without PCIe wait.  Returns True when
                          the restore is staged (already-staged counts),
                          False when it cannot be (no host state / ring
                          full — the backpressure counter increments)
``take_restore(id)``      consume a staged restore for COMBINE: returns
                          the host slices (staleness-checked against the
                          current host state — a checkpoint that advanced
                          since staging invalidates the prefetch) or the
                          synchronously-restored slices when nothing
                          usable was staged; None only without host state
``discard_restore(id)``   drop one staged restore + release its ring
                          reservation (MIGRATE: the state changes nodes)
``discard_restores()``    drop every staged restore (NODE_FAILURE: the
                          target devices are gone)
``heartbeat()``           emit this round's ``Heartbeat`` (or None when the
                          node is dead / its beat is suppressed) — the
                          scheduler feeds it to the ``HealthMonitor`` every
                          round (§5.6); the beat carries the cumulative
                          progress counters below for the
                          ``ProgressTracker``'s straggler detection
``transfer(kind, fn)``    run one risky host transfer (stage/drain/install/
                          migrate) through the fault injector + bounded
                          exponential-backoff retry envelope; raises
                          ``TransferDeadLetter`` after the retry budget
``faults``                per-node ``NodeFaults`` view (None = no injection)
``retry_policy``          ``RetryPolicy`` governing ``transfer``
``transfer_stats``        dict: retries / timeouts / dead_letters counters
``dead_lettered``         flag the scheduler polls after every dispatch to
                          escalate a dead-lettered node to NODE_FAILURE
``decode_steps``          cumulative decode steps run (heartbeat progress)
``tokens_out``            cumulative effective tokens emitted — per-node
                          EWMA throughput = Δtokens_out / Δclock()
========================  ==================================================
"""
from __future__ import annotations

from typing import (Any, Dict, List, Optional, Protocol, Sequence,
                    runtime_checkable)

PROTOCOL_METHODS = (
    "clock", "idle_tick", "acquire_slot", "free_slot", "extract_slot",
    "install_slot", "reconfigure_partition", "decode_page", "sync_appends",
    "stage_appends", "drain_appends", "prefill", "heartbeat", "transfer",
    "stage_restore", "take_restore", "discard_restore", "discard_restores",
)
PROTOCOL_ATTRS = (
    "node_id", "max_active", "num_devices", "host_store", "allocator",
    "stats", "faults", "retry_policy", "transfer_stats", "dead_lettered",
    "decode_steps", "tokens_out",
)


@runtime_checkable
class ExecutionBackend(Protocol):
    """The slot protocol every engine must implement (see module doc)."""

    node_id: int
    max_active: int
    num_devices: int
    host_store: Any
    allocator: Any
    stats: Any
    faults: Any
    retry_policy: Any
    transfer_stats: Dict[str, int]
    dead_lettered: bool
    decode_steps: int
    tokens_out: float

    def clock(self) -> float: ...

    def idle_tick(self) -> None: ...

    def acquire_slot(self, co) -> Optional[int]: ...

    def free_slot(self, co) -> None: ...

    def extract_slot(self, co) -> Dict[str, Any]: ...

    def install_slot(self, co, slices: Dict[str, Any]) -> None: ...

    def reconfigure_partition(self, co, group: List[int]) -> None: ...

    def decode_page(self, active: Sequence, P: int) -> None: ...

    def sync_appends(self, active: Sequence) -> None: ...

    def stage_appends(self, active: Sequence) -> None: ...

    def drain_appends(self, keep_newest: int = 0) -> None: ...

    def prefill(self, cos: Sequence) -> None: ...

    def stage_restore(self, co) -> bool: ...

    def take_restore(self, seq_id: int) -> Optional[Dict[str, Any]]: ...

    def discard_restore(self, seq_id: int) -> None: ...

    def discard_restores(self) -> None: ...

    def heartbeat(self) -> Optional[Any]: ...

    def transfer(self, kind: str, fn: Any) -> Any: ...


def validate_backend(backend):
    """Check `backend` against the ExecutionBackend contract.

    Accepts an instance (methods + data attributes checked — what the
    scheduler does at construction) or a class (methods only: the data
    members are created per-instance in ``__init__``, which is how the
    engines declare conformance at import time).  Returns the argument so
    it composes, raises ``TypeError`` naming every missing member.
    """
    is_cls = isinstance(backend, type)
    name = backend.__name__ if is_cls else type(backend).__name__
    missing = [m for m in PROTOCOL_METHODS
               if not callable(getattr(backend, m, None))]
    if not is_cls:
        missing += [a for a in PROTOCOL_ATTRS if not hasattr(backend, a)]
    if missing:
        raise TypeError(
            f"{name} does not implement ExecutionBackend: "
            f"missing {', '.join(missing)}")
    return backend
