"""Algorithm 1: module-granularity forward pass with intra-forward yields.

The paper's module wrapper (Fig. 4b) turns each neural module into a
coroutine step: attention runs per sub-batch of size B_attn, YIELDs its
hidden states, and the runtime COMBINEs all sub-batches into one
B_moe-sized batch before the (sparse) MoE module.  Control returns to the
host scheduler between every jitted module call — on TPU the yield point
*is* the boundary between two compiled programs (DESIGN.md §3).

This path executes real tokens in the mini-engine (module_granularity=True)
and is what benchmarks/expert_batching.py measures (Fig. 2b reproduction).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models import layers, moe as moe_lib, transformer as T
from repro.models.api import MeshAxes, ModelConfig


# LRU cap on (steps, n_sub, sampled, lp_k) page executables — sized so a
# mixed workload (all pow2 chunk sizes x sampled x logprob variants) does
# not thrash steady-state recompiles
_PAGE_JIT_CAP = 16


def _lru_get(cache: "OrderedDict", key, cap: int, make):
    """Fetch-or-build `key` in an OrderedDict LRU bounded to `cap`."""
    fn = cache.get(key)
    if fn is None:
        fn = cache[key] = make()
    else:
        cache.move_to_end(key)
    while len(cache) > cap:
        cache.popitem(last=False)
    return fn


def _sub_slices(B: int, n_sub: int) -> List[slice]:
    """Static sub-batch boundaries covering ALL rows; when B % n_sub != 0
    the later groups absorb the remainder (previously the tail rows were
    silently dropped, which broke any non-divisible active batch)."""
    bounds = [g * B // n_sub for g in range(n_sub + 1)]
    return [slice(bounds[g], bounds[g + 1]) for g in range(n_sub)]


@dataclasses.dataclass
class ModuleTrace:
    """Record of one coroutine step (for overhead accounting, Table 2)."""
    module: str
    layer: int
    batch: int
    tokens: int


class ModuleRuntime:
    """Per-model jitted module functions split at the paper's yield points.

    Yield-point option (b) from Fig. 6: attention | MoE as separate
    coroutine units (option (a) fuses them; option (c) per-expert is noted
    as memory-prohibitive by the paper)."""

    def __init__(self, cfg: ModelConfig, axes: MeshAxes, params):
        assert cfg.family in ("moe", "dense"), cfg.family
        self.cfg = cfg
        self.axes = axes
        self.params = params
        # pre-split stacked layer params -> list of per-layer trees
        L = cfg.num_layers
        self.layer_params = [jax.tree.map(lambda x, i=i: x[i],
                                          params["layers"])
                             for i in range(L)]
        self.traces: List[ModuleTrace] = []
        self._embed = jax.jit(self._embed_impl)
        self._attn = jax.jit(self._attn_impl, static_argnames=("nsub",))
        self._ffn = jax.jit(self._ffn_impl)
        self._head = jax.jit(self._head_impl)
        self._head_logits = jax.jit(self._head_logits_impl)
        self._page_cache: "OrderedDict[tuple, Any]" = OrderedDict()

    # --- jitted module bodies ------------------------------------------
    def _embed_impl(self, tokens):
        return T._embed_tokens(self.cfg, self.params, tokens[:, None])

    def _attn_impl(self, p, h, k_cache, v_cache, lengths, nsub):
        """Attention for ONE sub-batch (B_attn rows of the slot arrays)."""
        xn = layers.apply_norm(self.cfg, p["ln1"], h)
        a, kc, vc = layers.attention_decode(self.cfg, p["attn"], xn,
                                            k_cache, v_cache, lengths)
        return h + a, kc, vc

    def _ffn_impl(self, p, h):
        xn = layers.apply_norm(self.cfg, p["ln2"], h)
        if self.cfg.is_moe:
            y, _ = moe_lib.moe_fwd(self.cfg, self.axes, p["moe"], xn)
        else:
            y = layers.mlp_fwd(self.cfg, p["mlp"], xn)
        return h + y

    def _head_impl(self, h):
        return jnp.argmax(self._head_logits_impl(h), axis=-1).astype(
            jnp.int32)

    def _head_logits_impl(self, h):
        """Final norm + lm head -> next-token logits (B, V)."""
        h = layers.apply_norm(self.cfg, self.params["final_norm"], h)
        logits = T.logits_fn(self.cfg, self.params, h)
        return logits[:, 0, :]

    # --- Algorithm 1 ------------------------------------------------------
    def forward_decode(self, tokens, cache, lengths, b_attn: int,
                       on_yield: Optional[Callable] = None,
                       want_logits: bool = False):
        """One decode step for the full active batch with B_attn
        sub-batching and COMBINE before each FFN/MoE.

        tokens (B,), cache pytree with leaves (L,B,S,...), lengths (B,).
        Returns (next_tokens, new_cache) — or (logits, new_cache) with
        ``want_logits`` (the looped-baseline logprob path picks the token
        host-side)."""
        cfg = self.cfg
        B = tokens.shape[0]
        n_sub = max(B // max(b_attn, 1), 1)
        h = self._embed(tokens)
        new_k, new_v = [], []
        for l in range(cfg.num_layers):
            p = self.layer_params[l]
            kc_l, vc_l = cache["k"][l], cache["v"][l]
            h_parts, k_parts, v_parts = [], [], []
            for g, sl in enumerate(_sub_slices(B, n_sub)):
                bsz = sl.stop - sl.start
                hg, kg, vg = self._attn(p, h[sl], kc_l[sl], vc_l[sl],
                                        lengths[sl], n_sub)
                self.traces.append(ModuleTrace("attention", l, bsz, bsz))
                h_parts.append(hg)
                k_parts.append(kg)
                v_parts.append(vg)
                if on_yield is not None:
                    on_yield("attention", l, g)     # intra-forward YIELD
            # COMBINE: concatenate yielded hidden states -> B_moe batch
            h = jnp.concatenate(h_parts, axis=0)
            new_k.append(jnp.concatenate(k_parts, axis=0))
            new_v.append(jnp.concatenate(v_parts, axis=0))
            h = self._ffn(p, h)
            self.traces.append(ModuleTrace(
                "moe" if cfg.is_moe else "mlp", l, B, B))
            if on_yield is not None:
                on_yield("ffn", l, 0)
        cache = {"k": jnp.stack(new_k), "v": jnp.stack(new_v)}
        if want_logits:
            return self._head_logits(h), cache
        nxt = self._head(h)
        return nxt, cache

    # --- fused decode page (one program per page) ----------------------
    def forward_decode_page(self, tokens, cache, lengths, remaining,
                            b_attn: int, steps: int, sampling=None,
                            lp_k=None, flags=None):
        """Fused Algorithm-1 decode megastep: one jitted ``lax.scan`` over
        ``steps`` module-granularity decode steps.

        Each scanned step is the same decomposition as ``forward_decode``
        — B_attn sub-batched attention, COMBINE (concatenate) into the
        full B_moe batch before each FFN/MoE — but the whole page compiles
        into ONE device program: sampled tokens self-feed on device and
        finished slots (``remaining`` exhausted) are masked.  The
        intra-forward yield points become trace-time boundaries only;
        the scheduler regains control at the page boundary, which is all
        §5.3 requires.  Returns ``(token_block, tokens, lengths,
        remaining, cache)`` with ``token_block`` of shape (steps, B);
        the carry outputs stay on device so pages decompose into chained
        pow2 chunks (see NodeEngine.decode_page).

        ``sampling=(sp, state)`` swaps the head argmax for the sampling
        pipeline (see models.transformer.decode_page) and appends the
        advanced per-slot state to the returned tuple.  ``lp_k`` (None |
        0 | K) swaps the raw token rows for the packed logprob plane of
        ``models.transformer.pack_logprob_block``.  ``flags`` is the
        static :class:`repro.sampling.SampleFlags` plan (sampled path);
        it is part of the executable cache key."""
        B = int(tokens.shape[0])
        n_sub = max(B // max(b_attn, 1), 1)
        key = (int(steps), n_sub, sampling is not None, lp_k, flags)
        fn = _lru_get(self._page_cache, key, _PAGE_JIT_CAP,
                      lambda: jax.jit(partial(self._page_impl,
                                              steps=int(steps),
                                              n_sub=n_sub,
                                              sampled=sampling is not None,
                                              lp_k=lp_k, flags=flags),
                                      donate_argnums=(0,)))
        if sampling is None:
            return fn(cache, tokens, lengths, remaining)
        sp, state = sampling
        return fn(cache, tokens, lengths, remaining, sp, state)

    def _page_impl(self, cache, tokens, lengths, remaining, sp=None,
                   state=None, *, steps: int, n_sub: int,
                   sampled: bool = False, lp_k=None, flags=None):
        from repro.sampling import DEFAULT_FLAGS, sample_step
        flags = flags or DEFAULT_FLAGS

        cfg = self.cfg
        B = tokens.shape[0]
        slices = _sub_slices(B, n_sub)

        def model_step(cache, tokens, lengths):
            h = T._embed_tokens(cfg, self.params, tokens[:, None])

            def layer_body(hh, xs):
                p, c = xs
                h_parts, k_parts, v_parts = [], [], []
                for sl in slices:
                    hg, kg, vg = self._attn_impl(p, hh[sl], c["k"][sl],
                                                 c["v"][sl], lengths[sl],
                                                 n_sub)
                    h_parts.append(hg)
                    k_parts.append(kg)
                    v_parts.append(vg)
                hh = jnp.concatenate(h_parts, axis=0)   # COMBINE
                hh = self._ffn_impl(p, hh)
                return hh, {"k": jnp.concatenate(k_parts, axis=0),
                            "v": jnp.concatenate(v_parts, axis=0)}

            h, new_cache = jax.lax.scan(layer_body, h,
                                        (self.params["layers"], cache))
            return h, new_cache

        def emit(tokens, logits):
            return (tokens if lp_k is None
                    else T.pack_logprob_block(tokens, logits, lp_k))

        if not sampled:
            def one_step(carry, _):
                cache, tokens, lengths, remaining = carry
                h, new_cache = model_step(cache, tokens, lengths)
                if lp_k is None:
                    nxt, logits = self._head_impl(h), None
                else:
                    logits = self._head_logits_impl(h)
                    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                live = remaining > 0
                tokens = jnp.where(live, nxt, tokens)
                lengths = lengths + live.astype(jnp.int32)
                remaining = remaining - live.astype(jnp.int32)
                return (new_cache, tokens, lengths, remaining), \
                    emit(tokens, logits)

            (cache, tokens, lengths, remaining), block = jax.lax.scan(
                one_step, (cache, tokens, lengths, remaining), None,
                length=steps)
            return block, tokens, lengths, remaining, cache

        def one_step(carry, _):
            cache, tokens, lengths, remaining, state = carry
            h, new_cache = model_step(cache, tokens, lengths)
            logits = self._head_logits_impl(h)
            if lp_k is None:
                nxt, live, remaining, state = sample_step(
                    logits, remaining, state, sp, flags)
            else:
                nxt, live, remaining, state, lanes = sample_step(
                    logits, remaining, state, sp, flags, lp_k=lp_k)
            tokens = jnp.where(live, nxt, tokens)
            lengths = lengths + live.astype(jnp.int32)
            out = (tokens if lp_k is None
                   else T.pack_plane_from_lanes(tokens, lanes))
            return (new_cache, tokens, lengths, remaining, state), out

        (cache, tokens, lengths, remaining, state), block = jax.lax.scan(
            one_step, (cache, tokens, lengths, remaining, state), None,
            length=steps)
        return block, tokens, lengths, remaining, cache, state

    def expert_load(self, b_moe: int) -> Dict[str, float]:
        """Per-expert batch statistics at the MoE gate for a combined batch
        of b_moe tokens (Fig. 2b quantity)."""
        cfg = self.cfg
        if not cfg.is_moe:
            return {"per_expert": float(b_moe), "experts": 1}
        per = b_moe * cfg.experts_per_token / cfg.num_experts
        return {"per_expert": per, "experts": cfg.num_experts}
