"""The sequence coroutine abstraction (paper §4, Figure 4a).

A SequenceCoroutine carries everything needed to pause, migrate, combine,
partition and resume one sequence's computation: identity, token state,
phase, and references to where its KV/recurrent state lives (host store
pages vs a device slot).  The runtime manipulates coroutines exclusively
through the primitives in core/primitives.py.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Any, Callable, Dict, List, Optional

from repro.sampling.params import SamplingParams


class Phase(str, enum.Enum):
    PREFILL = "prefill"
    DECODING = "decoding"


class Status(str, enum.Enum):
    INIT = "init"          # submitted, not yet prefilled
    ACTIVE = "active"      # occupying a device slot
    INACTIVE = "inactive"  # yielded; state checkpointed to host
    DONE = "done"


@dataclasses.dataclass
class SequenceCoroutine:
    seq_id: int
    prompt: List[int]
    max_out: int
    max_in: int = 0
    phase: Phase = Phase.PREFILL
    status: Status = Status.INIT

    # generation state
    generated: List[int] = dataclasses.field(default_factory=list)
    last_token: int = 0
    length: int = 0                 # tokens represented in the KV state

    # sampling: params travel WITH the coroutine so COMBINE/MIGRATE/
    # PARTITION preserve per-sequence decoding behavior; device-side
    # sampling state (PRNG key index, penalty counts) is re-derived from
    # (sampling.seed, generated, prompt) at slot install, so no extra
    # state crosses nodes.  `stopped` records a stop-token hit (the stop
    # token IS emitted, then the sequence halts).
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    stopped: bool = False
    # deadline degradation: the scheduler's SEQ_DONE sweep sets this when
    # wall time since submit exceeds sampling.deadline_s — the sequence
    # finishes gracefully with whatever it has (finish_reason="deadline")
    deadlined: bool = False

    # logprobs: when requested, the fused megastep returns a second (P, B)
    # f32 chosen-token logprob plane (and optional top-K alternatives)
    # through the SAME single per-page transfer; values are log-softmax of
    # the raw model logits (pre-sampling-pipeline), aligned 1:1 with
    # `generated`.
    logprobs: bool = False               # collect chosen-token logprobs
    top_logprobs: int = 0                # also collect top-K alternatives
    token_logprobs: List[float] = dataclasses.field(default_factory=list)
    top_token_logprobs: List[List[tuple]] = dataclasses.field(
        default_factory=list)            # per token: [(token_id, lp), ...]

    # placement (scheduler book-keeping; the paper's `migrate` target)
    node: int = 0
    slot: Optional[int] = None      # device slot when ACTIVE
    partition_group: Optional[List[int]] = None  # device ids when PARTITIONed

    # shared-prefix fan-out: forks of one prompt share a fork_group (the
    # lead sibling's seq_id); the engine prefills the group's prompt once
    # and every sibling rides the lead's span pages copy-on-write.
    # prefix_hit_tokens counts prompt tokens whose prefill was skipped
    # (fork dedupe or a cross-submit PrefixIndex hit) — reset on recompute
    # recovery, where the prompt is re-prefilled from scratch.
    fork_group: Optional[int] = None
    prefix_hit_tokens: int = 0

    # module-level execution cursor (intra-forward yield position)
    module_cursor: int = 0          # index into the coroutine execution flow
    output: Any = None              # hidden states between module calls

    # user callbacks (paper §4.3: custom local state handling, e.g. dynamic
    # KV quantization) — called as cb(coroutine, event, payload)
    callbacks: Dict[str, Callable] = dataclasses.field(default_factory=dict)

    # accounting
    submitted_t: float = dataclasses.field(default_factory=time.monotonic)
    finished_t: Optional[float] = None
    yields: int = 0
    migrations: int = 0

    # ------------------------------------------------------------------
    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def done(self) -> bool:
        return self.status == Status.DONE

    @property
    def remaining(self) -> int:
        if self.stopped or self.deadlined:
            return 0
        return max(self.max_out - len(self.generated), 0)

    @property
    def finish_reason(self) -> str:
        # a stop-token hit outranks the deadline: the output is already
        # complete, the deadline merely arrived in the same round
        if self.stopped:
            return "stop"
        if self.deadlined:
            return "deadline"
        return "length"

    def tokens(self) -> List[int]:
        return self.prompt + self.generated

    def fire(self, event: str, payload=None):
        cb = self.callbacks.get(event)
        if cb is not None:
            cb(self, event, payload)

    def finish(self):
        self.status = Status.DONE
        self.finished_t = time.monotonic()
        self.fire("on_done", None)

    def sct(self) -> Optional[float]:
        """Sequence completion time (paper §2.1)."""
        if self.finished_t is None:
            return None
        return self.finished_t - self.submitted_t
