"""Event queue + typed result records for the event-driven runtime (§3/§5).

Two event families live here:

* **Scheduler events** (``EventKind`` / ``Event`` / ``EventQueue``) — the
  *inputs* the ``CoroutineScheduler`` dispatches through its policy table.
  The queue is priority-ordered so that correctness events (SYNC) precede
  utilization events (REFILL) which precede opportunistic ones (MIGRATE);
  GPUs always have work as long as any queue is non-empty.
* **Runtime records** (``TokenBlockEvent`` / ``SeqFinishedEvent`` /
  ``PrimitiveEvent``) — the *outputs* yielded by
  ``CoroutineScheduler.stream()`` as pages complete, the stream-first
  result surface ``run()`` and ``BatchMaster`` are built on.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
from typing import Any, List, Optional, Tuple


class EventKind(enum.IntEnum):          # ordering = processing priority
    SYNC = 0              # issue async KV appends (page boundary, §5.3 i)
    SYNC_DRAIN = 1        # land in-flight KV blobs in the host store —
    #                       priority-ordered BEFORE every consumer of
    #                       host-store state (evict / migrate / failure),
    #                       so a staged-but-undrained blob can never be
    #                       outrun by a drop or a cross-node move
    SEQ_DONE = 2          # eviction of completed sequences (§5.3 ii)
    SEQ_PREEMPT = 3       # memory-pressure governor: device pages crossed
    #                       the allocator's high watermark — checkpoint the
    #                       least-progress sequences to the host store and
    #                       free their device pages.  Ranks BEFORE
    #                       PAGE_BOUNDARY so preemption lands before the
    #                       boundary handler tries to extend every active
    #                       sequence by a page (the extension would fail on
    #                       an exhausted pool that preemption can relieve)
    PAGE_BOUNDARY = 4     # extension / yield decisions (§5.3 iii)
    MODULE_READY = 5      # intra-forward successor enqueued by YIELD
    REFILL = 6            # ON_REFILL_NODE (§5.1 Alg. 2)
    LONG_TAIL = 7         # ON_LONG_TAIL -> PARTITION
    NODE_SLOW = 8         # straggler mitigation: a live node's EWMA
    #                       throughput fell below the fleet median for K
    #                       consecutive rounds (ProgressTracker) — shed a
    #                       fraction of its work to fast survivors.  The
    #                       node is alive (its heartbeats still arrive),
    #                       so this is distinct from NODE_FAILURE and
    #                       ranks just above MIGRATE: shedding is load
    #                       balancing with evidence, not recovery
    MIGRATE = 9           # opportunistic load balancing
    NODE_FAILURE = 10     # health monitor (§5.6)
    NODE_DRAIN = 11       # elastic scale-down: graceful drain-and-handoff —
    #                       checkpoint + MIGRATE every live sequence to a
    #                       survivor (zero recompute), then retire the node.
    #                       Lowest priority: a drain never outruns recovery.


@dataclasses.dataclass(order=True)
class Event:
    sort_key: tuple = dataclasses.field(init=False, repr=False)
    kind: EventKind = EventKind.MODULE_READY
    node: int = 0
    payload: Any = None
    seq: int = dataclasses.field(default_factory=itertools.count().__next__)

    def __post_init__(self):
        self.sort_key = (int(self.kind), self.seq)


class EventQueue:
    def __init__(self):
        self._heap = []
        self._count = itertools.count()

    def push(self, kind: EventKind, node: int = 0, payload: Any = None):
        ev = Event(kind=kind, node=node, payload=payload,
                   seq=next(self._count))
        heapq.heappush(self._heap, ev)

    def pop(self) -> Optional[Event]:
        if not self._heap:
            return None
        return heapq.heappop(self._heap)

    def __len__(self):
        return len(self._heap)

    def __bool__(self):
        return bool(self._heap)


# ---------------------------------------------------------------------------
# runtime records — the stream-first result surface
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RuntimeRecord:
    """Base of the typed records yielded by ``CoroutineScheduler.stream()``.

    ``custom_id`` is filled in by ``BatchMaster`` when the record belongs
    to a batch-API request (the scheduler itself only knows seq_ids)."""
    seq_id: int
    node: int


@dataclasses.dataclass
class TokenBlockEvent(RuntimeRecord):
    """Tokens appended to one sequence by one decode page (or prefill).

    ``offset`` is the index of ``tokens[0]`` within the sequence's full
    generated stream — consumers reassemble exactly ``run()``'s output by
    concatenating blocks in order, and an ``offset`` of 0 re-appearing
    mid-stream signals a failure-recovery recompute (the earlier tokens
    were re-generated and supersede what was streamed before)."""
    tokens: List[int] = dataclasses.field(default_factory=list)
    offset: int = 0
    logprobs: Optional[List[float]] = None
    top_logprobs: Optional[List[List[Tuple[int, float]]]] = None
    custom_id: Optional[str] = None


@dataclasses.dataclass
class SeqFinishedEvent(RuntimeRecord):
    """A sequence completed and released its device + host pages."""
    finish_reason: str = "length"       # "stop" | "length" | "deadline"
    n_generated: int = 0
    sct_s: Optional[float] = None       # sequence completion time (§2.1)
    custom_id: Optional[str] = None


@dataclasses.dataclass
class PrimitiveEvent(RuntimeRecord):
    """A coroutine primitive fired (yield/combine/partition/migrate — plus
    'recompute' for the failure-recovery path that replays from the
    prompt)."""
    primitive: str = ""
    detail: Any = None
    custom_id: Optional[str] = None


@dataclasses.dataclass
class HealthEvent(RuntimeRecord):
    """The health subsystem acted on a node: the monitor declared it dead
    (``reason='heartbeat'``), a transfer dead-lettered out of its retry
    budget (``reason='dead_letter'``), an external caller demanded a
    failover (``reason='external'``), or the progress tracker flagged a
    live straggler (``reason='slow'`` — NODE_SLOW, not NODE_FAILURE).
    ``seq_id`` is -1 — this record is about a node, not a sequence."""
    reason: str = "heartbeat"
    detail: Any = None
    custom_id: Optional[str] = None
