"""Event queue for the event-driven coroutine runtime (paper §3/§5).

Events are processed by the scheduler loop; GPUs always have work as long
as any queue is non-empty.  The queue is priority-ordered so that
correctness events (SYNC) precede utilization events (REFILL) which precede
opportunistic ones (MIGRATE).
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
from typing import Any, Optional


class EventKind(enum.IntEnum):          # ordering = processing priority
    SYNC = 0              # wait for async KV appends (page boundary, §5.3 i)
    SEQ_DONE = 1          # eviction of completed sequences (§5.3 ii)
    PAGE_BOUNDARY = 2     # extension / yield decisions (§5.3 iii)
    MODULE_READY = 3      # intra-forward successor enqueued by YIELD
    REFILL = 4            # ON_REFILL_NODE (§5.1 Alg. 2)
    LONG_TAIL = 5         # ON_LONG_TAIL -> PARTITION
    MIGRATE = 6           # opportunistic load balancing
    NODE_FAILURE = 7      # health monitor (§5.6)


@dataclasses.dataclass(order=True)
class Event:
    sort_key: tuple = dataclasses.field(init=False, repr=False)
    kind: EventKind = EventKind.MODULE_READY
    node: int = 0
    payload: Any = None
    seq: int = dataclasses.field(default_factory=itertools.count().__next__)

    def __post_init__(self):
        self.sort_key = (int(self.kind), self.seq)


class EventQueue:
    def __init__(self):
        self._heap = []
        self._count = itertools.count()

    def push(self, kind: EventKind, node: int = 0, payload: Any = None):
        ev = Event(kind=kind, node=node, payload=payload,
                   seq=next(self._count))
        heapq.heappush(self._heap, ev)

    def pop(self) -> Optional[Event]:
        if not self._heap:
            return None
        return heapq.heappop(self._heap)

    def __len__(self):
        return len(self._heap)

    def __bool__(self):
        return bool(self._heap)
