"""Shared-prefix KV reuse: trie index, refcounted spans, COW pages."""
from repro.prefix.index import PrefixIndex, PrefixNode, block_key

__all__ = ["PrefixIndex", "PrefixNode", "block_key"]
