"""Token-trie prefix index over paged host-KV spans.

A :class:`PrefixIndex` maps page-aligned token blocks to shared, read-only
KV pages from :mod:`repro.memory.paged_kv`.  Each trie node covers exactly
one page (``page_size`` tokens); its key chains the parent's key with a
stable hash of the node's token block, so the deepest node's key identifies
the whole span.  Nodes are refcounted by the sequences bound to them:
``acquire``/``release`` walk the chain root-ward so an inner node can never
be evicted while a descendant span is live.

Eviction is LRU over zero-ref *leaves* only and cascades: once a leaf goes,
its parent may become a zero-ref leaf and is a candidate on the next pass.
Dropping a node releases the index's reference to its page arrays — with no
live sequence bound (refs == 0 is the precondition) that frees the host
memory too.

The index is engine-local (one per :class:`HostKVStore`).  ``graft`` adopts
a chain from a peer store's index during MIGRATE: nodes already present are
reused (the span's bytes move zero times for siblings that migrated
earlier); missing nodes are re-created around the *same* read-only page
arrays, so a span crosses the wire once no matter how many forks ride it.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

_MASK = 0xFFFFFFFFFFFFFFFF


def block_key(parent_key: int, block: Sequence[int]) -> int:
    """Stable chained hash of one page-aligned token block (FNV-style)."""
    h = (parent_key * 0x100000001B3 + 0x9E3779B97F4A7C15) & _MASK
    for t in block:
        h = ((h ^ (int(t) & 0xFFFFFFFF)) * 0x100000001B3) & _MASK
    return h


class PrefixNode:
    """One shared page: ``page_size`` tokens plus their KV page per leaf."""

    __slots__ = ("key", "block", "parent", "children", "pages", "refs",
                 "tick")

    def __init__(self, key: int, block: tuple, parent: Optional["PrefixNode"]):
        self.key = key
        self.block = block
        self.parent = parent
        self.children: Dict[int, "PrefixNode"] = {}
        self.pages: Dict[str, np.ndarray] = {}
        self.refs = 0
        self.tick = 0

    def chain(self) -> List["PrefixNode"]:
        """Root-to-self node list (excluding the sentinel root)."""
        out: List[PrefixNode] = []
        nd: Optional[PrefixNode] = self
        while nd is not None and nd.parent is not None:
            out.append(nd)
            nd = nd.parent
        out.reverse()
        return out

    def nbytes(self) -> int:
        return sum(int(p.nbytes) for p in self.pages.values())


class PrefixIndex:
    """Refcounted trie of shared KV page spans with LRU eviction."""

    def __init__(self, page_size: int, max_pages: int = 4096):
        self.page_size = page_size
        self.max_pages = max_pages
        self.root = PrefixNode(0, (), None)
        self.num_pages = 0
        self.cached_nbytes = 0      # bytes of page arrays held by the trie
        self._tick = 0
        self.stats = {"hits": 0, "hit_tokens": 0, "inserted_pages": 0,
                      "evicted_pages": 0, "acquires": 0, "releases": 0}

    # -- lookup / insert ----------------------------------------------------

    def match(self, tokens: Sequence[int]) -> List[PrefixNode]:
        """Longest chain of full-page blocks of ``tokens`` present in the
        trie.  Does NOT acquire — callers bind via ``acquire``."""
        P = self.page_size
        cur, chain = self.root, []
        for i in range(len(tokens) // P):
            block = tuple(int(t) for t in tokens[i * P:(i + 1) * P])
            nxt = cur.children.get(block_key(cur.key, block))
            if nxt is None or nxt.block != block:
                break
            chain.append(nxt)
            cur = nxt
        if chain:
            self.stats["hits"] += 1
            self.stats["hit_tokens"] += len(chain) * P
        return chain

    def extend(self, chain: List[PrefixNode], tokens: Sequence[int],
               pages_for: Optional[Callable[[int], Dict[str, np.ndarray]]]
               = None) -> List[PrefixNode]:
        """Insert nodes for the full-page blocks of ``tokens`` beyond
        ``chain`` (a ``match`` result).  ``pages_for(page_idx)`` supplies
        the page arrays for a new node — they are frozen read-only here so
        every holder copy-on-writes.  ``None`` inserts metadata-only nodes
        (SimEngine).  Returns the full chain covering the prompt's pages."""
        P = self.page_size
        cur = chain[-1] if chain else self.root
        out = list(chain)
        for i in range(len(out), len(tokens) // P):
            block = tuple(int(t) for t in tokens[i * P:(i + 1) * P])
            key = block_key(cur.key, block)
            nd = cur.children.get(key)
            if nd is None or nd.block != block:
                nd = PrefixNode(key, block, cur)
                if pages_for is not None:
                    for name, page in pages_for(i).items():
                        page.flags.writeable = False
                        nd.pages[name] = page
                cur.children[key] = nd
                self.num_pages += 1
                self.cached_nbytes += nd.nbytes()
                self.stats["inserted_pages"] += 1
            out.append(nd)
            cur = nd
        self._maybe_evict()
        return out

    def graft(self, src_node: PrefixNode) -> tuple:
        """Adopt a peer index's chain (MIGRATE dst side).  Returns
        ``(chain, new_bytes)`` where ``new_bytes`` counts only pages this
        store did not already hold — a sibling's earlier migrate makes the
        span free."""
        cur, chain, new_bytes = self.root, [], 0
        for nd in src_node.chain():
            child = cur.children.get(nd.key)
            if child is None or child.block != nd.block:
                child = PrefixNode(nd.key, nd.block, cur)
                child.pages = dict(nd.pages)
                cur.children[nd.key] = child
                self.num_pages += 1
                self.cached_nbytes += child.nbytes()
                self.stats["inserted_pages"] += 1
                new_bytes += child.nbytes()
            chain.append(child)
            cur = child
        return chain, new_bytes

    # -- refcounts ----------------------------------------------------------

    def acquire(self, node: Optional[PrefixNode]) -> None:
        if node is None:
            return
        self.stats["acquires"] += 1
        self._tick += 1
        nd: Optional[PrefixNode] = node
        while nd is not None and nd.parent is not None:
            nd.refs += 1
            nd.tick = self._tick
            nd = nd.parent

    def release(self, node: Optional[PrefixNode]) -> None:
        if node is None:
            return
        self.stats["releases"] += 1
        nd: Optional[PrefixNode] = node
        while nd is not None and nd.parent is not None:
            if nd.refs <= 0:
                raise AssertionError("prefix span refcount underflow")
            nd.refs -= 1
            nd = nd.parent
        self._maybe_evict()

    def live_refs(self) -> int:
        """Sum of refcounts over the whole trie (0 == no bound sequences)."""
        total, stack = 0, list(self.root.children.values())
        while stack:
            nd = stack.pop()
            total += nd.refs
            stack.extend(nd.children.values())
        return total

    # -- eviction -----------------------------------------------------------

    def _evictable(self) -> List[PrefixNode]:
        out, stack = [], list(self.root.children.values())
        while stack:
            nd = stack.pop()
            if not nd.children and nd.refs == 0:
                out.append(nd)
            stack.extend(nd.children.values())
        return out

    def _evict(self, victim: PrefixNode) -> None:
        del victim.parent.children[victim.key]
        self.cached_nbytes -= victim.nbytes()
        victim.pages.clear()    # cascade: frees the host-store pages
        self.num_pages -= 1
        self.stats["evicted_pages"] += 1

    def evict_lru(self) -> bool:
        """Evict the LRU zero-ref leaf regardless of ``max_pages`` — the
        entry point of the host-store byte-budget cascade
        (``HostKVStore.enforce_budget``).  Returns False when every span
        is live-referenced (nothing evictable)."""
        victims = self._evictable()
        if not victims:
            return False
        self._evict(min(victims, key=lambda nd: nd.tick))
        return True

    def _maybe_evict(self) -> None:
        while self.num_pages > self.max_pages:
            victims = self._evictable()
            if not victims:
                return              # every span is live-referenced
            self._evict(min(victims, key=lambda nd: nd.tick))
