"""Production mesh construction.

Functions (not module constants) so importing never touches jax device
state.  Single pod = 16x16 = 256 chips (TPU v5e pod); multi-pod = 2 pods =
512 chips with a leading "pod" axis (data parallelism across the
inter-pod DCN/ICI links).
"""
from __future__ import annotations

from repro import compat
from repro.models.api import MeshAxes


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU smoke tests (same axis names as production)."""
    return compat.make_mesh((data, model), ("data", "model"))


def mesh_axes(mesh) -> MeshAxes:
    if "pod" in mesh.axis_names:
        return MeshAxes(batch=("pod", "data"), model="model")
    return MeshAxes(batch=("data",), model="model")


def batch_extent(mesh) -> int:
    """Product of DP axis sizes."""
    import math

    ax = mesh_axes(mesh)
    return math.prod(mesh.shape[a] for a in ax.batch) if ax.batch else 1
