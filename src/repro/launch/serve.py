"""Production serving driver: builds the decode cell for an (arch, shape),
runs the batch engine loop.  On this CPU container use --reduced to
actually execute; full configs are exercised through dryrun.py.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b --reduced
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.scheduler import CoroutineScheduler, SchedulerConfig
from repro.runtime.api import BatchMaster, BatchRequest
from repro.runtime.engine import NodeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-active", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    engines = [NodeEngine(cfg, node_id=i, max_active=args.max_active,
                          max_len=256, page_size=args.page_size)
               for i in range(args.nodes)]
    master = BatchMaster(engines, SchedulerConfig(page_size=args.page_size))
    rng = np.random.default_rng(0)
    reqs = [BatchRequest(custom_id=f"r{i}",
                         prompt=list(rng.integers(2, cfg.vocab_size, 8)),
                         max_tokens=int(rng.integers(4, 48)))
            for i in range(args.requests)]
    bid = master.submit(reqs)
    bo = master.run(bid)
    print(f"{bo.id}: {bo.request_counts} BCT={bo.bct_s:.2f}s")
    for i, e in enumerate(engines):
        print(f"node{i}: {e.stats.counts} decode_steps={e.decode_steps}")


if __name__ == "__main__":
    main()
