import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

"""Multi-pod dry run (deliverable e).

Lowers + compiles every (architecture x input shape) cell against the
production meshes — 16x16 single pod and 2x16x16 multi-pod — and records
memory_analysis / cost_analysis / collective traffic for the roofline
(EXPERIMENTS.md §Dry-run / §Roofline).

The XLA_FLAGS line above MUST run before any other jax-importing module:
jax locks the device count at first init.  Only the dry-run uses 512
placeholder devices; smoke tests and benchmarks see the 1 real CPU device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                # everything
    ... --arch qwen3_moe_30b --shape train_4k --mesh single --unroll
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED, ARCH_IDS, get_config
from repro.distributed.collectives import collective_stats
from repro.launch import steps
from repro.launch.mesh import make_production_mesh
from repro.models.api import SHAPES, shape_applicable

HW = {  # TPU v5e per chip
    "peak_flops": 197e12,       # bf16
    "hbm_bw": 819e9,            # bytes/s
    "ici_bw": 50e9,             # bytes/s per link
    "hbm_bytes": 16 * 2**30,
}


def run_cell(arch: str, shape_name: str, multi_pod: bool, unroll: bool,
             outdir: str):
    mesh = make_production_mesh(multi_pod=multi_pod)
    tag = f"{arch}.{shape_name}.{'multi' if multi_pod else 'single'}"
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, SHAPES[shape_name])
    if not ok:
        rec = {"cell": tag, "status": "skipped", "why": why}
        _save(outdir, tag, rec)
        print(f"SKIP {tag}: {why}")
        return rec
    t0 = time.time()
    try:
        cell = steps.build_cell(arch, shape_name, mesh, unroll=unroll)
        lowered = cell.lower()
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        colls = collective_stats(hlo, world=mesh.size)
        hoist = _cpu_bf16_hoist_bytes(hlo)
        rec = {
            "cell": tag, "status": "ok", "kind": cell.kind, "note": cell.note,
            "unrolled": unroll,
            "mesh": dict(mesh.shape),
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "per_device": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_bytes": (ma.argument_size_in_bytes
                               + ma.temp_size_in_bytes
                               + ma.output_size_in_bytes
                               - ma.alias_size_in_bytes),
                "flops": ca.get("flops", 0.0),
                "bytes_accessed": ca.get("bytes accessed", 0.0),
            },
            "collectives": colls,
            "hlo_bytes": len(hlo),
            # XLA's CPU backend legalizes bf16 dots via f32 operand converts
            # and hoists whole-stack conversions out of the layer loop; a
            # TPU backend (native bf16 MXU) allocates none of these.  The
            # adjusted peak subtracts those identifiable f32 convert
            # buffers (DESIGN.md §6).
            "cpu_bf16_hoist_bytes": hoist,
        }
        peak = rec["per_device"]["peak_bytes"]
        adj = max(peak - hoist, 0)
        rec["per_device"]["peak_bytes_adjusted"] = adj
        rec["fits_hbm"] = adj < HW["hbm_bytes"]
        print(f"OK   {tag} lower={t_lower:.1f}s compile={t_compile:.1f}s "
              f"peak={peak/2**30:.2f}GiB adj={adj/2**30:.2f}GiB "
              f"fits={rec['fits_hbm']} "
              f"coll={colls['total_wire_bytes']/2**20:.1f}MiB")
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec = {"cell": tag, "status": "fail", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
        print(f"FAIL {tag}: {type(e).__name__}: {str(e)[:200]}")
    _save(outdir, tag, rec)
    return rec


import re as _re

_CONVERT_RE = _re.compile(
    r"%wrapped_convert[^=]*=\s*f32\[([0-9,]+)\]")


def _cpu_bf16_hoist_bytes(hlo: str) -> int:
    """Sum of f32 buffers created by CPU-backend bf16->f32 operand
    legalization (hoisted whole-tensor converts >= 64 MiB)."""
    total = 0
    seen = set()
    for m in _CONVERT_RE.finditer(hlo):
        dims = m.group(1)
        if dims in seen:
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n * 4 >= 64 * 2**20:
            seen.add(dims)
            total += n * 4
    return total


def _save(outdir, tag, rec):
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, 'all' (assigned 10) or 'all+paper'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scans for exact per-layer cost analysis")
    ap.add_argument("--outdir", default="experiments/dryrun")
    args = ap.parse_args()

    archs = (ASSIGNED if args.arch == "all"
             else ARCH_IDS if args.arch == "all+paper"
             else [args.arch])
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    t0 = time.time()
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(run_cell(arch, shape, mp, args.unroll,
                                        args.outdir))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skipped, {n_fail} failed "
          f"in {time.time()-t0:.0f}s ==")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
