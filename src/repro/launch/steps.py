"""Step builders: train_step / prefill_step / serve_step per (arch x shape).

``build_cell`` returns everything the dry-run, roofline harness and the
runtime engine need: the step function, sharded ShapeDtypeStruct inputs,
in/out shardings and donation indices.  No device memory is allocated —
inputs are abstract until a caller materializes them.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat, optim
from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_lib
from repro.models import transformer as T
from repro.models.api import SHAPES, MeshAxes, ModelConfig, shape_applicable


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    mesh: Any
    axes: MeshAxes
    step: Callable
    in_sds: tuple              # ShapeDtypeStructs (sharded)
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    kind: str
    note: str = ""

    def jitted(self):
        return jax.jit(self.step, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        with compat.use_mesh(self.mesh):
            return self.jitted().lower(*self.in_sds)


def _ns(mesh, tree):
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _train_seq_hint(cfg, axes, tp):
    return shd.make_hint(cfg, axes, tp)


def build_cell(arch: str, shape_name: str, mesh, *, unroll: bool = False,
               opt_cfg: Optional[optim.AdamWConfig] = None,
               microbatches: int = 4,
               exact_microbatches: Optional[int] = None,
               train_regime: str = "tp") -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{arch} x {shape_name}: {why}")
    axes = mesh_lib.mesh_axes(mesh)
    tp = mesh.shape["model"]
    mesh_batch = mesh_lib.batch_extent(mesh)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        n_mb = (exact_microbatches if exact_microbatches
                else _auto_microbatches(cfg, B, S, mesh_batch, microbatches))
        return _build_train(cfg, arch, shape_name, mesh, axes, tp, mesh_batch,
                            B, S, unroll, opt_cfg or optim.AdamWConfig(),
                            n_mb, train_regime)
    if shape.kind == "prefill":
        return _build_prefill(cfg, arch, shape_name, mesh, axes, tp,
                              mesh_batch, B, S, unroll)
    return _build_decode(cfg, arch, shape_name, mesh, axes, tp, mesh_batch,
                         B, S, unroll)


# ---------------------------------------------------------------------------


def _auto_microbatches(cfg, B, S, mesh_batch, floor, target=2 * 2**30):
    """Pick the microbatch count so the per-device remat stash (one hidden
    state per layer per microbatch) stays under `target` bytes."""
    L = cfg.num_layers + cfg.encoder_layers
    n = 1
    while n < floor and B % (2 * n * mesh_batch) == 0:
        n *= 2
    per_layer = lambda nn: (B // mesh_batch // nn) * S * cfg.d_model * 2
    while (L * per_layer(n) > target and B % (2 * n * mesh_batch) == 0
           and B // mesh_batch // n > 1):
        n *= 2
    return n


def _batch_sds(cfg, kind, B, S, bspecs, mesh):
    out = {}
    if kind == "decode":
        out["tokens"] = jax.ShapeDtypeStruct(
            (B,), jnp.int32, sharding=NamedSharding(mesh, bspecs["tokens"]))
        out["lengths"] = jax.ShapeDtypeStruct(
            (B,), jnp.int32, sharding=NamedSharding(mesh, bspecs["lengths"]))
        return out
    S_text = S
    if cfg.family == "vlm":
        S_text = S - cfg.num_patches
        out["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, bspecs["patches"]))
    if cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, bspecs["frames"]))
    out["tokens"] = jax.ShapeDtypeStruct(
        (B, S_text if cfg.family == "vlm" else S), jnp.int32,
        sharding=NamedSharding(mesh, bspecs["tokens"]))
    if kind == "train":
        out["labels"] = jax.ShapeDtypeStruct(
            (B, S), jnp.int32, sharding=NamedSharding(mesh, bspecs["labels"]))
    return out


def _build_train(cfg, arch, shape_name, mesh, axes, tp, mesh_batch, B, S,
                 unroll, ocfg, n_mb=1, regime="tp"):
    if regime == "fsdp":
        # ZeRO-3: the whole mesh is the DP world; weights gather per layer
        axes = MeshAxes(batch=axes.batch + (axes.model,), model=None)
        mesh_batch = mesh.size
        n_mb = 1
    pspecs = shd.param_specs(cfg, axes, tp, regime, n_dev=mesh.size)
    bspecs = shd.batch_specs(cfg, axes, B, mesh_batch, "train")
    hint = shd.make_hint(cfg, axes, tp) if regime == "tp" else None
    n_dev = mesh.size
    flat_spec = P(tuple(a for a in (("pod",) if "pod" in mesh.axis_names else ())
                        + ("data", "model"))) if ocfg.zero1 else P()
    flat_sharding = NamedSharding(mesh, flat_spec)
    param_shardings = _ns(mesh, pspecs)

    def loss_fn(params, batch):
        return T.forward_loss(cfg, axes, params, batch, hint=hint, remat=True,
                              unroll=unroll)

    def _mb_split(x):
        # (B, ...) -> (n_mb, B/n_mb, ...), keeping the DP shard on dim 1
        y = x.reshape(n_mb, x.shape[0] // n_mb, *x.shape[1:])
        sp = P(None, axes.batch, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(y, sp)

    def train_step(state, batch):
        params, opt_state = state["params"], state["opt"]

        if n_mb > 1:
            # microbatch gradient accumulation (Alg. 1 sub-batching applied
            # at the training level): bounds the remat stash to one
            # microbatch; grads accumulate in fp32.
            mbs = jax.tree.map(_mb_split, batch)

            def mb_step(carry, mb):
                loss_sum, gacc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (loss_sum + l, gacc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                mb_step, (jnp.zeros((), jnp.float32), g0), mbs,
                unroll=unroll)
            loss = loss / n_mb
            grads = jax.tree.map(lambda g: g / n_mb, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        new_params, new_opt, gnorm = optim.apply_updates(
            ocfg, params, grads, opt_state, n_dev,
            flat_sharding=flat_sharding, param_shardings=param_shardings)
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss, "grad_norm": gnorm})

    params_sds = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    params_sds = jax.tree.map(
        lambda l, sp: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        params_sds, pspecs)
    opt_sds = jax.eval_shape(partial(optim.init_opt_state, n_dev=n_dev),
                             params_sds)
    opt_sds = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                       sharding=flat_sharding if l.ndim == 1
                                       else NamedSharding(mesh, P())),
        opt_sds)
    state_sds = {"params": params_sds, "opt": opt_sds}
    batch_sds = _batch_sds(cfg, "train", B, S, bspecs, mesh)

    state_sh = jax.tree.map(lambda l: l.sharding, state_sds)
    batch_sh = jax.tree.map(lambda l: l.sharding, batch_sds)
    metrics_sh = {"loss": NamedSharding(mesh, P()),
                  "grad_norm": NamedSharding(mesh, P())}
    return Cell(arch, shape_name, cfg, mesh, axes, train_step,
                (state_sds, batch_sds), (state_sh, batch_sh),
                (state_sh, metrics_sh), (0,), "train",
                note=shd.explain(cfg, tp))


def _build_prefill(cfg, arch, shape_name, mesh, axes, tp, mesh_batch, B, S,
                   unroll):
    pspecs = shd.param_specs(cfg, axes, tp, "tp")
    bspecs = shd.batch_specs(cfg, axes, B, mesh_batch, "prefill")
    cspecs = shd.cache_specs(cfg, axes, tp, B, mesh_batch)
    hint = shd.make_hint(cfg, axes, tp)

    def prefill_step(params, batch):
        logits, cache = T.prefill(cfg, axes, params, batch, hint=hint,
                                  unroll=unroll)
        return logits, cache

    params_sds = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    params_sds = jax.tree.map(
        lambda l, sp: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        params_sds, pspecs)
    batch_sds = _batch_sds(cfg, "prefill", B, S, bspecs, mesh)

    Bax = bspecs["tokens"][0]
    out_sh = (NamedSharding(mesh, P(Bax, None, None)), _ns(mesh, _prefill_cache_specs(cfg, axes, tp, B, mesh_batch, S)))
    return Cell(arch, shape_name, cfg, mesh, axes, prefill_step,
                (params_sds, batch_sds),
                (jax.tree.map(lambda l: l.sharding, params_sds),
                 jax.tree.map(lambda l: l.sharding, batch_sds)),
                out_sh, (), "prefill", note=shd.explain(cfg, tp))


def _prefill_cache_specs(cfg, axes, tp, B, mesh_batch, S):
    """Prefill emits the decode-layout cache (seq over model)."""
    return shd.cache_specs(cfg, axes, tp, B, mesh_batch)


def _build_decode(cfg, arch, shape_name, mesh, axes, tp, mesh_batch, B, S,
                  unroll):
    pspecs = shd.param_specs(cfg, axes, tp, "decode")
    bspecs = shd.batch_specs(cfg, axes, B, mesh_batch, "decode")
    cspecs = shd.cache_specs(cfg, axes, tp, B, mesh_batch)

    def serve_step(params, cache, tokens, lengths):
        next_tokens, new_cache = T.decode_step(cfg, axes, params, cache,
                                               tokens, lengths, unroll=unroll)
        return next_tokens, new_cache, lengths + 1

    params_sds = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    params_sds = jax.tree.map(
        lambda l, sp: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        params_sds, pspecs)
    cache_sds = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
    cache_sds = jax.tree.map(
        lambda l, sp: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        cache_sds, cspecs)
    tok_sds = _batch_sds(cfg, "decode", B, S, bspecs, mesh)

    Bax = bspecs["tokens"][0]
    out_sh = (NamedSharding(mesh, bspecs["tokens"]),
              jax.tree.map(lambda l: l.sharding, cache_sds),
              NamedSharding(mesh, bspecs["lengths"]))
    return Cell(arch, shape_name, cfg, mesh, axes, serve_step,
                (params_sds, cache_sds, tok_sds["tokens"], tok_sds["lengths"]),
                (jax.tree.map(lambda l: l.sharding, params_sds),
                 jax.tree.map(lambda l: l.sharding, cache_sds),
                 tok_sds["tokens"].sharding, tok_sds["lengths"].sharding),
                out_sh, (1,), "decode", note=shd.explain(cfg, tp))
