"""Production training driver: builds the train cell for an (arch) on the
production mesh and — on real hardware — runs the step loop with
checkpoint/restart.  On this CPU container, --dry lowers + compiles only
(see dryrun.py for the full matrix); --reduced actually trains a few steps.

    PYTHONPATH=src python -m repro.launch.train --arch llama3_2_1b --dry
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--dry", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.dry:
        import os

        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_count=512")
        from repro.launch import steps
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cell = steps.build_cell(args.arch, "train_4k", mesh)
        compiled = cell.lower().compile()
        ma = compiled.memory_analysis()
        print(f"{args.arch} train_4k compiled for {dict(mesh.shape)}; "
              f"peak/device={ (ma.argument_size_in_bytes + ma.temp_size_in_bytes + ma.output_size_in_bytes - ma.alias_size_in_bytes)/2**30:.2f} GiB")
        return

    # reduced real training on CPU
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import optim
    from repro.configs import reduced_config
    from repro.models import transformer as T
    from repro.models.api import MeshAxes
    from repro.runtime import checkpoint as ckpt

    cfg = reduced_config(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = optim.AdamWConfig(lr=1e-3, zero1=False)
    opt = optim.init_opt_state(params, n_dev=1)
    rng = np.random.default_rng(0)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.forward_loss(cfg, MeshAxes(), p, batch,
                                     remat=True))(params)
        params, opt, _ = optim.apply_updates(ocfg, params, grads, opt, 1)
        return params, opt, loss

    for i in range(args.steps):
        toks = jnp.asarray(rng.integers(2, cfg.vocab_size, (4, 32)), jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        params, opt, loss = step(params, opt, batch)
        if i % 5 == 0:
            print(f"step {i} loss {float(loss):.4f}")
    ckpt.save("/tmp/repro_train_ckpt", params, extra={"steps": args.steps})
    print("checkpoint saved to /tmp/repro_train_ckpt")


if __name__ == "__main__":
    main()
