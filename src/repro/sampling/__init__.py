"""On-device sampling subsystem: temperature / top-k / top-p decoding.

The subsystem has three layers:

* ``params``     — the host-side :class:`SamplingParams` dataclass carried
  on every :class:`~repro.core.coroutine.SequenceCoroutine`, plus
  ``pack_params`` which turns a list of per-sequence params into the
  (B,)-batched device arrays the jitted pipeline consumes.
* ``processors`` — pure jittable logit processors: penalties and
  temperature, then ONE joint top-k/top-p/min-p value threshold
  (``joint_threshold``) instead of a sort+softmax per filter.  Every
  stage is an exact identity at its parameter's default value, so a
  default-constructed SamplingParams run through the full pipeline
  reproduces greedy argmax bit-for-bit.
* ``sample``     — the batched ``sample`` entry point and the scan-step
  ``sample_step``, dispatching on a static :class:`SampleFlags` plan
  (``flags_for``): the Pallas fused-sampling kernel on TPU
  (``repro.kernels.fused_sampling``) or one of three shared-sort XLA
  tiers, with penalty/stop/greedy-select ops statically dropped when no
  active slot needs them; plus the deterministic PRNG-state helpers
  threaded as scan carry through the fused decode megastep.

Reproducibility contract: the key used for a sequence's t-th sampled
token is ``fold_in(PRNGKey(seed), t)`` — a pure function of the
per-sequence seed and the token index, never of batch composition, slot
index, page size or node placement.  Host-side state (penalty counts,
token index) is re-derivable from the coroutine's token list, so
YIELD/COMBINE/MIGRATE/PARTITION preserve the sampled stream exactly.
"""
from repro.sampling.params import (MAX_STOP_TOKENS, SamplingParams,
                                   derive_fork_seed, pack_params)
from repro.sampling.processors import (apply_min_p, apply_penalties,
                                       apply_temperature, apply_top_k,
                                       apply_top_p, joint_filter,
                                       joint_threshold, process_logits)
from repro.sampling.sample import (DEFAULT_FLAGS, SampleFlags, base_keys,
                                   base_keys_host, default_backend,
                                   flags_for, init_state, sample,
                                   sample_one, sample_step, step_keys,
                                   stop_hit, token_gumbel)

__all__ = [
    "MAX_STOP_TOKENS", "SamplingParams", "derive_fork_seed", "pack_params",
    "apply_penalties", "apply_temperature", "apply_top_k", "apply_top_p",
    "apply_min_p", "joint_threshold", "joint_filter", "process_logits",
    "DEFAULT_FLAGS", "SampleFlags", "base_keys", "base_keys_host",
    "default_backend", "flags_for", "init_state", "sample", "sample_one",
    "sample_step", "step_keys", "stop_hit", "token_gumbel",
]
