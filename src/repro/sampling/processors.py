"""Pure jittable logit processors — single-pass joint-threshold pipeline.

The PR 2 pipeline ran three INDEPENDENT full-vocab sorts and three
softmaxes per slot per step (one inside each of top-k / top-p / min-p).
At V≈128k that is O(V log V) sorted (B, V) temporaries per step — pure
HBM-bandwidth loss in the regime where decode should be memory bound
("Mind the Memory Gap", Recasens et al. 2025).  The key observation is
that every one of the three filters is a *value threshold*:

    top-k   keeps  x >= tau_k     (tau_k = k-th largest logit)
    top-p   keeps  x >= tau_p     (tau_p = smallest logit of the nucleus
                                   of the top-k-filtered distribution)
    min-p   keeps  x >= tau_m     (tau_m = max + log(min_p): the
                                   renormalisation after earlier filters
                                   cancels on both sides of the compare)

and because each filter keeps a top-segment of the value order, their
sequential composition is exactly ``x >= max(tau_k, tau_p, tau_m)``.  So
the whole pipeline needs ONE sort (to read tau_k and the sorted row
top-p integrates over) and ONE softmax (top-p's nucleus mass) — computed
by :func:`joint_threshold` — instead of a sort+softmax per filter.

Three statically-selected tiers share the same threshold semantics
(``SampleFlags.kc`` in sample.py picks one per megastep):

* ``kc == 0``  — full shared sort (any row may need the whole
  distribution, i.e. top-p enabled with top-k disabled);
* ``kc > 0``   — ``lax.top_k(x, kc)`` partial sort: when every row that
  enables top-p also enables top-k (k <= kc), the nucleus is contained
  in the top-kc lanes, so the O(V log V) sort drops to O(V log kc) and
  stays flat as V grows to 128k;
* ``kc == -1`` — no sort at all (only temperature / min-p / penalties
  active anywhere: tau_m needs just the row max).

On TPU the XLA tiers are the *fallback*; the Pallas kernel in
``repro.kernels.fused_sampling`` derives the same joint threshold with a
tiled histogram refinement and no materialised sorted copies at all.

Every processor remains an EXACT identity at its parameter's disabled
value: dividing by a 1.0 penalty and scaling by a 1.0 temperature are
exact float ops, and the joint threshold degrades to -inf when all three
filters are disabled, so ``jnp.where(x >= -inf, x, _)`` returns ``x``
bit-for-bit.  That exactness is what lets ``SamplingParams()`` reproduce
PR 1's argmax megastep (tests/test_sampling.py::test_greedy_parity).

The per-filter reference processors (`apply_top_k` / `apply_top_p` /
`apply_min_p`) are kept as the executable specification of each filter's
semantics — tests assert the joint threshold matches their composition.

All processors are batched across device slots with ``jax.vmap`` in
sample.py — never loop over slots on the host.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30      # matches the attention-mask convention in models/


def apply_penalties(logits, counts_full, counts_gen, rep, pres, freq):
    """Repetition / presence / frequency penalties for one slot.

    logits (V,) f32; counts_full (V,) int32 occurrence counts over
    prompt+generated; counts_gen (V,) int32 over generated tokens only;
    rep/pres/freq scalars.  Repetition follows the HF full-context
    convention (divides positive logits, multiplies negative ones, for
    any token seen in prompt OR output); presence/frequency follow the
    OpenAI/vLLM convention and penalize only tokens the model itself
    generated (a prompt that repeats a token must not pre-ban it).
    """
    seen = counts_full > 0
    rep_l = jnp.where(logits > 0, logits / rep, logits * rep)
    logits = jnp.where(seen, rep_l, logits)
    cg = counts_gen.astype(jnp.float32)
    return logits - freq * cg - pres * (counts_gen > 0).astype(jnp.float32)


def apply_temperature(logits, temperature):
    """Scale by 1/T; T <= 0 (greedy) leaves logits untouched — the
    sampler takes the argmax branch in that case."""
    scale = jnp.where(temperature > 0.0, temperature, 1.0)
    return logits / scale


# ---------------------------------------------------------------------------
# reference per-filter processors (executable spec; not on the hot path)
# ---------------------------------------------------------------------------


def apply_top_k(logits, k):
    """Keep the k highest logits (k == 0 disables).  Ties at the k-th
    value are all kept (standard behavior)."""
    V = logits.shape[-1]
    kth_idx = jnp.clip(k - 1, 0, V - 1)
    kth = jnp.sort(logits)[::-1][kth_idx]
    keep = (logits >= kth) | (k <= 0)
    return jnp.where(keep, logits, _NEG_INF)


def apply_top_p(logits, p):
    """Nucleus sampling: keep the smallest prefix of the sorted
    distribution whose cumulative probability reaches p (p >= 1 disables).
    The top token is always kept (exclusive-cumsum comparison)."""
    sl = jnp.sort(logits)[::-1]
    probs = jax.nn.softmax(sl)
    cum_excl = jnp.cumsum(probs) - probs
    kept = jnp.where(cum_excl < p, sl, jnp.inf)
    kth = jnp.min(kept)
    keep = (logits >= kth) | (p >= 1.0)
    return jnp.where(keep, logits, _NEG_INF)


def apply_min_p(logits, min_p):
    """Drop tokens whose probability is below min_p * max probability
    (min_p == 0 disables)."""
    probs = jax.nn.softmax(logits)
    keep = (probs >= min_p * jnp.max(probs)) | (min_p <= 0.0)
    return jnp.where(keep, logits, _NEG_INF)


# ---------------------------------------------------------------------------
# single-pass joint threshold (the hot path)
# ---------------------------------------------------------------------------


def joint_threshold(logits, k, p, min_p, kc: int = 0):
    """The single value ``tau`` such that the top-k -> top-p -> min-p
    composition keeps exactly ``{x : x >= tau}``.  -inf when all three
    filters are disabled (k <= 0, p >= 1, min_p <= 0).

    Shape-generic over leading batch dims: logits (..., V) with k/p/min_p
    (...,) — the hot path calls it BATCHED on (B, V) rather than under
    ``jax.vmap`` (vmapping the sorted-row reductions lowers to gathers an
    order of magnitude slower than the native batched ops on CPU).

    ``kc`` is the static tier described in the module docstring: 0 =
    full sort, > 0 = ``lax.top_k(x, kc)`` partial sort (valid when every
    DRAWING row has 0 < top_k <= kc — a filterless temperature-only row
    needs the whole distribution and forces the full tier, see
    ``sample.flags_for``), -1 = sortless (valid when no row enables
    top-k or top-p).  Tiers agree on the kept SET for distinct logit
    values; nucleus boundaries may differ by float-reduction order, and
    the partial tier truncates k-th-value TIES that extend past the kc
    lanes (apply_top_k keeps all ties) — which is why the tier is fixed
    per megastep (sample.py).
    """
    k, p, min_p = (jnp.asarray(v) for v in (k, p, min_p))
    if kc < 0:
        return jnp.where(min_p > 0.0,
                         jnp.max(logits, axis=-1) + jnp.log(min_p),
                         -jnp.inf)
    if kc == 0:
        sl = jnp.flip(jnp.sort(logits, axis=-1), -1)  # the ONE sort
    else:
        sl = jax.lax.top_k(logits, kc)[0]             # partial sort
    return tau_from_sorted_rows(sl, k, p, min_p)


def tau_from_sorted_rows(sl, k, p, min_p):
    """Joint threshold from descending(-prefix) rows ``sl`` (..., cap) —
    the full sorted row (cap == V) or the top-kc lanes.  Shared by
    `joint_threshold` and the lane-tier sampler so the nucleus-edge
    semantics live in exactly one place."""
    cap = sl.shape[-1]
    idx = jnp.clip(k - 1, 0, cap - 1).astype(jnp.int32)
    kth = jnp.take_along_axis(sl, idx[..., None], axis=-1)[..., 0]
    tau_k = jnp.where(k > 0, kth, -jnp.inf)
    slk = jnp.where(sl >= tau_k[..., None], sl, _NEG_INF)
    probs = jax.nn.softmax(slk, axis=-1)              # the ONE softmax
    cum_excl = jnp.cumsum(probs, axis=-1) - probs
    kept = jnp.where(cum_excl < p[..., None], slk, jnp.inf)
    tau_p = jnp.where(p < 1.0, jnp.min(kept, axis=-1), -jnp.inf)
    tau_m = jnp.where(min_p > 0.0, sl[..., 0] + jnp.log(min_p), -jnp.inf)
    return jnp.maximum(jnp.maximum(tau_k, tau_p), tau_m)


def joint_filter(logits, k, p, min_p, kc: int = 0):
    """Mask everything below the joint threshold to ``_NEG_INF``."""
    tau = joint_threshold(logits, k, p, min_p, kc)
    return jnp.where(logits >= tau[..., None], logits, _NEG_INF)


def process_logits(logits, counts_full, counts_gen, sp_row, *,
                   pen: bool = True, kc: int = 0):
    """Full pipeline for one slot: penalties -> temperature -> joint
    top-k/top-p/min-p threshold filter.  ``sp_row`` is one row of the
    pack_params arrays.  ``pen=False`` (static) skips the penalty ops
    entirely — the engine sets it when no active slot enables any
    penalty, dropping the per-step (B, V) count reads from the megastep.
    """
    logits = logits.astype(jnp.float32)
    if pen:
        logits = apply_penalties(logits, counts_full, counts_gen,
                                 sp_row["repetition_penalty"],
                                 sp_row["presence_penalty"],
                                 sp_row["frequency_penalty"])
    logits = apply_temperature(logits, sp_row["temperature"])
    return joint_filter(logits, sp_row["top_k"], sp_row["top_p"],
                        sp_row["min_p"], kc)
