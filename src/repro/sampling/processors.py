"""Pure jittable logit processors.

Each processor takes a single slot's logits row (V,) float32 plus scalar
parameters and returns a transformed row.  They compose in the standard
order (penalties -> temperature -> top-k -> top-p -> min-p) and every one
of them is an EXACT identity at its parameter's disabled value: dividing
by a 1.0 penalty and scaling by a 1.0 temperature are exact float ops, and
the masks are gated with ``jnp.where`` on the disabled predicate.  That
exactness is what lets ``SamplingParams()`` reproduce PR 1's argmax
megastep token-for-token (tests/test_sampling.py::test_greedy_parity).

All processors are batched across device slots with ``jax.vmap`` in
sample.py — never loop over slots on the host.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30      # matches the attention-mask convention in models/


def apply_penalties(logits, counts_full, counts_gen, rep, pres, freq):
    """Repetition / presence / frequency penalties for one slot.

    logits (V,) f32; counts_full (V,) int32 occurrence counts over
    prompt+generated; counts_gen (V,) int32 over generated tokens only;
    rep/pres/freq scalars.  Repetition follows the HF full-context
    convention (divides positive logits, multiplies negative ones, for
    any token seen in prompt OR output); presence/frequency follow the
    OpenAI/vLLM convention and penalize only tokens the model itself
    generated (a prompt that repeats a token must not pre-ban it).
    """
    seen = counts_full > 0
    rep_l = jnp.where(logits > 0, logits / rep, logits * rep)
    logits = jnp.where(seen, rep_l, logits)
    cg = counts_gen.astype(jnp.float32)
    return logits - freq * cg - pres * (counts_gen > 0).astype(jnp.float32)


def apply_temperature(logits, temperature):
    """Scale by 1/T; T <= 0 (greedy) leaves logits untouched — the
    sampler takes the argmax branch in that case."""
    scale = jnp.where(temperature > 0.0, temperature, 1.0)
    return logits / scale


def apply_top_k(logits, k):
    """Keep the k highest logits (k == 0 disables).  Ties at the k-th
    value are all kept (standard behavior)."""
    V = logits.shape[-1]
    kth_idx = jnp.clip(k - 1, 0, V - 1)
    kth = jnp.sort(logits)[::-1][kth_idx]
    keep = (logits >= kth) | (k <= 0)
    return jnp.where(keep, logits, _NEG_INF)


def apply_top_p(logits, p):
    """Nucleus sampling: keep the smallest prefix of the sorted
    distribution whose cumulative probability reaches p (p >= 1 disables).
    The top token is always kept (exclusive-cumsum comparison)."""
    sl = jnp.sort(logits)[::-1]
    probs = jax.nn.softmax(sl)
    cum_excl = jnp.cumsum(probs) - probs
    kept = jnp.where(cum_excl < p, sl, jnp.inf)
    kth = jnp.min(kept)
    keep = (logits >= kth) | (p >= 1.0)
    return jnp.where(keep, logits, _NEG_INF)


def apply_min_p(logits, min_p):
    """Drop tokens whose probability is below min_p * max probability
    (min_p == 0 disables)."""
    probs = jax.nn.softmax(logits)
    keep = (probs >= min_p * jnp.max(probs)) | (min_p <= 0.0)
    return jnp.where(keep, logits, _NEG_INF)


def process_logits(logits, counts_full, counts_gen, sp_row):
    """Full pipeline for one slot: penalties -> temperature -> top-k ->
    top-p -> min-p.  ``sp_row`` is one row of the pack_params arrays."""
    logits = logits.astype(jnp.float32)
    logits = apply_penalties(logits, counts_full, counts_gen,
                             sp_row["repetition_penalty"],
                             sp_row["presence_penalty"],
                             sp_row["frequency_penalty"])
    logits = apply_temperature(logits, sp_row["temperature"])
    logits = apply_top_k(logits, sp_row["top_k"])
    logits = apply_top_p(logits, sp_row["top_p"])
    logits = apply_min_p(logits, sp_row["min_p"])
    return logits
