"""Batched sampling entry point + the scan-carried PRNG/penalty state.

Key discipline
--------------
The key for a sequence's t-th generated token is
``fold_in(PRNGKey(seed), t)`` — derived fresh every step from the
constant per-slot base key and a carried token counter, NOT an evolving
split chain.  A split chain would make the stream depend on how many
scan steps the slot sat masked in (batch composition) and would be
unreconstructible after MIGRATE; fold_in(base, t) is a pure function of
(seed, t), so any node can resume the stream from the coroutine's token
count alone.

Sampling itself is the Gumbel-max trick: ``argmax(logits + gumbel)`` is
an exact categorical draw from ``softmax(logits)``, costs one argmax (no
cumsum search), and degrades to plain argmax when temperature <= 0.

State carried per slot through the megastep scan:
* ``gen_count``     (B,) int32   — tokens generated so far (key index)
* ``counts``        (B, V) int32 — generated-token counts (presence/
  frequency penalties; advanced in-scan)
* ``prompt_counts`` (B, V) int32 — prompt-token counts (loop-invariant;
  repetition penalty sees prompt_counts + counts)

All are re-derivable host-side from the coroutine (len(generated),
bincounts of generated / prompt), which is why YIELD/COMBINE/MIGRATE need
no extra device state movement.
"""
from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def base_keys(seeds) -> jnp.ndarray:
    """(B,) uint32 seeds -> (B, 2) raw threefry key array."""
    return jax.vmap(lambda s: jax.random.PRNGKey(s))(
        jnp.asarray(seeds, jnp.uint32))


def _bincounts(token_lists, vocab: int) -> np.ndarray:
    out = np.zeros((len(token_lists), vocab), np.int32)
    for i, toks in enumerate(token_lists):
        if toks:
            out[i] = np.bincount(
                np.asarray(toks, np.int64), minlength=vocab)[:vocab]
    return out


def init_state(seeds, prompt_lists, generated_lists,
               vocab: int) -> Dict[str, np.ndarray]:
    """Host-side state for a batch of slots (install/prefill time).

    seeds (B,) ints; prompt_lists / generated_lists: per-slot token ids
    (penalty counts and the PRNG position are recomputed from them, never
    migrated as device state)."""
    return {"seed": np.asarray(seeds, np.uint32),
            "gen_count": np.asarray([len(g) for g in generated_lists],
                                    np.int32),
            "counts": _bincounts(generated_lists, vocab),
            "prompt_counts": _bincounts(prompt_lists, vocab)}


def step_keys(base, gen_count):
    """Per-slot key for the current step: fold_in(base_b, gen_count_b)."""
    return jax.vmap(jax.random.fold_in)(base, gen_count)


def sample_one(logits, counts_full, counts_gen, sp_row, key):
    """Sample one token for one slot.  logits (V,) f32, counts_* (V,)
    i32, sp_row: one row of pack_params arrays, key: (2,) raw PRNG key.
    Returns int32 token id."""
    from repro.sampling.processors import process_logits

    proc = process_logits(logits, counts_full, counts_gen, sp_row)
    greedy_tok = jnp.argmax(proc)
    gumbel = jax.random.gumbel(key, proc.shape, jnp.float32)
    sampled_tok = jnp.argmax(proc + gumbel)
    return jnp.where(sp_row["temperature"] <= 0.0, greedy_tok,
                     sampled_tok).astype(jnp.int32)


def sample(logits, counts_full, counts_gen, sp, keys):
    """Batched sampling, vmapped across device slots.

    logits (B, V), counts_* (B, V), sp: dict of (B,)-rows from
    pack_params (the "stop"/"seed" entries are ignored here), keys (B, 2).
    """
    rows = {k: sp[k] for k in ("temperature", "top_k", "top_p", "min_p",
                               "repetition_penalty", "presence_penalty",
                               "frequency_penalty")}
    return jax.vmap(sample_one)(logits, counts_full, counts_gen, rows,
                                keys)


def stop_hit(tokens, stop_table):
    """(B,) bool: did slot b's token land in its stop set?  stop_table
    (B, MAX_STOP_TOKENS) int32 padded with -1 (never matches)."""
    return jnp.any(tokens[:, None] == stop_table, axis=1)


def sample_step(logits, remaining, state, sp):
    """One fused-megastep sampling step for the whole batch.

    Consumes the (B, V) logits the model head produced, draws one token
    per slot with the per-slot fold_in key, and advances the carried
    state for LIVE slots only (masked slots must not consume randomness
    or counts, or batch composition would perturb the stream).

    Returns (next_tokens (B,) i32, live (B,) bool, new_remaining, new_state).
    Stop-token hits zero the slot's remaining AFTER the stop token is
    emitted, exactly mirroring the host-side truncation.
    """
    base = state["base_key"]
    gen_count = state["gen_count"]
    counts = state["counts"]
    prompt_counts = state["prompt_counts"]
    keys = step_keys(base, gen_count)
    nxt = sample(logits, prompt_counts + counts, counts, sp, keys)
    live = remaining > 0
    hit = stop_hit(nxt, sp["stop"]) & live
    B = nxt.shape[0]
    counts = counts.at[jnp.arange(B), nxt].add(live.astype(jnp.int32))
    gen_count = gen_count + live.astype(jnp.int32)
    new_remaining = jnp.where(hit, 0, remaining - live.astype(jnp.int32))
    return nxt, live, new_remaining, {
        "base_key": base, "gen_count": gen_count, "counts": counts,
        "prompt_counts": prompt_counts}
