"""Batched sampling entry point + the scan-carried PRNG/penalty state.

Key discipline
--------------
The key for a sequence's t-th generated token is
``fold_in(PRNGKey(seed), t)`` — derived fresh every step from the
constant per-slot base key and a carried token counter, NOT an evolving
split chain.  A split chain would make the stream depend on how many
scan steps the slot sat masked in (batch composition) and would be
unreconstructible after MIGRATE; fold_in(base, t) is a pure function of
(seed, t), so any node can resume the stream from the coroutine's token
count alone.

Sampling itself is the Gumbel-max trick: ``argmax(logits + gumbel)`` is
an exact categorical draw from ``softmax(logits)``, costs one argmax (no
cumsum search), and degrades to plain argmax when temperature <= 0.  The
noise is ADDRESSABLE per token: ``token_gumbel`` hashes
``fold_in(step_key, token_id)``, so the lane tier generates noise for
just its kc lane ids, the full tiers for ``arange(V)``, and the Pallas
kernel consumes the same rows as an input — every path realizes the
bitwise-identical (seed, t, token) -> noise mapping.

Static execution plan (:class:`SampleFlags`)
--------------------------------------------
The logit pipeline has three data-independent degrees of freedom that
are wasteful to decide on device every step, so the engine derives them
ON THE HOST from the active batch's SamplingParams and bakes them into
the megastep executable (they are part of the jit cache key):

* ``backend`` — ``"pallas"`` routes the filter + draw through the fused
  single-pass kernel (``repro.kernels.fused_sampling``); ``"xla"`` is
  the shared-sort fallback for platforms where Pallas interpret mode is
  slow (CPU CI).  ``"pallas_interpret"`` runs the kernel interpreted
  (tests).
* ``pen`` — False drops the penalty ops AND the per-step (B, V) count
  updates from the scan when no active slot enables a penalty.
* ``kc`` — the sort tier of ``processors.joint_threshold``: 0 full
  sort, > 0 partial ``lax.top_k`` sort, -1 sortless.

Every tier (and the kernel) consumes the same TOKEN-indexed noise from
the same fold_in key and computes the same kept set, so the realized
stream is a pure function of (seed, t, logits) no matter which tier the
batch composition selects — the PR 2 reproducibility contract survives
the tiering.  The only residual flags-sensitivity is float-reduction
order in the nucleus-mass sums (kc lanes vs V entries), which can flip
a nucleus-boundary token only when its exclusive cumulative mass lands
within an ulp of ``top_p``.

State carried per slot through the megastep scan:
* ``gen_count``     (B,) int32   — tokens generated so far (key index)
* ``counts``        (B, V) int32 — generated-token counts (presence/
  frequency penalties; advanced in-scan only when ``flags.pen``)
* ``prompt_counts`` (B, V) int32 — prompt-token counts (loop-invariant;
  repetition penalty sees prompt_counts + counts)

All are re-derivable host-side from the coroutine (len(generated),
bincounts of generated / prompt), which is why YIELD/COMBINE/MIGRATE need
no extra device state movement.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.sampling.processors import apply_penalties, apply_temperature


@dataclasses.dataclass(frozen=True)
class SampleFlags:
    """Static (host-decided, jit-keyed) execution plan for one megastep."""
    backend: str = "xla"     # "xla" | "pallas" | "pallas_interpret"
    pen: bool = True         # any penalty enabled in the active batch
    kc: int = 0              # sort tier: 0 full, >0 top-kc, -1 sortless
    mixed: bool = True       # any greedy (temperature <= 0) row present
    stops: bool = True       # any stop-token set non-empty


DEFAULT_FLAGS = SampleFlags()


def default_backend() -> str:
    """Kernel on real TPUs, shared-sort XLA everywhere else.  Override
    with REPRO_SAMPLING_BACKEND=xla|pallas|pallas_interpret."""
    env = os.environ.get("REPRO_SAMPLING_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def flags_for(sps, vocab: int) -> SampleFlags:
    """Derive the static plan from the active slots' SamplingParams.

    ``kc`` buckets the top-k cap to a pow2 (floor 8) so slot churn does
    not mint a new executable per distinct k.  The lane tier (kc > 0)
    requires EVERY drawing row (temperature > 0, not greedy-default) to
    have top-k active — a filterless or top-p-only row samples from a
    set no static cap bounds, so any such row forces the full-sort tier;
    greedy rows are fine either way (lane 0 IS the argmax)."""
    act = [s for s in sps if not s.is_greedy_default]
    pen = any(s.repetition_penalty != 1.0 or s.presence_penalty != 0.0
              or s.frequency_penalty != 0.0 for s in act)
    drawing = [s for s in act if s.temperature > 0.0]
    ks = [s.top_k for s in drawing if s.top_k > 0]
    if drawing and all(s.top_k > 0 for s in drawing):
        kc = max(_pow2(max(ks)), 8)
        if kc >= vocab:
            kc = 0
    elif any(s.top_k > 0 or s.top_p < 1.0 for s in drawing):
        kc = 0
    else:
        kc = -1
    return SampleFlags(backend=default_backend(), pen=pen, kc=kc,
                       mixed=any(s.temperature <= 0.0 for s in sps),
                       stops=any(s.stop for s in sps))


def base_keys_host(seeds) -> np.ndarray:
    """(B,) uint32 seeds -> (B, 2) raw threefry key array, built on the
    host: ``PRNGKey(s)`` for a 32-bit seed is just ``[0, s]`` (verified
    against ``jax.random.PRNGKey`` in tests), so slot installs never pay
    an eager device dispatch for key derivation."""
    seeds = np.asarray(seeds, np.uint32)
    return np.stack([np.zeros_like(seeds), seeds], axis=-1)


def base_keys(seeds) -> jnp.ndarray:
    """(B,) uint32 seeds -> (B, 2) raw threefry key array."""
    return jnp.asarray(base_keys_host(seeds))


def _bincounts(token_lists, vocab: int) -> np.ndarray:
    out = np.zeros((len(token_lists), vocab), np.int32)
    for i, toks in enumerate(token_lists):
        if toks:
            out[i] = np.bincount(
                np.asarray(toks, np.int64), minlength=vocab)[:vocab]
    return out


def init_state(seeds, prompt_lists, generated_lists,
               vocab: int) -> Dict[str, np.ndarray]:
    """Host-side state for a batch of slots (install/prefill time).

    seeds (B,) ints; prompt_lists / generated_lists: per-slot token ids
    (penalty counts and the PRNG position are recomputed from them, never
    migrated as device state)."""
    return {"seed": np.asarray(seeds, np.uint32),
            "gen_count": np.asarray([len(g) for g in generated_lists],
                                    np.int32),
            "counts": _bincounts(generated_lists, vocab),
            "prompt_counts": _bincounts(prompt_lists, vocab)}


def step_keys(base, gen_count):
    """Per-slot key for the current step: fold_in(base_b, gen_count_b)."""
    return jax.vmap(jax.random.fold_in)(base, gen_count)


def token_gumbel(keys, ids):
    """ADDRESSABLE per-token Gumbel noise: g[b, j] is a pure function of
    (keys[b], ids[b, j]) — one threefry hash per addressed token, the
    uniform taken from the first word of ``fold_in(key, token_id)``.

    Token-indexed addressing is what lets every tier realize the
    bitwise-identical stream while generating only the noise it needs:
    the lane tier hashes its kc lane ids (V-independent), the full tiers
    hash ``arange(V)``, and the Pallas kernel consumes the same rows as
    an input.  keys (B, 2) uint32; ids (B, I) int32 -> (B, I) f32."""
    bits = jax.vmap(lambda k, row: jax.vmap(
        lambda v: jax.random.fold_in(k, v))(row))(keys, ids)[..., 0]
    u = (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2 ** -24)
    u = u + jnp.float32(2 ** -25)        # (0, 1): log(log) stays finite
    return -jnp.log(-jnp.log(u))


def _gumbel_rows(keys, V: int):
    """(B, V) full-vocab noise rows: ``token_gumbel`` at every id."""
    B = keys.shape[0]
    ids = jnp.broadcast_to(jnp.arange(V, dtype=jnp.int32)[None], (B, V))
    return token_gumbel(keys, ids)


def sample_one(logits, counts_full, counts_gen, sp_row, key,
               flags: SampleFlags = DEFAULT_FLAGS):
    """Sample one token for one slot.  logits (V,) f32, counts_* (V,)
    i32, sp_row: one row of pack_params arrays, key: (2,) raw PRNG key.
    Returns int32 token id.

    Reference single-row form.  The hot path (`sample` / `sample_step`)
    is natively batched and tier-selected but consumes the same
    token-indexed Gumbel row, so it realizes the same draw as this
    function (up to nucleus-boundary ulp ties, see `flags_for`)."""
    from repro.sampling.processors import process_logits

    proc = process_logits(logits, counts_full, counts_gen, sp_row,
                          pen=flags.pen, kc=flags.kc)
    greedy_tok = jnp.argmax(proc)
    gumbel = token_gumbel(key[None], jnp.arange(
        proc.shape[-1], dtype=jnp.int32)[None])[0]
    sampled_tok = jnp.argmax(proc + gumbel)
    return jnp.where(sp_row["temperature"] <= 0.0, greedy_tok,
                     sampled_tok).astype(jnp.int32)


def _processed(logits, counts_full, counts_gen, sp, flags: SampleFlags):
    """Batched penalties + temperature (the cheap elementwise prefix the
    kernel does not fold in)."""
    x = logits.astype(jnp.float32)
    if flags.pen:
        x = jax.vmap(apply_penalties)(x, counts_full, counts_gen,
                                      sp["repetition_penalty"],
                                      sp["presence_penalty"],
                                      sp["frequency_penalty"])
    return jax.vmap(apply_temperature)(x, sp["temperature"])


def _xla_lanes(raw, tokens, lp_k: int):
    """Logprob lanes from raw logits — bitwise-identical math to
    models.transformer.pack_logprob_block (log_softmax + lax.top_k)."""
    lp = jax.nn.log_softmax(raw.astype(jnp.float32), axis=-1)
    chosen = jnp.take_along_axis(lp, tokens[:, None].astype(jnp.int32),
                                 axis=1)[:, 0]
    lanes = {"chosen_lp": chosen, "top_vals": None, "top_idx": None}
    if lp_k > 0:
        lanes["top_vals"], lanes["top_idx"] = jax.lax.top_k(lp, lp_k)
    return lanes


def _sample_impl(logits, counts_full, counts_gen, sp, keys,
                 flags: SampleFlags, raw=None, lp_k: Optional[int] = None):
    """Shared batched core: returns (tokens (B,) i32, lanes | None).

    ``raw`` (B, V) are the PRE-pipeline model logits the logprob plane
    reports (PR 3 contract: logprobs are pre-filter); lanes are computed
    in the same kernel invocation on the pallas path, or with one
    log_softmax + lax.top_k on the XLA path."""
    if flags.backend in ("pallas", "pallas_interpret"):
        from repro.kernels.fused_sampling.ops import fused_sample

        proc = _processed(logits, counts_full, counts_gen, sp, flags)
        gumbel = _gumbel_rows(keys, proc.shape[-1])
        out = fused_sample(proc, gumbel, sp["top_k"], sp["top_p"],
                           sp["min_p"], raw=raw,
                           lp_k=0 if lp_k is None else max(lp_k, 0),
                           with_lanes=lp_k is not None,
                           interpret=flags.backend == "pallas_interpret")
        tokens = (jnp.where(sp["temperature"] <= 0.0, out["greedy"],
                            out["sampled"]) if flags.mixed
                  else out["sampled"]).astype(jnp.int32)
        lanes = None
        if lp_k is not None:
            logz = out["m_raw"] + jnp.log(out["l_raw"])
            lanes = {"chosen_lp": jnp.take_along_axis(
                         raw.astype(jnp.float32),
                         tokens[:, None], axis=1)[:, 0] - logz,
                     "top_vals": None, "top_idx": None}
            if lp_k > 0:
                lanes["top_vals"] = out["top_vals"] - logz[:, None]
                lanes["top_idx"] = out["top_idx"]
        return tokens, lanes

    # XLA fallback, natively batched (vmapping the sorted-row reductions
    # is an order of magnitude slower on CPU) — same keep-set as
    # sample_one's per-row pipeline
    if flags.kc > 0:
        tokens = _sample_topk_lanes(logits, counts_full, counts_gen, sp,
                                    keys, flags)
    else:
        from repro.sampling.processors import _NEG_INF, joint_threshold
        proc = _processed(logits, counts_full, counts_gen, sp, flags)
        tau = joint_threshold(proc, sp["top_k"], sp["top_p"], sp["min_p"],
                              flags.kc)
        proc = jnp.where(proc >= tau[:, None], proc, _NEG_INF)
        sampled = jnp.argmax(proc + _gumbel_rows(keys, proc.shape[-1]),
                             axis=-1)
        if flags.mixed:
            greedy = jnp.argmax(proc, axis=-1)
            sampled = jnp.where(sp["temperature"] <= 0.0, greedy, sampled)
        tokens = sampled.astype(jnp.int32)
    lanes = _xla_lanes(raw, tokens, lp_k) if lp_k is not None else None
    return tokens, lanes


def _sample_topk_lanes(logits, counts_full, counts_gen, sp, keys,
                       flags: SampleFlags):
    """Top-kc-tier draw in the (B, kc) top-k lanes.

    When every drawing row has top-k active (<= kc), the kept set is
    contained in the top-kc lanes, so after ONE ``lax.top_k`` over the
    (penalized) logits the temperature, the three thresholds and the
    argmax all run on (B, kc) instead of (B, V) — the per-step sort work
    drops from O(V log V) to O(V log kc) (the benchmark sweep's
    large-vocab scaling) and the greedy token is lane 0 for free.
    Gumbel noise stays TOKEN-indexed: the full fold_in(seed, t) noise
    row is drawn and gathered at the lane token ids, so every tier, the
    Pallas kernel and the looped baseline realize the identical stream
    for the same (seed, t, logits) regardless of which tier the batch
    composition selects."""
    from repro.sampling.processors import _NEG_INF, tau_from_sorted_rows

    x = logits.astype(jnp.float32)
    if flags.pen:
        x = jax.vmap(apply_penalties)(x, counts_full, counts_gen,
                                      sp["repetition_penalty"],
                                      sp["presence_penalty"],
                                      sp["frequency_penalty"])
    sl, si = jax.lax.top_k(x, flags.kc)              # the ONE (B, V) pass
    scale = jnp.where(sp["temperature"] > 0.0, sp["temperature"], 1.0)
    sl = sl / scale[:, None]
    tau = tau_from_sorted_rows(sl, sp["top_k"], sp["top_p"], sp["min_p"])
    masked = jnp.where(sl >= tau[:, None], sl, _NEG_INF)
    g = token_gumbel(keys, si)           # noise for the kc lane ids only
    lane = jnp.argmax(masked + g, axis=-1)
    sampled = jnp.take_along_axis(si, lane[:, None], axis=-1)[:, 0]
    if flags.mixed:
        sampled = jnp.where(sp["temperature"] <= 0.0, si[:, 0], sampled)
    return sampled.astype(jnp.int32)


def sample(logits, counts_full, counts_gen, sp, keys,
           flags: SampleFlags = DEFAULT_FLAGS):
    """Batched sampling across device slots.

    logits (B, V), counts_* (B, V), sp: dict of (B,)-rows from
    pack_params (the "stop"/"seed" entries are ignored here), keys (B, 2).
    """
    return _sample_impl(logits, counts_full, counts_gen, sp, keys,
                        flags)[0]


def stop_hit(tokens, stop_table):
    """(B,) bool: did slot b's token land in its stop set?  stop_table
    (B, MAX_STOP_TOKENS) int32 padded with -1 (never matches)."""
    return jnp.any(tokens[:, None] == stop_table, axis=1)


def sample_step(logits, remaining, state, sp,
                flags: SampleFlags = DEFAULT_FLAGS,
                lp_k: Optional[int] = None):
    """One fused-megastep sampling step for the whole batch.

    Consumes the (B, V) logits the model head produced, draws one token
    per slot with the per-slot fold_in key, and advances the carried
    state for LIVE slots only (masked slots must not consume randomness
    or counts, or batch composition would perturb the stream).

    Returns ``(next_tokens (B,) i32, live (B,) bool, new_remaining,
    new_state)`` — plus a lanes dict appended when ``lp_k`` is not None
    (the pre-filter logprob lanes for the transfer plane, computed from
    the RAW logits in the same pass on the kernel path).

    Stop-token hits zero the slot's remaining AFTER the stop token is
    emitted, exactly mirroring the host-side truncation.
    """
    base = state["base_key"]
    gen_count = state["gen_count"]
    counts = state["counts"]
    prompt_counts = state["prompt_counts"]
    keys = step_keys(base, gen_count)
    cf = prompt_counts + counts if flags.pen else counts
    nxt, lanes = _sample_impl(logits, cf, counts, sp, keys, flags,
                              raw=logits if lp_k is not None else None,
                              lp_k=lp_k)
    live = remaining > 0
    if flags.pen:
        B = nxt.shape[0]
        counts = counts.at[jnp.arange(B), nxt].add(live.astype(jnp.int32))
    gen_count = gen_count + live.astype(jnp.int32)
    new_remaining = remaining - live.astype(jnp.int32)
    if flags.stops:
        hit = stop_hit(nxt, sp["stop"]) & live
        new_remaining = jnp.where(hit, 0, new_remaining)
    new_state = {"base_key": base, "gen_count": gen_count, "counts": counts,
                 "prompt_counts": prompt_counts}
    if lp_k is None:
        return nxt, live, new_remaining, new_state
    return nxt, live, new_remaining, new_state, lanes
