"""Host-side sampling configuration and its batched device packing.

``SamplingParams`` travels on the coroutine (so COMBINE/MIGRATE/PARTITION
carry it for free); ``pack_params`` produces the (B,)-shaped arrays the
jitted processors consume, one row per device slot.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

# Fixed stop-token capacity so megastep shapes stay static across batches.
MAX_STOP_TOKENS = 8


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-sequence decoding configuration (vLLM/OpenAI-style surface).

    ``temperature <= 0`` means greedy argmax; the default instance is
    exactly PR 1's greedy megastep (every processor is an identity at its
    default value).  ``seed=None`` derives a deterministic per-sequence
    seed from ``seq_id`` at submit time, so two sequences with identical
    prompts still explore independently while staying reproducible.
    """
    temperature: float = 0.0
    top_k: int = 0                    # 0 = disabled
    top_p: float = 1.0                # 1.0 = disabled
    min_p: float = 0.0                # 0.0 = disabled
    repetition_penalty: float = 1.0   # 1.0 = disabled
    presence_penalty: float = 0.0
    frequency_penalty: float = 0.0
    seed: Optional[int] = None
    stop: Tuple[int, ...] = ()        # stop token ids (emitted, then halt)
    # host-only: wall-clock deadline (seconds since submit) after which
    # the sequence finishes gracefully with finish_reason="deadline".
    # Never packed to device (pack_params) and irrelevant to
    # is_greedy_default — it shapes scheduling, not logits.
    deadline_s: Optional[float] = None

    def __post_init__(self):
        if len(self.stop) > MAX_STOP_TOKENS:
            raise ValueError(
                f"at most {MAX_STOP_TOKENS} stop tokens (got {len(self.stop)})")
        if self.top_k < 0 or not (0.0 < self.top_p <= 1.0):
            raise ValueError(f"bad top_k/top_p: {self.top_k}/{self.top_p}")

    @property
    def is_greedy_default(self) -> bool:
        """True iff this instance is indistinguishable from PR 1 greedy —
        eligible for the sampling-free megastep (no PRNG, no counts)."""
        return (self.temperature <= 0.0 and self.top_k == 0
                and self.top_p >= 1.0 and self.min_p <= 0.0
                and self.repetition_penalty == 1.0
                and self.presence_penalty == 0.0
                and self.frequency_penalty == 0.0
                and self.seed is None and not self.stop)

    def effective_seed(self, seq_id: int) -> int:
        return self.seed if self.seed is not None else seq_id

    def truncate_at_stop(self, tokens) -> Tuple[list, bool]:
        """Host-side mirror of the on-device stop semantics: the stop
        token is emitted, then the sequence halts.  Returns
        (kept_tokens, stopped)."""
        toks = [int(t) for t in tokens]
        if not self.stop:
            return toks, False
        ss = set(self.stop)
        for i, t in enumerate(toks):
            if t in ss:
                return toks[: i + 1], True
        return toks, False


def derive_fork_seed(base_seed: int, fork_index: int) -> int:
    """Per-fork seed derivation for ``submit(n=...)`` fan-out.

    An explicit seed shared by a whole fork group would make every sibling
    decode the same stream; splitmix-style mixing gives each fork a stable,
    well-separated seed so fork k of seed s is reproducible on its own
    (submit a single sequence with ``derive_fork_seed(s, k)`` and you get
    the identical stream).  Fork 0 (the lead) keeps the base seed."""
    if fork_index == 0:
        return base_seed
    z = (base_seed + fork_index * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return (z ^ (z >> 31)) & 0xFFFFFFFF


def pack_params(sps: Sequence[SamplingParams],
                seq_ids: Sequence[int]) -> Dict[str, np.ndarray]:
    """Batch per-sequence params into row-aligned numpy arrays.

    Returns float32/int32 arrays of shape (B,) plus a (B, MAX_STOP_TOKENS)
    stop table padded with -1 (token ids are non-negative, so -1 never
    matches) and the (B,) effective seeds.  Callers upload with
    ``jnp.asarray`` and scatter rows with ``.at[slot].set`` as slots churn.
    """
    B = len(sps)
    out = {
        "temperature": np.zeros((B,), np.float32),
        "top_k": np.zeros((B,), np.int32),
        "top_p": np.ones((B,), np.float32),
        "min_p": np.zeros((B,), np.float32),
        "repetition_penalty": np.ones((B,), np.float32),
        "presence_penalty": np.zeros((B,), np.float32),
        "frequency_penalty": np.zeros((B,), np.float32),
        "stop": np.full((B, MAX_STOP_TOKENS), -1, np.int32),
        "seed": np.zeros((B,), np.uint32),
    }
    for i, (sp, sid) in enumerate(zip(sps, seq_ids)):
        out["temperature"][i] = sp.temperature
        out["top_k"][i] = sp.top_k
        out["top_p"][i] = sp.top_p
        out["min_p"][i] = sp.min_p
        out["repetition_penalty"][i] = sp.repetition_penalty
        out["presence_penalty"][i] = sp.presence_penalty
        out["frequency_penalty"][i] = sp.frequency_penalty
        if sp.stop:
            out["stop"][i, : len(sp.stop)] = sp.stop
        out["seed"][i] = np.uint32(sp.effective_seed(sid) & 0xFFFFFFFF)
    return out
