"""Per-node batch-inference engine: real JAX execution + coroutine slots.

One NodeEngine = one node's GPU pool.  It owns a dense device decode cache
with `max_active` sequence slots, a paged host store (single source of
truth, §5.2), a page allocator (two-page lazy allocation), and jitted
prefill/decode steps.  The CoroutineScheduler drives it exclusively
through the formal ExecutionBackend slot protocol (core/backend.py —
conformance declared below), so the exact same scheduling code also
drives the cluster simulator.

Logprobs: when any active coroutine requests them, the megastep emits the
packed ``(P, B, 2+2K)`` plane of ``models.transformer.pack_logprob_block``
(chosen-token logprob + top-K alternatives from the RAW model logits)
instead of the bare token block — the page still crosses in ONE
device→host transfer; ``_apply_block`` unpacks it host-side.

Fused decode megastep (default)
-------------------------------
``decode_page`` runs one jitted ``lax.scan`` over the whole page: tokens,
lengths, the per-slot ``remaining`` countdown and the KV cache stay on
device (cache donated across the scan), sampled tokens self-feed, and
finished slots are masked out.  The page returns as ONE ``(P, max_active)``
token block — exactly one device→host transfer per page (counted in
``d2h_transfers``; the per-token loop pays one per token plus Python
bookkeeping, the dispatch-bound regime benchmarks/decode_throughput.py
measures).  Ragged pages decompose into chained pow2-sized scan chunks
(40 -> 32+8) so the engine holds at most ``log2(P)`` megastep
executables while never running a wasted masked step.

Host-sync contract: after ``decode_page``, coroutine state (generated/
last_token/length) is already updated from the block; the page's KV then
moves to the host store through a two-stage software pipeline —
``stage_appends`` issues ONE jitted batched gather of every dirty slot's
new window and starts the device→host copy asynchronously
(``copy_to_host_async``), snapshotting each slot's ``[synced, length)``
span at issue time; ``drain_appends`` later materializes the blob into
per-page host-store appends.  The scheduler drains page N's blob at page
N+1's SYNC_DRAIN phase, so the PCIe transfer rides behind the next
megastep instead of blocking the loop (``overlap=False`` restores the
blocking gather-transfer-append of the seed path; ``sync_appends`` is
stage+drain in one call).  In-flight staged bytes are metered through a
real ``memory.buffers.RingBuffer``: a stage that would overflow
``ring_buffer_bytes`` falls back to a synchronous drain first (counted
in ``sync_stalls`` — the signal the §5.4 plan sizes the buffer against).

Slot installs (COMBINE/refill) are likewise staged host-side and applied
in ONE jitted multi-slot scatter — cache leaves, last tokens and lengths
together — right before the next consumer of device state (decode or
extract), so refilling n slots costs one dispatch instead of
``n * (leaves + 2)`` eager scatters.

Supports dense and MoE families (caches {"k","v"}); set
``module_granularity=True`` to decode through the Algorithm-1 module
runtime (per-sub-batch attention + COMBINE before MoE), which fuses the
same way via ``ModuleRuntime.forward_decode_page``.

Robustness (§5.6): the engine emits a ``heartbeat()`` each scheduler
round and routes its risky host transfers — the staged d2h copy issue
("stage"), blob materialization ("drain"), the batched install scatter
("install") and migrate blob moves ("migrate") — through ``transfer``,
the bounded-exponential-backoff retry envelope of ``runtime/faults.py``.
A transfer that exhausts its retry budget dead-letters: the lost blob's
host-store entries are dropped (``_abandon_blob`` — a lagging checkpoint
must never feed a migrate) and the scheduler escalates the node to
NODE_FAILURE.  An injected ``NodeFaults`` view (``faults=``) makes the
engine honor a deterministic FaultPlan: death/oom refuse admissions and
compute, stale windows suppress heartbeats, transfer faults exercise the
retry path — all keyed to scheduler rounds, hence replayable.

Sampling: when any active coroutine carries non-default SamplingParams,
``decode_page`` switches to the sampled megastep variant — same fused
scan with the per-slot PRNG position and penalty counts riding the carry
(repro.sampling) — still one device→host transfer per page.  The engine
derives a static ``SampleFlags`` plan from the active batch (Pallas
kernel vs shared-sort XLA tier, penalty/stop/greedy-select skips) that
is part of the megastep jit key.  Per-slot sampling state is re-derived
from the coroutine at ``install_slot`` (keys are fold_in(seed,
token_index), counts a bincount of its tokens) — staged host-side and
flushed to the device in ONE batched scatter at the next sampled page,
so slot churn never perturbs a sequence's sampled stream and never pays
per-slot eager dispatches.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro import sampling as smp
from repro.core.backend import validate_backend
from repro.core.coroutine import Phase, SequenceCoroutine, Status
from repro.core.forward import ModuleRuntime, _lru_get
from repro.core.primitives import PrimitiveStats
from repro.memory.allocator import PageAllocator
from repro.memory.buffers import RingBuffer
from repro.memory.paged_kv import HostKVStore
from repro.models import transformer as T
from repro.models.api import MeshAxes, ModelConfig
from repro.runtime.failure import DeviceStatus, Heartbeat
from repro.runtime.faults import (NodeFaults, RetryPolicy,
                                  TransferDeadLetter, guarded_transfer)

_PREFILL_JIT_CAP = 8    # LRU cap on (B, S)-bucketed prefill executables
_GATHER_JIT_CAP = 16    # LRU cap on (n, W)-bucketed sync-gather executables
_INSTALL_JIT_CAP = 8    # LRU cap on n-bucketed multi-slot install scatters
# staging-path PCIe-class bandwidth for the ring buffer's timing model
# (core/plan.py Hardware.host_link_bw); the live gate only uses occupancy
_HOST_LINK_BW = 32e9
# the per-slot sampling-params rows the batched sampler consumes
_SAMPLE_ROW_KEYS = ("temperature", "top_k", "top_p", "min_p",
                    "repetition_penalty", "presence_penalty",
                    "frequency_penalty")
# LRU cap on (scan-length, sampled, lp_k)-keyed megasteps — sized for all
# pow2 chunk sizes of a page x {greedy, sampled} x {no-lp, lp} variants
# coexisting without steady-state eviction/re-jit churn
_MEGASTEP_JIT_CAP = 32


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class _InFlightSync:
    """One staged KV blob: the device array whose async device→host copy
    has been issued, plus everything needed to land it in the host store
    later — the leaf layout and the per-slot ``(seq_id, start, n, first)``
    spans snapshotted at issue time (slot reuse after the snapshot cannot
    corrupt it: the gather already copied the values)."""
    __slots__ = ("blob", "metas", "snaps", "nbytes", "name")

    def __init__(self, blob, metas, snaps, nbytes, name):
        self.blob = blob
        self.metas = metas
        self.snaps = snaps
        self.nbytes = nbytes
        self.name = name


def _np_top_k_idx(x: np.ndarray, k: int) -> np.ndarray:
    """Indices of the k largest entries, ties broken by LOWEST index —
    matching ``jax.lax.top_k`` so host-side (prefill / looped-baseline)
    top-logprobs agree with the device plane.  (``argsort()[::-1]`` would
    break ties by highest index.)"""
    return np.argsort(-x, kind="stable")[:k]


def _np_log_softmax(x: np.ndarray) -> np.ndarray:
    """Host-side log-softmax over the last axis (prefill / looped-baseline
    logprobs; the fused path computes this on device)."""
    x = np.asarray(x, np.float32)
    m = x.max(axis=-1, keepdims=True)
    e = np.exp(x - m)
    return (x - m) - np.log(e.sum(axis=-1, keepdims=True))


class NodeEngine:
    def __init__(self, cfg: ModelConfig, *, node_id: int = 0,
                 max_active: int = 8, max_len: int = 256,
                 page_size: int = 32, num_devices: int = 8,
                 device_pages: Optional[int] = None,
                 module_granularity: bool = False, b_attn: int = 0,
                 fused: bool = True, overlap: bool = True,
                 ring_buffer_bytes: Optional[int] = None,
                 restore_ring_bytes: Optional[int] = None, seed: int = 0,
                 faults: Optional[NodeFaults] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 enable_prefix: bool = True):
        assert cfg.family in ("dense", "moe") and cfg.sliding_window == 0, \
            "mini-engine supports dense/moe caches; see cluster sim for rest"
        self.cfg = cfg
        self.axes = MeshAxes(batch=("data",), model="model")
        self.node_id = node_id
        self.max_active = max_active
        self.max_len = max_len
        self.num_devices = num_devices
        self.page_size = page_size
        self.fused = fused
        self.overlap = overlap

        self.params = T.init_params(cfg, jax.random.PRNGKey(seed))
        self.host_store = HostKVStore(page_size, enable_prefix=enable_prefix)
        total_pages = device_pages or (max_active * max_len // page_size * 2)
        self.allocator = PageAllocator(total_pages, page_size)
        self.stats = PrimitiveStats()

        # ---- robustness (§5.6): fault injection + guarded transfers -------
        self.faults = faults                    # NodeFaults view or None
        self.retry_policy = retry_policy or RetryPolicy()
        self.transfer_stats = {"retries": 0, "timeouts": 0, "dead_letters": 0}
        self.dead_lettered = False      # scheduler escalates to NODE_FAILURE
        self.oom_rejections = 0         # admissions refused by an oom fault
        self.straggler_steps = 0        # decode steps run under a straggler
        self.abandoned_blobs = 0        # staged blobs lost to dead-letters

        # device slot arrays
        self.cache = T.init_cache(cfg, max_active, max_len)
        self.tokens = jnp.zeros((max_active,), jnp.int32)
        self.lengths = jnp.zeros((max_active,), jnp.int32)
        self.slot_owner: List[Optional[int]] = [None] * max_active
        self.synced_len: Dict[int, int] = {}

        # per-slot sampling params (host mirror, uploaded lazily) + the
        # device-resident sampling state that rides the megastep carry
        V = T.padded_vocab(cfg)
        self._sp_host = smp.pack_params([smp.SamplingParams()] * max_active,
                                        list(range(max_active)))
        self._sp_dev: Optional[Dict] = None
        self._sample_state = {
            "base_key": jnp.zeros((max_active, 2), jnp.uint32),
            "gen_count": jnp.zeros((max_active,), jnp.int32),
            "counts": jnp.zeros((max_active, V), jnp.int32),
            "prompt_counts": jnp.zeros((max_active, V), jnp.int32),
        }
        # slot installs stage their re-derived sampling state here (host
        # numpy, keyed by slot so a re-install overwrites) and the next
        # sampled decode_page scatters everything in one batch — per-slot
        # eager device dispatches were the dominant sampled-path overhead
        self._pending_smp: "OrderedDict[int, tuple]" = OrderedDict()
        self._prefill_sample_cache: "OrderedDict[tuple, object]" = \
            OrderedDict()
        self._flush_cache: "OrderedDict[int, object]" = OrderedDict()

        self._decode = jax.jit(
            lambda p, c, t, l: T.decode_step(cfg, self.axes, p, c, t, l),
            donate_argnums=(1,))
        self._decode_logits = None      # lazy: looped-baseline logprob path
        self._megastep_cache: "OrderedDict[tuple, object]" = OrderedDict()
        self._prefill_cache: "OrderedDict[tuple, object]" = OrderedDict()
        self.module_rt = (ModuleRuntime(cfg, self.axes, self.params)
                          if module_granularity else None)
        self.b_attn = b_attn or max_active
        self.decode_steps = 0
        self.tokens_out = 0.0       # cumulative effective tokens emitted —
        #                             heartbeat progress counter; an injected
        #                             straggler divides the increment by its
        #                             factor (the fault is honored where it
        #                             is observed: a real node cannot be
        #                             slowed deterministically, so its beat
        #                             under-reports progress instead)
        self.prefill_tokens = 0
        self.prefill_tokens_saved = 0   # prompt tokens served from shared KV
        self.d2h_transfers = 0      # device→host copies through _to_host

        # ---- pipelined host-KV staging (stage_appends / drain_appends) ----
        # stable leaf layout of the concatenated sync blob
        self._blob_metas = [(name, leaf.shape[3:],
                             int(np.prod(leaf.shape[3:])) if leaf.shape[3:]
                             else 1)
                            for name, leaf in self.cache.items()]
        self._inflight: Deque[_InFlightSync] = deque()
        self._gather_cache: "OrderedDict[tuple, object]" = OrderedDict()
        # the live backpressure gate: worst case one page blob is every
        # slot dirty for a full page; default capacity = two of those
        # (pipeline depth 1 + the blob being staged) so steady state
        # never stalls while a runaway pipeline cannot hoard host RAM
        page_blob = sum(leaf.dtype.itemsize * leaf.shape[0]
                        * _pow2(max_active) * _pow2(page_size) * f
                        for (_, _, f), leaf in zip(self._blob_metas,
                                                   self.cache.values()))
        self.ring = RingBuffer(ring_buffer_bytes or 2 * page_blob,
                               _HOST_LINK_BW)
        self._sync_tag = 0
        self.sync_stages = 0        # async-staged blobs
        self.sync_drains = 0        # blobs landed in the host store
        self.sync_stalls = 0        # ring-full fallbacks to synchronous drain
        self.sync_wait_s = 0.0      # wall time blocked materializing blobs
        self.staged_bytes = 0       # cumulative bytes through the ring

        # ---- batched slot installs (COMBINE/refill) -----------------------
        # slot -> (cache slices, last_token, length), flushed in one jitted
        # multi-slot scatter before the next consumer of device state
        self._pending_install: "OrderedDict[int, tuple]" = OrderedDict()
        self._install_cache: "OrderedDict[int, object]" = OrderedDict()

        # ---- staged h2d restores (stage_restore / take_restore) -----------
        # the host→device mirror of the d2h sync pipeline: a suspended
        # sequence's checkpoint is device_put BEFORE its COMBINE, metered
        # through its own h2d ring buffer (same stage/drain discipline as
        # the d2h ring; a separate instance because one full-sequence
        # restore dwarfs a decode-page blob, and restore prefetch must
        # never starve the sync pipeline's staging room), so the PCIe
        # copy rides behind the decode page between staging and admission.
        # seq_id -> (device slices, host length at stage, ring name,
        #            nbytes, transfer cost)
        seq_blob = max(page_blob * max_len
                       // (_pow2(max_active) * _pow2(page_size)), 1)
        self.restore_ring = RingBuffer(restore_ring_bytes or 2 * seq_blob,
                                       _HOST_LINK_BW)
        self._restore_staged: "OrderedDict[int, tuple]" = OrderedDict()
        self.restore_stages = 0         # restores prefetched through the ring
        self.restore_stalls = 0         # prefetches refused: ring had no room
        self.restore_wait_s = 0.0       # h2d restore transfer time (all paths)
        self.restore_stage_hidden_s = 0.0   # portion hidden behind decode
        self.restore_staged_bytes = 0   # cumulative prefetched bytes

    # ------------------------------------------------------------- protocol
    def clock(self) -> float:
        return time.monotonic()

    def idle_tick(self):
        pass

    def heartbeat(self) -> Optional[Heartbeat]:
        """This round's liveness beat for the scheduler's HealthMonitor.
        A dead or heartbeat-suppressed node yields None — the monitor
        counts the miss and declares failure after ``dead_after`` in a
        row."""
        if self.faults is not None and (
                self.faults.dead or self.faults.heartbeat_suppressed()):
            return None
        return Heartbeat(self.node_id, self.clock(),
                         [DeviceStatus(d) for d in range(self.num_devices)],
                         decode_steps=self.decode_steps,
                         tokens=self.tokens_out)

    def transfer(self, kind: str, fn):
        """Run one risky host transfer through the retry/timeout/dead-
        letter envelope (ExecutionBackend.transfer)."""
        return guarded_transfer(self, kind, fn)

    def acquire_slot(self, co: SequenceCoroutine) -> Optional[int]:
        if self.faults is not None:
            if self.faults.dead:
                return None             # zombie node admits nothing
            if self.faults.oom_active():
                self.oom_rejections += 1
                return None
        if not self.allocator.can_admit(2):
            return None
        for s, owner in enumerate(self.slot_owner):
            if owner is None:
                self.slot_owner[s] = co.seq_id
                self.allocator.alloc(co.seq_id, 2)
                return s
        return None

    def free_slot(self, co: SequenceCoroutine):
        if co.slot is not None and self.slot_owner[co.slot] == co.seq_id:
            self.slot_owner[co.slot] = None
            self.lengths = self.lengths.at[co.slot].set(0)

    def extract_slot(self, co: SequenceCoroutine) -> Dict[str, np.ndarray]:
        self._flush_pending_installs()      # the slot may itself be pending
        s = co.slot
        return {name: np.asarray(leaf[:, s]) for name, leaf in
                self.cache.items()}

    def install_slot(self, co: SequenceCoroutine, slices: Dict[str, np.ndarray]):
        """Stage a COMBINE resume: the cache/token/length writes are held
        host-side and applied by ``_flush_pending_installs`` in ONE jitted
        multi-slot scatter at the next consumer of device state, so a
        refill installing n slots costs one dispatch instead of
        ``n * (leaves + 2)`` eager ones (the remaining eager-dispatch tax
        on slot churn, shared by greedy and sampled paths).  Re-installing
        the same slot overwrites its pending entry."""
        self._pending_install[co.slot] = (slices, int(co.last_token),
                                          int(co.length))
        self.synced_len[co.seq_id] = co.length
        self._install_sampling(co)

    def _pad_slot_arr(self, arr: np.ndarray, leaf) -> np.ndarray:
        """Pad/crop a restored (L, len, ...) slice to the leaf's (L, S, ...)
        slot shape."""
        pad = leaf.shape[2] - arr.shape[1]
        if pad > 0:
            return np.pad(arr, [(0, 0), (0, pad)]
                          + [(0, 0)] * (arr.ndim - 2))
        return arr[:, : leaf.shape[2]]

    def _install_now(self, s: int, slices: Dict[str, np.ndarray],
                     last_token: int, length: int):
        """Eager per-slot install — only for slices missing cache leaves
        (a partial checkpoint must not zero the leaves it omits)."""
        for name, arr in slices.items():
            if name not in self.cache:
                continue
            leaf = self.cache[name]
            a = self._pad_slot_arr(arr, leaf)
            self.cache[name] = leaf.at[:, s].set(jnp.asarray(a, leaf.dtype))
        self.tokens = self.tokens.at[s].set(last_token)
        self.lengths = self.lengths.at[s].set(length)

    def _flush_pending_installs(self):
        """Apply all staged slot installs in one jitted batched scatter
        (slot counts pow2-padded by repeating the first entry — duplicate
        identical updates are harmless — so slot churn reuses a handful
        of executables).  Called before anything reads device slot state
        (decode, extract, the sync gather)."""
        if not self._pending_install:
            return
        items = list(self._pending_install.items())
        self._pending_install.clear()
        names = [m[0] for m in self._blob_metas]
        full, partial = [], []
        for s, (slices, tok, ln) in items:
            dst = full if all(nm in slices for nm in names) else partial
            dst.append((s, slices, tok, ln))
        for s, slices, tok, ln in partial:
            self._install_now(s, slices, tok, ln)
        if not full:
            return
        n = _pow2(len(full))
        full += [full[0]] * (n - len(full))
        slot_idx = np.array([s for s, *_ in full], np.int32)
        toks = np.array([t for _, _, t, _ in full], np.int32)
        lens = np.array([l for *_, l in full], np.int32)
        upds = {}
        for name, leaf in self.cache.items():
            rows = [np.asarray(self._pad_slot_arr(slices[name], leaf),
                               leaf.dtype) for _, slices, _, _ in full]
            upds[name] = np.stack(rows, axis=1)     # (L, n, S, *trail)

        def make():
            def _apply(cache, tokens, lengths, idx, upd, tok, ln):
                new = dict(cache)
                for nm, u in upd.items():
                    new[nm] = cache[nm].at[:, idx].set(u)
                return new, tokens.at[idx].set(tok), lengths.at[idx].set(ln)
            return jax.jit(_apply, donate_argnums=(0, 1, 2))
        fn = _lru_get(self._install_cache, n, _INSTALL_JIT_CAP, make)
        try:
            out = self.transfer("install", lambda: fn(
                self.cache, self.tokens, self.lengths, jnp.asarray(slot_idx),
                {k: jnp.asarray(v) for k, v in upds.items()},
                jnp.asarray(toks), jnp.asarray(lens)))
        except TransferDeadLetter:
            # the staged installs are lost and their slots hold stale
            # data; the scheduler sees ``dead_lettered`` and escalates to
            # NODE_FAILURE, whose recovery recomputes the affected
            # sequences from their prompts
            return
        self.cache, self.tokens, self.lengths = out

    def _install_sampling(self, co: SequenceCoroutine):
        """Bind a slot's sampling params + re-derived device state.

        The PRNG position is just len(generated) (keys are fold_in(base,
        t), not a split chain) and penalty counts are bincounts of the
        coroutine's tokens, so a coroutine arriving via COMBINE or MIGRATE
        resumes its sampled stream exactly where it left off — no device
        sampling state ever crosses nodes.  Greedy-default sequences only
        reset the slot's params row (a stale sampled row must not make the
        sampled megastep draw for them); their state rows are don't-care
        (temperature<=0 takes the argmax branch), so the O(V) count
        derivation and device scatters are skipped on the slot-churn hot
        path of all-greedy workloads.  The derivation itself is pure
        numpy; the device scatter is deferred to the next sampled page
        (``_flush_pending_sampling``) so a refill installing n slots
        costs ONE batched update instead of 4n eager dispatches."""
        s = co.slot
        row = smp.pack_params([co.sampling], [co.seq_id])
        for k in self._sp_host:
            self._sp_host[k][s] = row[k][0]
        self._sp_dev = None             # host mirror dirty; re-upload lazily
        if co.sampling.is_greedy_default:
            self._pending_smp.pop(s, None)
            return
        V = self._sample_state["counts"].shape[1]
        st_row = smp.init_state(row["seed"], [co.prompt], [co.generated], V)
        self._pending_smp[s] = (smp.base_keys_host(st_row["seed"])[0],
                                st_row["gen_count"][0], st_row["counts"][0],
                                st_row["prompt_counts"][0])

    def _flush_pending_sampling(self):
        """Apply all staged slot-install sampling rows to the device
        state in ONE jitted batched scatter (row counts are pow2-padded
        by repeating the first row — duplicate identical updates are
        harmless — so slot churn reuses a handful of executables)."""
        if not self._pending_smp:
            return
        slots = list(self._pending_smp)
        rows = list(self._pending_smp.values())
        self._pending_smp.clear()
        n = _pow2(len(slots))
        slots += [slots[0]] * (n - len(slots))
        rows += [rows[0]] * (n - len(rows))
        cols = [np.stack([r[i] for r in rows]) for i in range(4)]

        def make():
            def _apply(state, sl, bk, gc, cnt, pc):
                return {"base_key": state["base_key"].at[sl].set(bk),
                        "gen_count": state["gen_count"].at[sl].set(gc),
                        "counts": state["counts"].at[sl].set(cnt),
                        "prompt_counts":
                            state["prompt_counts"].at[sl].set(pc)}
            return jax.jit(_apply, donate_argnums=(0,))
        fn = _lru_get(self._flush_cache, n, 8, make)
        self._sample_state = fn(self._sample_state,
                                jnp.asarray(slots, jnp.int32), *cols)

    def _sp_device(self) -> Dict:
        """Packed per-slot sampling params as device arrays (cached until
        a slot install dirties the host mirror)."""
        if self._sp_dev is None:
            self._sp_dev = {k: jnp.asarray(v)
                            for k, v in self._sp_host.items()
                            if k != "seed"}
        return self._sp_dev

    def reconfigure_partition(self, co: SequenceCoroutine, group: List[int]):
        # On TPU: re-lower the decode step over the group mesh (sequence-
        # split KV).  Single-host CPU: bookkeeping only; the cluster
        # simulator models the speedup (runtime/cluster.py).
        pass

    # ------------------------------------------------------------- transfers
    def _to_host(self, arr) -> np.ndarray:
        """Single funnel for device→host copies (spy point for tests)."""
        self.d2h_transfers += 1
        return np.asarray(arr)

    # ------------------------------------------------------------- compute
    def decode_page(self, active: Sequence[SequenceCoroutine], P: int):
        """Decode up to P tokens for every active sequence.

        Fused path (default): jitted scan(s) totalling exactly
        ``min(P, max remaining)`` steps — the done mask inside the scan
        handles mid-page finishes, and capping the page at the max
        remaining IS the early page exit (no token is ever decoded past a
        slot's budget).  The per-page ``decode_steps`` counter advances by
        the logical step count, same as the per-token loop, so
        simulator/roofline accounting is unchanged."""
        if self.faults is not None and self.faults.dead:
            return                      # zombie: no compute until failover
        self._flush_pending_installs()
        if not active:
            return
        steps = min(P, max(c.remaining for c in active))
        if steps <= 0:
            return
        if self.faults is not None and self.faults.straggler_factor() > 1.0:
            # a real node can't be slowed deterministically — count the
            # affected steps so tests/telemetry see the straggler window
            self.straggler_steps += steps
        tot0 = sum(len(c.generated) for c in active)
        sampled = any(not c.sampling.is_greedy_default for c in active)
        want_lp = [c for c in active if c.logprobs]
        lp_k = max(c.top_logprobs for c in want_lp) if want_lp else None
        # static sampling plan (backend / penalty skip / sort tier) decided
        # host-side from the active params — part of the jit cache key
        flags = (smp.flags_for([c.sampling for c in active],
                               T.padded_vocab(self.cfg)) if sampled else None)
        if not self.fused and not sampled:
            self._decode_page_looped(active, P, lp_k)
            self._account_progress(active, tot0)
            return
        # exact step count via pow2 decomposition (40 -> 32+8): each chunk
        # is a cached scan executable (≤ log2(P) distinct sizes), chunks
        # chain on device, blocks concatenate on device -> no masked tail
        # compute and still ONE host transfer for the whole page.  When
        # any active sequence carries non-default SamplingParams the same
        # loop runs the sampled megastep variant: the per-slot sampling
        # state (fold_in PRNG position, penalty counts) rides the scan
        # carry and stop-token hits mask slots on device.  Non-fused
        # sampled (baseline): chunk size 1, one transfer per token.
        rem = np.zeros((self.max_active,), np.int32)
        for co in active:
            rem[co.slot] = co.remaining
        rem_j = jnp.asarray(rem)
        sp = self._sp_device() if sampled else None
        if sampled:
            self._flush_pending_sampling()
        state = self._sample_state
        blocks = []
        left = steps
        while left > 0:
            chunk = (1 << (left.bit_length() - 1)) if self.fused else 1
            if self.module_rt is not None:
                out = self.module_rt.forward_decode_page(
                    self.tokens, self.cache, self.lengths, rem_j,
                    self.b_attn, chunk,
                    sampling=(sp, state) if sampled else None, lp_k=lp_k,
                    flags=flags)
            else:
                mega = self._get_megastep(chunk, sampled, lp_k, flags)
                args = (self.params, self.cache, self.tokens, self.lengths,
                        rem_j) + ((sp, state) if sampled else ())
                out = mega(*args)
            if sampled:
                blk, self.tokens, self.lengths, rem_j, self.cache, state = \
                    out
            else:
                blk, self.tokens, self.lengths, rem_j, self.cache = out
            blocks.append(blk if self.fused else self._to_host(blk))
            left -= chunk
        if sampled:
            self._sample_state = state
        self.decode_steps += steps
        if self.fused:
            block = blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks)
            block_np = self._to_host(block)  # the ONE d2h transfer per page
        else:
            block_np = np.concatenate(blocks)
        self._apply_block(active, block_np, steps)
        self._account_progress(active, tot0)

    def _account_progress(self, active: Sequence[SequenceCoroutine],
                          tot0: int) -> None:
        """Advance the heartbeat progress counter by this page's emitted
        tokens.  An injected straggler divides the credit by its factor —
        the engine cannot actually run slower, so the fault is honored at
        the observation boundary: the node's beats under-report progress
        exactly as a real 4x-slow node's would."""
        emitted = sum(len(c.generated) for c in active) - tot0
        f = 1.0
        if self.faults is not None:
            f = max(self.faults.straggler_factor(), 1.0)
        self.tokens_out += emitted / f

    def _apply_block(self, active: Sequence[SequenceCoroutine], block_np,
                     steps: int):
        """Apply a (steps, max_active) token block — or the packed
        (steps, max_active, 2+2K) logprob plane — to coroutine state,
        truncating at each sequence's first stop-token hit (the stop token
        is emitted, then the sequence halts — mirroring the on-device
        remaining-zeroing)."""
        lp_np = topv = topi = None
        if block_np.ndim == 3:
            toks_np, lp_np, topv, topi = T.unpack_logprob_block(block_np)
        else:
            toks_np = block_np
        for co in active:
            n = min(steps, co.remaining)
            if n <= 0:
                continue
            toks, hit = co.sampling.truncate_at_stop(toks_np[:n, co.slot])
            co.stopped = co.stopped or hit
            co.generated.extend(toks)
            co.last_token = toks[-1]
            co.length += len(toks)
            if co.logprobs and lp_np is not None:
                self._append_logprobs(
                    co, [float(x) for x in lp_np[:len(toks), co.slot]],
                    None if topv is None else topv[:len(toks), co.slot],
                    None if topi is None else topi[:len(toks), co.slot])

    @staticmethod
    def _append_logprobs(co: SequenceCoroutine, chosen, topv, topi):
        """Append one block of chosen-token logprobs (and the requested
        top-K alternatives) aligned with the tokens just applied."""
        co.token_logprobs.extend(chosen)
        if co.top_logprobs and topv is not None:
            k = co.top_logprobs
            for t in range(len(chosen)):
                co.top_token_logprobs.append(
                    [(int(topi[t][j]), float(topv[t][j])) for j in range(k)])

    def _get_megastep(self, steps: int, sampled: bool = False, lp_k=None,
                      flags=None):
        def make():
            if sampled:
                def _mega(params, cache, tokens, lengths, remaining, sp,
                          state):
                    return T.decode_page(self.cfg, self.axes, params, cache,
                                         tokens, lengths, remaining, steps,
                                         sampling=(sp, state), lp_k=lp_k,
                                         flags=flags)
            else:
                def _mega(params, cache, tokens, lengths, remaining):
                    return T.decode_page(self.cfg, self.axes, params, cache,
                                         tokens, lengths, remaining, steps,
                                         lp_k=lp_k)
            return jax.jit(_mega, donate_argnums=(1,))
        return _lru_get(self._megastep_cache, (steps, sampled, lp_k, flags),
                        _MEGASTEP_JIT_CAP, make)

    def _get_prefill_sampler(self, n: int, flags):
        """Jitted first-token draw (keys = fold_in(base, 0)); without the
        cache every prefill re-traced the eagerly-vmapped sampler, which
        cost more than the prefill forward itself."""
        def make():
            def _draw(logits2d, pcounts, counts, sp_rows, base):
                keys = smp.step_keys(base, jnp.zeros((n,), jnp.int32))
                return smp.sample(logits2d, pcounts, counts, sp_rows, keys,
                                  flags)
            return jax.jit(_draw)
        return _lru_get(self._prefill_sample_cache, (n, flags),
                        _PREFILL_JIT_CAP, make)

    def _decode_page_looped(self, active: Sequence[SequenceCoroutine],
                            P: int, lp_k=None):
        """Seed per-token loop: one jitted step, one host round-trip and
        Python bookkeeping per token.  Kept as the measured baseline for
        benchmarks/decode_throughput.py (fused=False).  With ``lp_k`` the
        raw logits cross per token instead and the logprobs are computed
        host-side (baseline only; the fused path packs them on device)."""
        by_slot = {c.slot: c for c in active}
        steps = min(P, max(c.remaining for c in active))
        if (lp_k is not None and self.module_rt is None
                and self._decode_logits is None):
            self._decode_logits = jax.jit(
                lambda p, c, t, l: T.decode_step_logits(
                    self.cfg, self.axes, p, c, t, l), donate_argnums=(1,))
        for _ in range(steps):
            lp_np = None
            if lp_k is not None:
                if self.module_rt is not None:
                    logits, self.cache = self.module_rt.forward_decode(
                        self.tokens, self.cache, self.lengths, self.b_attn,
                        want_logits=True)
                else:
                    logits, self.cache = self._decode_logits(
                        self.params, self.cache, self.tokens, self.lengths)
                logits_np = self._to_host(logits)
                lp_np = _np_log_softmax(logits_np)
                nxt_np = np.argmax(logits_np, axis=-1).astype(np.int32)
            elif self.module_rt is not None:
                nxt, self.cache = self.module_rt.forward_decode(
                    self.tokens, self.cache, self.lengths, self.b_attn)
                nxt_np = None
            else:
                nxt, self.cache = self._decode(self.params, self.cache,
                                               self.tokens, self.lengths)
                nxt_np = None
            self.decode_steps += 1
            if nxt_np is None:
                nxt_np = self._to_host(nxt)
            upd_tok, upd_len = [], []
            for s, co in by_slot.items():
                if co.remaining > 0:
                    tok = int(nxt_np[s])
                    co.generated.append(tok)
                    co.last_token = tok
                    co.length += 1
                    if co.logprobs and lp_np is not None:
                        topv = topi = None
                        if co.top_logprobs:
                            topi = [_np_top_k_idx(lp_np[s],
                                                  co.top_logprobs)]
                            topv = [lp_np[s][topi[0]]]
                        self._append_logprobs(
                            co, [float(lp_np[s, tok])], topv, topi)
                    upd_tok.append((s, tok))
                    upd_len.append((s, co.length))
            if upd_tok:
                idx = jnp.array([s for s, _ in upd_tok])
                self.tokens = self.tokens.at[idx].set(
                    jnp.array([t for _, t in upd_tok], jnp.int32))
                self.lengths = self.lengths.at[idx].set(
                    jnp.array([l for _, l in upd_len], jnp.int32))
            if all(c.remaining == 0 for c in active):
                break

    def sync_appends(self, active: Sequence[SequenceCoroutine]):
        """Blocking host-KV sync (§5.3 i): stage + drain in one call —
        the seed path's gather-transfer-append, kept for direct callers
        and as the measured baseline of the ``--overlap`` benchmark."""
        self.stage_appends(active)
        self.drain_appends()

    def _get_gather(self, n: int, W: int):
        """Jitted batched dirty-window gather -> one (L, n, W, F_total)
        blob, bucketed to (pow2 slots, pow2 window) so steady-state page
        syncs reuse a handful of executables."""
        names = [m[0] for m in self._blob_metas]

        def make():
            def _g(cache, slots, pos):
                parts = []
                for nm in names:
                    seg = cache[nm][:, slots, pos]      # (L, n, W, *trail)
                    parts.append(seg.reshape(seg.shape[0], n, W, -1))
                return jnp.concatenate(parts, axis=-1)
            return jax.jit(_g)
        return _lru_get(self._gather_cache, (n, W), _GATHER_JIT_CAP, make)

    def _gather_dirty(self, active) -> Optional[_InFlightSync]:
        """Issue the batched gather of every dirty slot's [synced, length)
        window and snapshot the per-slot spans; advances ``synced_len`` at
        ISSUE time (the pipeline owns the window from here — a later
        yield checkpoint supersedes it with identical values, so a stale
        drain is a harmless rewrite)."""
        assert len({leaf.dtype for leaf in self.cache.values()}) == 1, \
            "batched gather concatenates leaves: mixed dtypes would be " \
            "silently promoted — add a per-dtype blob before relaxing this"
        self._flush_pending_installs()
        todo = []
        for co in active:
            if co.slot is None:
                continue
            start = self.synced_len.get(co.seq_id, 0)
            first = not self.host_store.has(co.seq_id)
            if first:
                start = 0               # first sync: checkpoint from zero
            if co.length > start:
                todo.append((co, start, first))
        if not todo:
            return None
        n, W = len(todo), int(max(co.length - start
                                  for co, start, _ in todo))
        n_pad, W_pad = _pow2(n), _pow2(W)
        pad = [todo[0]] * (n_pad - n)
        slots = np.array([[co.slot] for co, _, _ in todo + pad], np.int32)
        starts = np.array([start for _, start, _ in todo + pad])
        pos = np.minimum(starts[:, None] + np.arange(W_pad)[None],
                         self.max_len - 1).astype(np.int32)
        blob = self._get_gather(n_pad, W_pad)(
            self.cache, jnp.asarray(slots), jnp.asarray(pos))
        snaps = []
        for co, start, first in todo:
            snaps.append((co.seq_id, start, co.length - start, first))
            self.synced_len[co.seq_id] = co.length
        self._sync_tag += 1
        nbytes = int(np.prod(blob.shape)) * blob.dtype.itemsize
        return _InFlightSync(blob, self._blob_metas, snaps, nbytes,
                             f"sync{self._sync_tag}")

    def stage_appends(self, active: Sequence[SequenceCoroutine]):
        """Issue the page's dirty-window KV gather and start its async
        device→host copy; the blob rides the ring buffer until
        ``drain_appends`` lands it.  With ``overlap=False`` (or when the
        blob cannot fit the ring even after a forced drain) this degrades
        to the blocking synchronous path."""
        ent = self._gather_dirty(active)
        if ent is None:
            return
        if not self.overlap:
            self._materialize(ent)
            return
        if not self.ring.can_fit(ent.nbytes):
            # backpressure: land everything in flight, then retry the
            # reservation — the stall the plan optimizer sizes
            # ring_buffer_bytes against
            self.sync_stalls += 1
            self.drain_appends()
        if self.ring.can_fit(ent.nbytes):
            try:
                self.transfer("stage",
                              lambda: compat.copy_to_host_async(ent.blob))
            except TransferDeadLetter:
                self._abandon_blob(ent)
                return
            self.ring.reserve(ent.name, ent.nbytes)
            self._inflight.append(ent)
            self.sync_stages += 1
            self.staged_bytes += ent.nbytes
        else:
            self._materialize(ent)      # blob larger than the whole ring

    def drain_appends(self, keep_newest: int = 0):
        """Land staged blobs in the host store, oldest first.  The
        scheduler's SYNC_DRAIN phase keeps the newest blob in flight
        (``keep_newest=1``) so its copy rides behind the next megastep;
        every consumer of host-store state (evict / migrate / failure
        recovery) forces a full drain first."""
        while len(self._inflight) > keep_newest:
            ent = self._inflight.popleft()
            self.ring.release(ent.name)
            self._materialize(ent)
            self.sync_drains += 1

    def _materialize(self, ent: _InFlightSync):
        """Blocking half of the pipeline: wait for the blob's copy (the
        ONE host transfer for the page's KV) and append it page-by-page
        into the host store."""
        t0 = time.perf_counter()
        try:
            blob = self.transfer("drain", lambda: self._to_host(ent.blob))
        except TransferDeadLetter:
            self._abandon_blob(ent)
            return
        finally:
            self.sync_wait_s += time.perf_counter() - t0
        offs, off = {}, 0
        for name, trail, f in ent.metas:
            offs[name] = (off, off + f)
            off += f
        L = blob.shape[0]
        for i, (seq_id, start, n, first) in enumerate(ent.snaps):
            if not first and not self.host_store.has(seq_id):
                continue    # dropped (evicted) after issue: do not resurrect
            slices = {}
            for name, trail, _ in ent.metas:
                lo, hi = offs[name]
                slices[name] = blob[:, i, :n, lo:hi].reshape((L, n) + trail)
            if self.host_store.has(seq_id):
                self.host_store.append_tokens(seq_id, slices, start)
            else:
                self.host_store.checkpoint(seq_id, slices, start + n)

    def _abandon_blob(self, ent: _InFlightSync):
        """A staged blob was lost to a dead-lettered transfer.  Its
        sequences' host checkpoints now lag their coroutines' generated
        streams, so a later migrate would resume from CORRUPT state —
        drop their host-store entries entirely; the NODE_FAILURE recovery
        this dead-letter escalates to will recompute them from their
        prompts (bitwise-identical tokens, §5.6)."""
        self.abandoned_blobs += 1
        for seq_id, _start, _n, _first in ent.snaps:
            if self.host_store.has(seq_id):
                self.host_store.drop(seq_id)
            self.synced_len.pop(seq_id, None)

    # ------------------------------------- staged h2d restores (governor)
    def stage_restore(self, co: SequenceCoroutine) -> bool:
        """Prefetch a suspended sequence's host checkpoint toward the
        device (async ``device_put`` per cache leaf) behind a ring-buffer
        reservation — the h2d mirror of ``stage_appends``.  The copy
        overlaps the decode page(s) between now and the sequence's
        COMBINE, where ``take_restore`` consumes it without PCIe wait.
        Returns True when a restore is staged for the sequence
        (pre-existing counts); False when it cannot be (no host state, or
        the ring has no room — counted in ``restore_stalls``, the same
        backpressure signal the d2h pipeline uses)."""
        ent = self._restore_staged.get(co.seq_id)
        if ent is not None:
            if (self.host_store.has(co.seq_id)
                    and self.host_store.seqs[co.seq_id].length == ent[1]):
                return True
            self.discard_restore(co.seq_id)     # stale: checkpoint advanced
        if not self.host_store.has(co.seq_id):
            return False
        t0 = time.perf_counter()
        slices = self.host_store.restore(co.seq_id, self.max_len)
        nbytes = sum(int(np.asarray(v).nbytes) for v in slices.values())
        if not self.restore_ring.can_fit(nbytes):
            self.restore_stalls += 1
            return False
        try:
            dev = self.transfer("restore", lambda: {
                k: jax.device_put(v) for k, v in slices.items()})
        except TransferDeadLetter:
            return False
        self.restore_ring.reserve(f"restore{co.seq_id}", nbytes)
        self._restore_staged[co.seq_id] = (
            dev, self.host_store.seqs[co.seq_id].length,
            f"restore{co.seq_id}", nbytes, time.perf_counter() - t0)
        self.restore_stages += 1
        self.restore_staged_bytes += nbytes
        return True

    def restore_ready(self, seq_id: int) -> bool:
        """True when the sequence's staged restore has drained: a live
        (non-stale) prefetch is in the ring, so COMBINE's ``take_restore``
        installs it without a synchronous PCIe wait."""
        ent = self._restore_staged.get(seq_id)
        return (ent is not None and self.host_store.has(seq_id)
                and self.host_store.seqs[seq_id].length == ent[1])

    def take_restore(self, seq_id: int) -> Optional[Dict]:
        """Consume a staged restore for COMBINE.  A prefetch whose source
        checkpoint advanced since staging is stale and discarded (the
        append pipeline drained new pages into the host store) — the
        restore then falls back to the synchronous path.  Returns None
        only when the sequence has no host state at all."""
        ent = self._restore_staged.pop(seq_id, None)
        cur = (self.host_store.seqs[seq_id].length
               if self.host_store.has(seq_id) else None)
        if ent is not None:
            dev, length, name, nbytes, cost = ent
            self.restore_ring.release(name)
            if cur is not None and cur == length:
                # the device_put overlapped the pages decoded since it
                # was staged: its transfer time was hidden behind compute
                self.restore_wait_s += cost
                self.restore_stage_hidden_s += cost
                return dev
        if cur is None:
            return None
        t0 = time.perf_counter()
        slices = self.host_store.restore(seq_id, self.max_len)
        self.restore_wait_s += time.perf_counter() - t0
        return slices

    def discard_restore(self, seq_id: int) -> None:
        """Drop one staged restore and release its ring reservation
        (MIGRATE moved the state to another node, or it went stale)."""
        ent = self._restore_staged.pop(seq_id, None)
        if ent is not None:
            self.restore_ring.release(ent[2])

    def discard_restores(self) -> None:
        """Drop every staged restore (NODE_FAILURE teardown: the target
        devices are gone; the ring was already reset)."""
        for seq_id in list(self._restore_staged):
            self.discard_restore(seq_id)

    def prefill(self, cos: Sequence[SequenceCoroutine]):
        """Prefill a batch of INIT coroutines; leaves them INACTIVE with KV
        checkpointed to the host store (paper Fig. 7 prefill flow).

        Executables are bucketed to (pow2 batch, pow2 sequence) and held in
        a small LRU so long mixed workloads can't accumulate one jit per
        exact (B, S).

        With the prefix index enabled the batch is first deduplicated by
        prompt: a fork fan-out (or identical duplicate submits) forwards
        the prompt ONCE and every sibling samples its first token from the
        lead's logits row with its own seed — bitwise-identical to
        independent submissions because the forward and the sampler are
        row-wise and the first-token key is fold_in(PRNGKey(seed), 0) per
        row.  A lead whose leading full pages already sit in the index
        (cross-submit hit) skips their forward entirely: the span's host
        pages are grafted into a dense cache and only the prompt tail is
        teacher-forced through the decode step, which reproduces the full
        prefill's last-position logits and cache bitwise (decode and
        prefill share one attention implementation)."""
        if self.faults is not None and self.faults.dead:
            return          # zombie: coroutines stay INIT for recovery
        if not cos:
            return
        idx = self.host_store.prefix_index
        # prompt-identical groups (a fork fan-out arrives as one group); a
        # disabled index degrades to singleton groups == the PR 1-7 path
        groups: "OrderedDict[tuple, List[SequenceCoroutine]]" = OrderedDict()
        lead_of: Dict[int, int] = {}
        for c in cos:
            key = tuple(c.prompt) if idx is not None else ("seq", c.seq_id)
            groups.setdefault(key, []).append(c)
        for group in groups.values():
            for c in group:
                lead_of[c.seq_id] = group[0].seq_id
        leads = [g[0] for g in groups.values()]
        names = list(self.cache.keys())
        P = self.host_store.page_size
        # cross-submit hits: cap the reuse at the last full page BEFORE the
        # final prompt position — the last position must be recomputed to
        # produce the first-token logits
        hits: Dict[int, list] = {}
        fresh: List[SequenceCoroutine] = []
        for lead in leads:
            chain = []
            if idx is not None:
                chain = idx.match(lead.prompt)[: (lead.prompt_len - 1) // P]
                if chain and not all(all(nm in nd.pages for nm in names)
                                     for nd in chain):
                    chain = []      # span missing a cache leaf: recompute
            if chain:
                hits[lead.seq_id] = chain
            else:
                fresh.append(lead)

        lead_rows: Dict[int, object] = {}   # lead seq_id -> (V,) logits row
        fresh_logits = None
        if fresh:
            maxlen = max(c.prompt_len for c in fresh)
            S = max(_pow2(maxlen), 8)         # pow2 sequence bucket
            B = max(_pow2(len(fresh)), 1)     # pow2 batch bucket (padded)
            toks = np.zeros((B, S), np.int32)  # left-align, pad after
            last_idx = np.zeros((B,), np.int32)
            for i, c in enumerate(fresh):
                toks[i, : c.prompt_len] = c.prompt[:]
                last_idx[i] = c.prompt_len - 1
            def make():
                def _prefill_impl(params, tokens, last):
                    h, _, caches = T._backbone(self.cfg, self.axes, params,
                                               {"tokens": tokens}, None,
                                               True, False)  # h final-normed
                    hl = jnp.take_along_axis(h, last[:, None, None].astype(
                        jnp.int32).repeat(h.shape[-1], -1), axis=1)
                    logits = T.logits_fn(self.cfg, params, hl)
                    return logits, caches
                return jax.jit(_prefill_impl)
            fn = _lru_get(self._prefill_cache, (B, S), _PREFILL_JIT_CAP, make)
            fresh_logits, cache = fn(self.params, jnp.asarray(toks),
                                     jnp.asarray(last_idx))
            nf = len(fresh)
            # batched host-checkpoint gather: flatten every leaf's first-nf
            # rows into ONE (L, nf, W, F_total) blob and move it with a
            # single host transfer (the per-sequence/per-leaf slicing this
            # replaces paid n_seqs * n_leaves small copies per batch)
            W = maxlen
            assert len({leaf.dtype for leaf in cache.values()}) == 1, \
                "batched gather concatenates leaves: mixed dtypes would " \
                "be silently promoted — add a per-dtype blob before " \
                "relaxing this"
            metas, parts = [], []
            for name, leaf in cache.items():
                seg = leaf[:, :nf, :W]              # (L, nf, W, *trail)
                trail = seg.shape[3:]
                metas.append((name, trail,
                              int(np.prod(trail)) if trail else 1))
                parts.append(seg.reshape(seg.shape[0], nf, W, -1))
            blob = self._to_host(jnp.concatenate(parts, axis=-1))
            offs, off = {}, 0
            for name, trail, f in metas:
                offs[name] = (off, off + f)
                off += f
            L = blob.shape[0]
            for i, lead in enumerate(fresh):
                pl = lead.prompt_len
                slices = {}
                for name, trail, _ in metas:
                    lo, hi = offs[name]
                    slices[name] = blob[:, i, :pl, lo:hi].reshape(
                        (L, pl) + trail)
                self.host_store.checkpoint(lead.seq_id, slices, pl)
                lead_rows[lead.seq_id] = fresh_logits[i, 0, :]
                self.prefill_tokens += pl

        # prefix-hit leads: graft the span's host pages into a dense cache
        # and teacher-force only the tail (at most one page + the partial
        # block) through the decode step
        for lead in leads:
            chain = hits.get(lead.seq_id)
            if chain is None:
                continue
            m = len(chain) * P
            pl = lead.prompt_len
            self.host_store.attach_shared(lead.seq_id, chain)
            S = max(_pow2(pl), 8)
            dense = T.init_cache(self.cfg, 1, S)
            for name in names:
                seg = np.concatenate([nd.pages[name] for nd in chain],
                                     axis=1)        # (L, m, *trail)
                dense[name] = dense[name].at[:, :, :m].set(
                    jnp.asarray(seg)[:, None])
            if self._decode_logits is None:
                self._decode_logits = jax.jit(
                    lambda p, c, t, l: T.decode_step_logits(
                        self.cfg, self.axes, p, c, t, l),
                    donate_argnums=(1,))
            row = None
            for t in range(m, pl):
                row, dense = self._decode_logits(
                    self.params, dense,
                    jnp.asarray([lead.prompt[t]], jnp.int32),
                    jnp.asarray([t], jnp.int32))
            slices = {name: self._to_host(dense[name][:, 0, m:pl])
                      for name in names}
            self.host_store.append_tokens(lead.seq_id, slices, m)
            lead_rows[lead.seq_id] = row[0]
            lead.prefix_hit_tokens = m
            self.prefill_tokens += pl - m
            self.prefill_tokens_saved += m

        # publish every lead's prompt pages (dedupes to canonical frozen
        # spans), then bind fork siblings to the lead's span COW
        if idx is not None:
            for group in groups.values():
                lead = group[0]
                self.host_store.publish_prefix(lead.seq_id, lead.prompt)
                for sib in group[1:]:
                    self.host_store.clone_shared(lead.seq_id, sib.seq_id)
                    sib.prefix_hit_tokens = sib.prompt_len
                    self.prefill_tokens_saved += sib.prompt_len

        n = len(cos)
        if fresh_logits is not None and len(fresh) == n:
            logits2d = fresh_logits[:n, 0, :]   # no dedupe/hit: batch rows
        else:
            logits2d = jnp.stack([lead_rows[lead_of[c.seq_id]] for c in cos])
        # first generated token: device-sampled when any sequence asks for
        # it (key = fold_in(PRNGKey(seed), 0), counts over the prompt);
        # all-greedy batches keep the host argmax
        logits_np = None
        if any(not c.sampling.is_greedy_default for c in cos):
            sp = smp.pack_params([c.sampling for c in cos],
                                 [c.seq_id for c in cos])
            st = smp.init_state(sp["seed"], [list(c.prompt) for c in cos],
                                [[] for _ in cos],
                                T.padded_vocab(self.cfg))
            flags = smp.flags_for([c.sampling for c in cos],
                                  T.padded_vocab(self.cfg))
            draw = self._get_prefill_sampler(n, flags)
            first = self._to_host(draw(
                logits2d, jnp.asarray(st["prompt_counts"]),
                jnp.asarray(st["counts"]),
                {k: jnp.asarray(sp[k]) for k in _SAMPLE_ROW_KEYS},
                jnp.asarray(smp.base_keys_host(st["seed"]))))
        else:
            logits_np = self._to_host(logits2d)
            first = np.argmax(logits_np, axis=-1)
        lp_np = None
        if any(c.logprobs for c in cos):
            if logits_np is None:       # sampled batch: logits still on dev
                logits_np = self._to_host(logits2d)
            lp_np = _np_log_softmax(logits_np)
        for i, co in enumerate(cos):
            pl = co.prompt_len
            co.last_token = int(first[i])
            co.generated.append(co.last_token)
            if co.logprobs and lp_np is not None:
                topv = topi = None
                if co.top_logprobs:
                    topi = [_np_top_k_idx(lp_np[i], co.top_logprobs)]
                    topv = [lp_np[i][topi[0]]]
                self._append_logprobs(
                    co, [float(lp_np[i, co.last_token])], topv, topi)
            if co.last_token in co.sampling.stop:
                co.stopped = True
            co.length = pl
            co.phase = Phase.DECODING
            co.status = Status.INACTIVE
            self.synced_len[co.seq_id] = pl
        f = 1.0
        if self.faults is not None:
            f = max(self.faults.straggler_factor(), 1.0)
        self.tokens_out += len(cos) / f     # one first token per sequence


# NodeEngine declares conformance to the formal backend contract; the
# scheduler re-validates instances (including the data members created in
# __init__) at construction.
validate_backend(NodeEngine)
