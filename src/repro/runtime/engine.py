"""Per-node batch-inference engine: real JAX execution + coroutine slots.

One NodeEngine = one node's GPU pool.  It owns a dense device decode cache
with `max_active` sequence slots, a paged host store (single source of
truth, §5.2), a page allocator (two-page lazy allocation), and jitted
prefill/decode steps.  The CoroutineScheduler drives it exclusively through
the slot protocol, so the exact same scheduling code also drives the
cluster simulator.

Supports dense and MoE families (caches {"k","v"}); set
``module_granularity=True`` to decode through the Algorithm-1 module
runtime (per-sub-batch attention + COMBINE before MoE) instead of the
monolithic decode_step.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coroutine import Phase, SequenceCoroutine, Status
from repro.core.forward import ModuleRuntime
from repro.core.primitives import PrimitiveStats
from repro.memory.allocator import PageAllocator
from repro.memory.paged_kv import HostKVStore
from repro.models import transformer as T
from repro.models.api import MeshAxes, ModelConfig


class NodeEngine:
    def __init__(self, cfg: ModelConfig, *, node_id: int = 0,
                 max_active: int = 8, max_len: int = 256,
                 page_size: int = 32, num_devices: int = 8,
                 device_pages: Optional[int] = None,
                 module_granularity: bool = False, b_attn: int = 0,
                 seed: int = 0):
        assert cfg.family in ("dense", "moe") and cfg.sliding_window == 0, \
            "mini-engine supports dense/moe caches; see cluster sim for rest"
        self.cfg = cfg
        self.axes = MeshAxes(batch=("data",), model="model")
        self.node_id = node_id
        self.max_active = max_active
        self.max_len = max_len
        self.num_devices = num_devices
        self.page_size = page_size

        self.params = T.init_params(cfg, jax.random.PRNGKey(seed))
        self.host_store = HostKVStore(page_size)
        total_pages = device_pages or (max_active * max_len // page_size * 2)
        self.allocator = PageAllocator(total_pages, page_size)
        self.stats = PrimitiveStats()

        # device slot arrays
        self.cache = T.init_cache(cfg, max_active, max_len)
        self.tokens = jnp.zeros((max_active,), jnp.int32)
        self.lengths = jnp.zeros((max_active,), jnp.int32)
        self.slot_owner: List[Optional[int]] = [None] * max_active
        self.synced_len: Dict[int, int] = {}

        self._decode = jax.jit(
            lambda p, c, t, l: T.decode_step(cfg, self.axes, p, c, t, l),
            donate_argnums=(1,))
        self._prefill_cache: Dict[int, object] = {}
        self.module_rt = (ModuleRuntime(cfg, self.axes, self.params)
                          if module_granularity else None)
        self.b_attn = b_attn or max_active
        self.decode_steps = 0
        self.prefill_tokens = 0

    # ------------------------------------------------------------- protocol
    def clock(self) -> float:
        return time.monotonic()

    def idle_tick(self):
        pass

    def acquire_slot(self, co: SequenceCoroutine) -> Optional[int]:
        if not self.allocator.can_admit(2):
            return None
        for s, owner in enumerate(self.slot_owner):
            if owner is None:
                self.slot_owner[s] = co.seq_id
                self.allocator.alloc(co.seq_id, 2)
                return s
        return None

    def free_slot(self, co: SequenceCoroutine):
        if co.slot is not None and self.slot_owner[co.slot] == co.seq_id:
            self.slot_owner[co.slot] = None
            self.lengths = self.lengths.at[co.slot].set(0)

    def extract_slot(self, co: SequenceCoroutine) -> Dict[str, np.ndarray]:
        s = co.slot
        return {name: np.asarray(leaf[:, s]) for name, leaf in
                self.cache.items()}

    def install_slot(self, co: SequenceCoroutine, slices: Dict[str, np.ndarray]):
        s = co.slot
        for name, arr in slices.items():
            if name not in self.cache:
                continue
            leaf = self.cache[name]
            pad = leaf.shape[2] - arr.shape[1]
            a = np.pad(arr, [(0, 0), (0, pad)] + [(0, 0)] * (arr.ndim - 2)) \
                if pad > 0 else arr[:, : leaf.shape[2]]
            self.cache[name] = leaf.at[:, s].set(jnp.asarray(a, leaf.dtype))
        self.tokens = self.tokens.at[s].set(co.last_token)
        self.lengths = self.lengths.at[s].set(co.length)
        self.synced_len[co.seq_id] = co.length

    def reconfigure_partition(self, co: SequenceCoroutine, group: List[int]):
        # On TPU: re-lower the decode step over the group mesh (sequence-
        # split KV).  Single-host CPU: bookkeeping only; the cluster
        # simulator models the speedup (runtime/cluster.py).
        pass

    # ------------------------------------------------------------- compute
    def decode_page(self, active: Sequence[SequenceCoroutine], P: int):
        """Decode up to P tokens for every active sequence."""
        by_slot = {c.slot: c for c in active}
        steps = min(P, max(c.remaining for c in active))
        for _ in range(steps):
            if self.module_rt is not None:
                nxt, self.cache = self.module_rt.forward_decode(
                    self.tokens, self.cache, self.lengths, self.b_attn)
            else:
                nxt, self.cache = self._decode(self.params, self.cache,
                                               self.tokens, self.lengths)
            self.decode_steps += 1
            nxt_np = np.asarray(nxt)
            upd_tok, upd_len = [], []
            for s, co in by_slot.items():
                if co.remaining > 0:
                    tok = int(nxt_np[s])
                    co.generated.append(tok)
                    co.last_token = tok
                    co.length += 1
                    upd_tok.append((s, tok))
                    upd_len.append((s, co.length))
            if upd_tok:
                idx = jnp.array([s for s, _ in upd_tok])
                self.tokens = self.tokens.at[idx].set(
                    jnp.array([t for _, t in upd_tok], jnp.int32))
                self.lengths = self.lengths.at[idx].set(
                    jnp.array([l for _, l in upd_len], jnp.int32))
            if all(c.remaining == 0 for c in active):
                break

    def sync_appends(self, active: Sequence[SequenceCoroutine]):
        """Propagate freshly decoded KV entries to the host store (§5.3 i)."""
        for co in active:
            start = self.synced_len.get(co.seq_id, 0)
            if co.length <= start or co.slot is None:
                continue
            slices = {name: np.asarray(leaf[:, co.slot, start:co.length])
                      for name, leaf in self.cache.items()}
            if self.host_store.has(co.seq_id):
                self.host_store.append_tokens(co.seq_id, slices, start)
            else:
                full = {name: np.asarray(leaf[:, co.slot, :co.length])
                        for name, leaf in self.cache.items()}
                self.host_store.checkpoint(co.seq_id, full, co.length)
            self.synced_len[co.seq_id] = co.length

    def prefill(self, cos: Sequence[SequenceCoroutine]):
        """Prefill a batch of INIT coroutines; leaves them INACTIVE with KV
        checkpointed to the host store (paper Fig. 7 prefill flow)."""
        if not cos:
            return
        maxlen = max(c.prompt_len for c in cos)
        S = max(1 << (maxlen - 1).bit_length(), 8)  # pow2 bucket
        B = len(cos)
        toks = np.zeros((B, S), np.int32)           # left-align, pad after
        last_idx = np.zeros((B,), np.int32)
        for i, c in enumerate(cos):
            toks[i, : c.prompt_len] = c.prompt[:]
            last_idx[i] = c.prompt_len - 1
        key = (B, S)
        if key not in self._prefill_cache:
            def _prefill_impl(params, tokens, last):
                h, _, caches = T._backbone(self.cfg, self.axes, params,
                                           {"tokens": tokens}, None, True,
                                           False)   # h is final-normed
                hl = jnp.take_along_axis(h, last[:, None, None].astype(
                    jnp.int32).repeat(h.shape[-1], -1), axis=1)
                logits = T.logits_fn(self.cfg, params, hl)
                return logits, caches
            self._prefill_cache[key] = jax.jit(_prefill_impl)
        logits, cache = self._prefill_cache[key](
            self.params, jnp.asarray(toks), jnp.asarray(last_idx))
        logits_np = np.asarray(logits)
        for i, co in enumerate(cos):
            slices = {name: np.asarray(leaf[:, i, : co.prompt_len])
                      for name, leaf in cache.items()}
            self.host_store.checkpoint(co.seq_id, slices, co.prompt_len)
            co.last_token = int(np.argmax(logits_np[i, 0]))
            co.generated.append(co.last_token)
            co.length = co.prompt_len
            co.phase = Phase.DECODING
            co.status = Status.INACTIVE
            self.synced_len[co.seq_id] = co.prompt_len
            self.prefill_tokens += co.prompt_len
