"""OpenAI-compatible Batch API objects + master front-end (paper §5.6).

In-process implementation of the protocol shape (no HTTP server in this
container): a BatchMaster per model-parallel group accepts batch
submissions, over-subscribes its engines (dispatching far more requests
than concurrent capacity so the runtime can COMBINE from a deep resident
pool, §6.4 'Production deployment'), and returns results preserving input
order.
"""
from __future__ import annotations

import dataclasses
import json
import time
import uuid
from typing import Any, Dict, List, Optional, Sequence

from repro.core.scheduler import CoroutineScheduler, SchedulerConfig
from repro.sampling import SamplingParams


@dataclasses.dataclass
class BatchRequest:
    """One line of an OpenAI batch input file."""
    custom_id: str
    prompt: List[int]
    max_tokens: int = 128
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)

    @classmethod
    def from_json(cls, line: str) -> "BatchRequest":
        d = json.loads(line)
        body = d.get("body", d)
        sp = SamplingParams(
            temperature=float(body.get("temperature", 0.0)),
            top_k=int(body.get("top_k", 0)),
            top_p=float(body.get("top_p", 1.0)),
            min_p=float(body.get("min_p", 0.0)),
            repetition_penalty=float(body.get("repetition_penalty", 1.0)),
            presence_penalty=float(body.get("presence_penalty", 0.0)),
            frequency_penalty=float(body.get("frequency_penalty", 0.0)),
            seed=body.get("seed"),
            stop=tuple(body.get("stop", ())))
        return cls(custom_id=d.get("custom_id", str(uuid.uuid4())),
                   prompt=body["prompt"],
                   max_tokens=int(body.get("max_tokens", 128)),
                   sampling=sp)


@dataclasses.dataclass
class BatchObject:
    id: str
    status: str = "validating"        # validating|in_progress|completed
    created_at: float = dataclasses.field(default_factory=time.time)
    completed_at: Optional[float] = None
    request_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"total": 0, "completed": 0, "failed": 0})
    results: List[Dict[str, Any]] = dataclasses.field(default_factory=list)


class BatchMaster:
    """Master node: accepts batches, partitions sequences across workers via
    the coroutine scheduler, streams results to an output buffer."""

    def __init__(self, engines: Sequence, sched_cfg: SchedulerConfig = None,
                 oversubscribe: float = 4.0):
        self.engines = list(engines)
        self.sched_cfg = sched_cfg or SchedulerConfig()
        self.oversubscribe = oversubscribe
        self.batches: Dict[str, BatchObject] = {}

    def submit(self, requests: Sequence[BatchRequest]) -> str:
        bid = f"batch_{uuid.uuid4().hex[:12]}"
        bo = BatchObject(id=bid)
        bo.request_counts["total"] = len(requests)
        bo.status = "in_progress"
        self.batches[bid] = bo
        self._requests = list(requests)
        return bid

    def run(self, bid: str, max_ticks: int = 100000) -> BatchObject:
        bo = self.batches[bid]
        sched = CoroutineScheduler(self.engines, self.sched_cfg)
        ids = sched.submit([r.prompt for r in self._requests],
                           [r.max_tokens for r in self._requests],
                           sampling=[r.sampling for r in self._requests])
        rep = sched.run(max_ticks=max_ticks)
        for req, sid in zip(self._requests, ids):
            co = sched.cos[sid]
            bo.results.append({
                "custom_id": req.custom_id,
                "response": {"tokens": list(co.generated),
                             "finish_reason": (co.finish_reason if co.done
                                               else "incomplete")},
                "status_code": 200 if co.done else 504,
            })
            bo.request_counts["completed" if co.done else "failed"] += 1
        bo.status = "completed"
        bo.completed_at = time.time()
        bo.bct_s = rep["bct_s"]
        return bo

    def retrieve(self, bid: str) -> BatchObject:
        return self.batches[bid]

    def output_file(self, bid: str) -> str:
        """JSONL results, input order preserved (OpenAI batch format)."""
        return "\n".join(json.dumps(r) for r in self.batches[bid].results)
