"""OpenAI-compatible Batch API objects + master front-end (paper §5.6).

In-process implementation of the protocol shape (no HTTP server in this
container): a BatchMaster per model-parallel group accepts batch
submissions, over-subscribes its engines (dispatching far more requests
than concurrent capacity so the runtime can COMBINE from a deep resident
pool, §6.4 'Production deployment'), and serves results **stream-first**:
``BatchMaster.stream(bid)`` yields the scheduler's typed records
(``TokenBlockEvent`` / ``SeqFinishedEvent`` / ``PrimitiveEvent``,
annotated with the request's ``custom_id``) as pages complete, while
``BatchObject.results`` fills incrementally in completion order.
``run()`` is re-implemented on top of the stream — it drains it, then
re-orders the results to input order (the OpenAI batch contract).
"""
from __future__ import annotations

import dataclasses
import json
import time
import uuid
from typing import Any, Dict, Iterator, List, Optional, Sequence

from repro.core.events import RuntimeRecord, SeqFinishedEvent
from repro.core.scheduler import CoroutineScheduler, SchedulerConfig
from repro.sampling import SamplingParams


@dataclasses.dataclass
class BatchRequest:
    """One line of an OpenAI batch input file."""
    custom_id: str
    prompt: List[int]
    max_tokens: int = 128
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    logprobs: bool = False          # return chosen-token logprobs
    top_logprobs: int = 0           # also return the top-K alternatives

    @classmethod
    def from_json(cls, line: str) -> "BatchRequest":
        return cls.from_dict(json.loads(line))

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BatchRequest":
        """Build from an already-parsed input line — the streaming driver
        peeks ``custom_id`` before deciding whether to materialize the
        request at all (resume skip / duplicate skip)."""
        body = d.get("body", d)
        sp = SamplingParams(
            temperature=float(body.get("temperature", 0.0)),
            top_k=int(body.get("top_k", 0)),
            top_p=float(body.get("top_p", 1.0)),
            min_p=float(body.get("min_p", 0.0)),
            repetition_penalty=float(body.get("repetition_penalty", 1.0)),
            presence_penalty=float(body.get("presence_penalty", 0.0)),
            frequency_penalty=float(body.get("frequency_penalty", 0.0)),
            seed=body.get("seed"),
            stop=tuple(body.get("stop", ())),
            deadline_s=(float(body["deadline_s"])
                        if body.get("deadline_s") is not None else None))
        return cls(custom_id=d.get("custom_id", str(uuid.uuid4())),
                   prompt=body["prompt"],
                   max_tokens=int(body.get("max_tokens", 128)),
                   sampling=sp,
                   logprobs=bool(body.get("logprobs", False)),
                   top_logprobs=int(body.get("top_logprobs", 0)))


@dataclasses.dataclass
class BatchObject:
    id: str
    status: str = "validating"        # validating|in_progress|completed
    created_at: float = dataclasses.field(default_factory=time.time)
    completed_at: Optional[float] = None
    request_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"total": 0, "completed": 0, "failed": 0})
    results: List[Dict[str, Any]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _LiveBatch:
    """Working state of one incremental (driver-fed) batch: a long-lived
    scheduler that requests are appended to over time and pumped round by
    round.  Memory is bounded by the in-flight set, not the job: finished
    sequences are retired from the scheduler the moment their row is
    captured, and rows leave via ``pop_row`` (write-ahead consumers
    journal them immediately)."""
    sched: CoroutineScheduler
    by_seq: Dict[int, BatchRequest] = dataclasses.field(default_factory=dict)
    rows: Dict[int, Dict[str, Any]] = dataclasses.field(default_factory=dict)
    appended: int = 0
    finished: int = 0


class BatchMaster:
    """Master node: accepts batches, partitions sequences across workers via
    the coroutine scheduler, streams results as they complete.

    Two submission surfaces:

    * ``submit`` + ``run``/``stream`` — the OpenAI-style one-shot batch
      (whole request list up front, results retained on the batch object).
    * ``open`` + ``append``/``pump`` — the incremental surface the
      streaming job driver feeds: requests trickle in under a bounded
      window, each ``pump`` runs ONE scheduler round and returns its
      records, finished rows are popped (not retained), and ``cancel``
      hands back whatever never finished (replica drain/requeue)."""

    def __init__(self, engines: Sequence, sched_cfg: SchedulerConfig = None,
                 oversubscribe: float = 4.0, policy=None, fault_plan=None):
        self.engines = list(engines)
        self.sched_cfg = sched_cfg or SchedulerConfig()
        self.oversubscribe = oversubscribe
        # robustness passthrough (§5.6): a SchedulerPolicy (e.g. with a
        # recovery_choice hook) and/or a seeded FaultPlan applied to every
        # scheduler this master builds
        self.policy = policy
        self.fault_plan = fault_plan
        self.batches: Dict[str, BatchObject] = {}
        # per-batch working state, dropped at _finalize (only the
        # BatchObject survives a finished batch)
        self._requests: Dict[str, List[BatchRequest]] = {}
        self._scheds: Dict[str, CoroutineScheduler] = {}
        self._ids: Dict[str, List[int]] = {}
        self._rows: Dict[str, Dict[int, Dict[str, Any]]] = {}
        self._live: Dict[str, _LiveBatch] = {}

    def submit(self, requests: Sequence[BatchRequest]) -> str:
        bid = f"batch_{uuid.uuid4().hex[:12]}"
        bo = BatchObject(id=bid)
        bo.request_counts["total"] = len(requests)
        bo.status = "in_progress"
        self.batches[bid] = bo
        self._requests[bid] = list(requests)
        return bid

    # ----------------------------------------------------- incremental batch
    def open(self) -> str:
        """Start a long-lived incremental batch: the scheduler exists
        immediately, requests arrive later via ``append``, and the caller
        pumps rounds explicitly.  This is one elastic data-parallel
        *replica* from the streaming driver's point of view."""
        bid = f"batch_{uuid.uuid4().hex[:12]}"
        bo = BatchObject(id=bid, status="in_progress")
        self.batches[bid] = bo
        self._live[bid] = _LiveBatch(
            sched=CoroutineScheduler(self.engines, self.sched_cfg,
                                     policy=self.policy,
                                     fault_plan=self.fault_plan))
        return bid

    def append(self, bid: str,
               requests: Sequence[BatchRequest]) -> List[int]:
        """Feed more requests to a live batch; the next pumped round's
        REFILL admits them (mid-stream COMBINE)."""
        lb = self._live[bid]
        reqs = list(requests)
        ids = lb.sched.submit([r.prompt for r in reqs],
                              [r.max_tokens for r in reqs],
                              sampling=[r.sampling for r in reqs],
                              logprobs=[r.logprobs for r in reqs],
                              top_logprobs=[r.top_logprobs for r in reqs])
        for sid, r in zip(ids, reqs):
            lb.by_seq[sid] = r
        lb.appended += len(reqs)
        self.batches[bid].request_counts["total"] += len(reqs)
        return ids

    def pump(self, bid: str) -> List[RuntimeRecord]:
        """Run ONE scheduler round of a live batch; returns its records
        with ``custom_id`` annotated.  Each ``SeqFinishedEvent``'s result
        row is staged for ``pop_row`` and the sequence is retired from the
        scheduler — resident state stays proportional to the in-flight
        window, never the job."""
        lb = self._live[bid]
        recs = lb.sched.step()
        finished: List[int] = []
        for rec in recs:
            req = lb.by_seq.get(rec.seq_id)
            if req is not None:
                rec.custom_id = req.custom_id
                if isinstance(rec, SeqFinishedEvent):
                    lb.rows[rec.seq_id] = self._result_row(
                        req, lb.sched.cos[rec.seq_id])
                    finished.append(rec.seq_id)
        for sid in finished:
            lb.sched.retire(sid)
            del lb.by_seq[sid]
            lb.finished += 1
            self.batches[bid].request_counts["completed"] += 1
        return recs

    def pop_row(self, bid: str, seq_id: int) -> Optional[Dict[str, Any]]:
        """Take ownership of one finished row (write-ahead consumers
        journal it, then it is gone from the master)."""
        return self._live[bid].rows.pop(seq_id, None)

    def in_flight(self, bid: str) -> int:
        return len(self._live[bid].by_seq)

    def live_engines(self, bid: str) -> List:
        """Engines still in the live batch's scheduler rotation (shrinks
        under NODE_FAILURE / NODE_DRAIN)."""
        return list(self._live[bid].sched.engines)

    def capacity(self, bid: str) -> int:
        """Max requests worth dispatching to this live batch: surviving
        slots times the oversubscription depth (§6.4)."""
        slots = sum(e.max_active for e in self._live[bid].sched.engines)
        return int(slots * self.oversubscribe)

    def scheduler(self, bid: str) -> CoroutineScheduler:
        return self._live[bid].sched

    def cancel(self, bid: str) -> List[BatchRequest]:
        """Tear down a live batch NOW and hand back every request that has
        no captured row — the drain/requeue path.  (Rows still staged in
        ``rows`` are NOT returned: their requests finished and a consumer
        should ``pop_row`` them before cancelling.)"""
        lb = self._live.pop(bid)
        bo = self.batches[bid]
        bo.status = "drained"
        bo.completed_at = time.time()
        rep = lb.sched.report()
        bo.scheduler_status = rep["status"]
        bo.bct_s = rep["bct_s"]
        self._final_reports = getattr(self, "_final_reports", {})
        self._final_reports[bid] = rep
        return list(lb.by_seq.values())

    def close(self, bid: str) -> BatchObject:
        """Finalize a live batch whose work is fully consumed."""
        lb = self._live.pop(bid)
        bo = self.batches[bid]
        rep = lb.sched.report()
        bo.status = "completed"
        bo.completed_at = time.time()
        bo.scheduler_status = rep["status"]
        bo.bct_s = rep["bct_s"]
        self._final_reports = getattr(self, "_final_reports", {})
        self._final_reports[bid] = rep
        return bo

    def report(self, bid: str) -> Dict[str, Any]:
        """The scheduler report behind one batch — live (current state) or
        final (snapshot taken at close/cancel).  One scheduler's view; the
        driver-level ``StreamingJobDriver.report()`` merges these across
        replicas."""
        lb = self._live.get(bid)
        if lb is not None:
            return lb.sched.report()
        return getattr(self, "_final_reports", {}).get(bid, {})

    # ------------------------------------------------------------- streaming
    def stream(self, bid: str,
               max_ticks: int = 100000) -> Iterator[RuntimeRecord]:
        """Elastic result surface: yield runtime records as pages complete.

        Each record carries the owning request's ``custom_id``; on every
        ``SeqFinishedEvent`` the request's result row is appended to
        ``BatchObject.results`` (completion order) so pollers see partial
        output while the batch is in flight.  Consume fully (or call
        ``run()``) to finalize the batch object.  Abandoning the stream
        mid-flight leaves the batch ``in_progress``; calling again starts
        a fresh pass (results and counts reset — sequences re-decode).
        A finalized batch cannot be streamed again (use ``retrieve()``)."""
        bo = self.batches[bid]
        if bid not in self._requests:
            raise ValueError(
                f"batch {bid} is already finalized; use retrieve()")
        # fresh pass: discard partial state from any abandoned stream
        bo.results = []
        bo.request_counts["completed"] = 0
        bo.request_counts["failed"] = 0
        reqs = self._requests[bid]
        sched = CoroutineScheduler(self.engines, self.sched_cfg,
                                   policy=self.policy,
                                   fault_plan=self.fault_plan)
        self._scheds[bid] = sched
        ids = sched.submit([r.prompt for r in reqs],
                           [r.max_tokens for r in reqs],
                           sampling=[r.sampling for r in reqs],
                           logprobs=[r.logprobs for r in reqs],
                           top_logprobs=[r.top_logprobs for r in reqs])
        self._ids[bid] = ids
        self._rows[bid] = {}
        by_seq = {sid: r for sid, r in zip(ids, reqs)}
        for rec in sched.events(max_ticks):
            req = by_seq.get(rec.seq_id)
            if req is not None:
                rec.custom_id = req.custom_id
                if isinstance(rec, SeqFinishedEvent):
                    row = self._result_row(req, sched.cos[rec.seq_id])
                    self._rows[bid][rec.seq_id] = row
                    bo.results.append(row)
                    bo.request_counts["completed"] += 1
            yield rec
        self._finalize(bid)

    # ------------------------------------------------------------- blocking
    def run(self, bid: str, max_ticks: int = 100000) -> BatchObject:
        """Run to completion; results preserve input order (OpenAI batch
        contract).  Thin wrapper that drains ``stream()``; idempotent on
        an already-finalized batch."""
        if bid not in self._requests:           # already finalized
            return self.batches[bid]
        for _ in self.stream(bid, max_ticks=max_ticks):
            pass
        return self.batches[bid]

    def _finalize(self, bid: str) -> None:
        """Re-order results to input order (rows keyed by seq_id, so
        duplicate custom_ids cannot collapse), fill 504 rows for anything
        the tick budget cut off, and drop the per-batch working state —
        a long-lived master must not retain one scheduler per batch."""
        bo = self.batches[bid]
        sched = self._scheds.pop(bid)
        reqs = self._requests.pop(bid)
        ids = self._ids.pop(bid)
        rows = self._rows.pop(bid)
        rep = sched.report()
        bo.results = []
        for req, sid in zip(reqs, ids):
            row = rows.get(sid)
            if row is None:             # exhausted before finishing
                row = self._result_row(req, sched.cos[sid])
                bo.request_counts["failed"] += 1
            bo.results.append(row)
        bo.status = "completed"
        bo.completed_at = time.time()
        bo.bct_s = rep["bct_s"]
        bo.scheduler_status = rep["status"]

    @staticmethod
    def _result_row(req: BatchRequest, co) -> Dict[str, Any]:
        resp: Dict[str, Any] = {
            "tokens": list(co.generated),
            "finish_reason": co.finish_reason if co.done else "incomplete",
        }
        if req.logprobs or req.top_logprobs > 0:
            resp["logprobs"] = {
                "token_logprobs": [float(x) for x in co.token_logprobs]}
            if req.top_logprobs > 0:
                resp["logprobs"]["top_logprobs"] = [
                    [[int(t), float(lp)] for t, lp in row]
                    for row in co.top_token_logprobs]
        return {"custom_id": req.custom_id, "response": resp,
                "status_code": 200 if co.done else 504}

    def result_row(self, bid: str, seq_id: int) -> Optional[Dict[str, Any]]:
        """The finished result row for one in-flight sequence, or None if
        it has not finished (or the batch is already finalized).  This is
        what a write-ahead consumer (``runtime/ledger.py``) journals the
        moment the ``SeqFinishedEvent`` comes off the stream."""
        return self._rows.get(bid, {}).get(seq_id)

    def retrieve(self, bid: str) -> BatchObject:
        return self.batches[bid]

    def output_file(self, bid: str) -> str:
        """JSONL results, input order preserved (OpenAI batch format)."""
        return "\n".join(json.dumps(r) for r in self.batches[bid].results)
