"""OpenAI-compatible Batch API objects + master front-end (paper §5.6).

In-process implementation of the protocol shape (no HTTP server in this
container): a BatchMaster per model-parallel group accepts batch
submissions, over-subscribes its engines (dispatching far more requests
than concurrent capacity so the runtime can COMBINE from a deep resident
pool, §6.4 'Production deployment'), and serves results **stream-first**:
``BatchMaster.stream(bid)`` yields the scheduler's typed records
(``TokenBlockEvent`` / ``SeqFinishedEvent`` / ``PrimitiveEvent``,
annotated with the request's ``custom_id``) as pages complete, while
``BatchObject.results`` fills incrementally in completion order.
``run()`` is re-implemented on top of the stream — it drains it, then
re-orders the results to input order (the OpenAI batch contract).
"""
from __future__ import annotations

import dataclasses
import json
import time
import uuid
from typing import Any, Dict, Iterator, List, Optional, Sequence

from repro.core.events import RuntimeRecord, SeqFinishedEvent
from repro.core.scheduler import CoroutineScheduler, SchedulerConfig
from repro.sampling import SamplingParams


@dataclasses.dataclass
class BatchRequest:
    """One line of an OpenAI batch input file."""
    custom_id: str
    prompt: List[int]
    max_tokens: int = 128
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    logprobs: bool = False          # return chosen-token logprobs
    top_logprobs: int = 0           # also return the top-K alternatives

    @classmethod
    def from_json(cls, line: str) -> "BatchRequest":
        d = json.loads(line)
        body = d.get("body", d)
        sp = SamplingParams(
            temperature=float(body.get("temperature", 0.0)),
            top_k=int(body.get("top_k", 0)),
            top_p=float(body.get("top_p", 1.0)),
            min_p=float(body.get("min_p", 0.0)),
            repetition_penalty=float(body.get("repetition_penalty", 1.0)),
            presence_penalty=float(body.get("presence_penalty", 0.0)),
            frequency_penalty=float(body.get("frequency_penalty", 0.0)),
            seed=body.get("seed"),
            stop=tuple(body.get("stop", ())))
        return cls(custom_id=d.get("custom_id", str(uuid.uuid4())),
                   prompt=body["prompt"],
                   max_tokens=int(body.get("max_tokens", 128)),
                   sampling=sp,
                   logprobs=bool(body.get("logprobs", False)),
                   top_logprobs=int(body.get("top_logprobs", 0)))


@dataclasses.dataclass
class BatchObject:
    id: str
    status: str = "validating"        # validating|in_progress|completed
    created_at: float = dataclasses.field(default_factory=time.time)
    completed_at: Optional[float] = None
    request_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"total": 0, "completed": 0, "failed": 0})
    results: List[Dict[str, Any]] = dataclasses.field(default_factory=list)


class BatchMaster:
    """Master node: accepts batches, partitions sequences across workers via
    the coroutine scheduler, streams results as they complete."""

    def __init__(self, engines: Sequence, sched_cfg: SchedulerConfig = None,
                 oversubscribe: float = 4.0, policy=None, fault_plan=None):
        self.engines = list(engines)
        self.sched_cfg = sched_cfg or SchedulerConfig()
        self.oversubscribe = oversubscribe
        # robustness passthrough (§5.6): a SchedulerPolicy (e.g. with a
        # recovery_choice hook) and/or a seeded FaultPlan applied to every
        # scheduler this master builds
        self.policy = policy
        self.fault_plan = fault_plan
        self.batches: Dict[str, BatchObject] = {}
        # per-batch working state, dropped at _finalize (only the
        # BatchObject survives a finished batch)
        self._requests: Dict[str, List[BatchRequest]] = {}
        self._scheds: Dict[str, CoroutineScheduler] = {}
        self._ids: Dict[str, List[int]] = {}
        self._rows: Dict[str, Dict[int, Dict[str, Any]]] = {}

    def submit(self, requests: Sequence[BatchRequest]) -> str:
        bid = f"batch_{uuid.uuid4().hex[:12]}"
        bo = BatchObject(id=bid)
        bo.request_counts["total"] = len(requests)
        bo.status = "in_progress"
        self.batches[bid] = bo
        self._requests[bid] = list(requests)
        return bid

    # ------------------------------------------------------------- streaming
    def stream(self, bid: str,
               max_ticks: int = 100000) -> Iterator[RuntimeRecord]:
        """Elastic result surface: yield runtime records as pages complete.

        Each record carries the owning request's ``custom_id``; on every
        ``SeqFinishedEvent`` the request's result row is appended to
        ``BatchObject.results`` (completion order) so pollers see partial
        output while the batch is in flight.  Consume fully (or call
        ``run()``) to finalize the batch object.  Abandoning the stream
        mid-flight leaves the batch ``in_progress``; calling again starts
        a fresh pass (results and counts reset — sequences re-decode).
        A finalized batch cannot be streamed again (use ``retrieve()``)."""
        bo = self.batches[bid]
        if bid not in self._requests:
            raise ValueError(
                f"batch {bid} is already finalized; use retrieve()")
        # fresh pass: discard partial state from any abandoned stream
        bo.results = []
        bo.request_counts["completed"] = 0
        bo.request_counts["failed"] = 0
        reqs = self._requests[bid]
        sched = CoroutineScheduler(self.engines, self.sched_cfg,
                                   policy=self.policy,
                                   fault_plan=self.fault_plan)
        self._scheds[bid] = sched
        ids = sched.submit([r.prompt for r in reqs],
                           [r.max_tokens for r in reqs],
                           sampling=[r.sampling for r in reqs],
                           logprobs=[r.logprobs for r in reqs],
                           top_logprobs=[r.top_logprobs for r in reqs])
        self._ids[bid] = ids
        self._rows[bid] = {}
        by_seq = {sid: r for sid, r in zip(ids, reqs)}
        for rec in sched.events(max_ticks):
            req = by_seq.get(rec.seq_id)
            if req is not None:
                rec.custom_id = req.custom_id
                if isinstance(rec, SeqFinishedEvent):
                    row = self._result_row(req, sched.cos[rec.seq_id])
                    self._rows[bid][rec.seq_id] = row
                    bo.results.append(row)
                    bo.request_counts["completed"] += 1
            yield rec
        self._finalize(bid)

    # ------------------------------------------------------------- blocking
    def run(self, bid: str, max_ticks: int = 100000) -> BatchObject:
        """Run to completion; results preserve input order (OpenAI batch
        contract).  Thin wrapper that drains ``stream()``; idempotent on
        an already-finalized batch."""
        if bid not in self._requests:           # already finalized
            return self.batches[bid]
        for _ in self.stream(bid, max_ticks=max_ticks):
            pass
        return self.batches[bid]

    def _finalize(self, bid: str) -> None:
        """Re-order results to input order (rows keyed by seq_id, so
        duplicate custom_ids cannot collapse), fill 504 rows for anything
        the tick budget cut off, and drop the per-batch working state —
        a long-lived master must not retain one scheduler per batch."""
        bo = self.batches[bid]
        sched = self._scheds.pop(bid)
        reqs = self._requests.pop(bid)
        ids = self._ids.pop(bid)
        rows = self._rows.pop(bid)
        rep = sched.report()
        bo.results = []
        for req, sid in zip(reqs, ids):
            row = rows.get(sid)
            if row is None:             # exhausted before finishing
                row = self._result_row(req, sched.cos[sid])
                bo.request_counts["failed"] += 1
            bo.results.append(row)
        bo.status = "completed"
        bo.completed_at = time.time()
        bo.bct_s = rep["bct_s"]
        bo.scheduler_status = rep["status"]

    @staticmethod
    def _result_row(req: BatchRequest, co) -> Dict[str, Any]:
        resp: Dict[str, Any] = {
            "tokens": list(co.generated),
            "finish_reason": co.finish_reason if co.done else "incomplete",
        }
        if req.logprobs or req.top_logprobs > 0:
            resp["logprobs"] = {
                "token_logprobs": [float(x) for x in co.token_logprobs]}
            if req.top_logprobs > 0:
                resp["logprobs"]["top_logprobs"] = [
                    [[int(t), float(lp)] for t, lp in row]
                    for row in co.top_token_logprobs]
        return {"custom_id": req.custom_id, "response": resp,
                "status_code": 200 if co.done else 504}

    def result_row(self, bid: str, seq_id: int) -> Optional[Dict[str, Any]]:
        """The finished result row for one in-flight sequence, or None if
        it has not finished (or the batch is already finalized).  This is
        what a write-ahead consumer (``runtime/ledger.py``) journals the
        moment the ``SeqFinishedEvent`` comes off the stream."""
        return self._rows.get(bid, {}).get(seq_id)

    def retrieve(self, bid: str) -> BatchObject:
        return self.batches[bid]

    def output_file(self, bid: str) -> str:
        """JSONL results, input order preserved (OpenAI batch format)."""
        return "\n".join(json.dumps(r) for r in self.batches[bid].results)
