"""Crash-resumable job ledger: write-ahead output log for batch jobs (§5.6).

``runtime/checkpoint.py`` snapshots *engine* state (params, sequence pool,
host KV) — enough to warm-restart a process that shut down cleanly.  This
module covers the other half of preemption tolerance: a **job-level
write-ahead ledger** that survives a SIGKILL mid-batch.  It is the
"crash-resumable progress ledger" the ROADMAP's million-sequence streaming
driver calls for: at that scale a batch runs for days and WILL be
preempted; recomputing finished sequences on every restart makes the job
quadratic.

Design
------
One append-only jsonl file, fsync'd per record, three record kinds:

``{"kind": "meta", "version": 1, ...}``
    header written when the ledger is created.
``{"kind": "submit", "custom_id": ..., "n": ...}``
    the job's request manifest, written before any work starts (so a
    resume can detect a changed request set).
``{"kind": "output", "custom_id": ..., "row": {...}}``
    one finished request's full result row, appended the moment its
    ``SeqFinishedEvent`` lands — the write-ahead part: a request is
    "finished" iff its output record is durably in the ledger.

Crash semantics:

* A SIGKILL between records loses at most the in-flight request(s) — they
  re-run on resume.  Finished rows are never recomputed (the acceptance
  bar: zero recompute of finished sequences).
* A SIGKILL mid-write leaves a torn trailing line; ``JobLedger.open``
  truncates it (the record never committed — its request re-runs).
* **Exactly-once outputs**: ``record_output`` refuses duplicates
  (first-wins by ``custom_id``), so a crash after the write but before
  the scheduler advanced cannot double-emit a row, and a resumed run
  re-streaming a finished id is a no-op.

Determinism is what makes resume *correct*, not just convenient: greedy
decode and the token-addressable fold_in sampled stream are bitwise
reproducible across batch composition, so the rows a resumed run computes
for the unfinished remainder are identical to what the uninterrupted run
would have produced — the combined output file is byte-for-byte the same.

``run_resumable`` packages the protocol: load ledger → skip finished →
submit the remainder → append each finish as it lands → return all rows
in input order.

Chunked segment rotation (million-line jobs)
--------------------------------------------
``JobLedger`` holds every finished row in memory and replays the whole
file on reopen — fine for a batch of thousands, quadratic pain for the
streaming driver's million-line jobs.  ``SegmentedJobLedger`` keeps the
record format but rotates the append file at ``rotate_records`` records
or ``rotate_bytes`` bytes.  Sealing a segment appends ONE fsync'd line to
``index.jsonl`` carrying the segment's ``[custom_id, offset, nbytes]``
locators; a resume therefore reads the index (ids + locators only, no
rows) plus the single live tail segment — reopen is O(segment), not
O(job), and no row body is ever resident unless explicitly read back
through its locator (``read_row`` / ``write_merged``).  Torn-line
truncation applies only to the newest (tail) segment and the index —
sealed segments were fsync'd before their seal record committed and are
never rewritten.  First-wins dedup spans segments: the earliest committed
locator for a ``custom_id`` is the row, across any crash/requeue race.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import (Any, Dict, IO, Iterable, Iterator, List, Optional,
                    Sequence, Tuple)

from repro.core.events import SeqFinishedEvent

LEDGER_VERSION = 1
SEGMENT_VERSION = 1


class LedgerError(RuntimeError):
    pass


class JobLedger:
    """Append-only jsonl write-ahead ledger for one batch job."""

    def __init__(self, path: str):
        self.path = path
        self._fh: Optional[IO[str]] = None
        self.submitted: List[str] = []      # custom_ids in submit order
        self.finished: Dict[str, Dict[str, Any]] = {}   # custom_id -> row
        self.meta: Dict[str, Any] = {}
        self.torn_records = 0

    # ------------------------------------------------------------------ io
    def open(self) -> "JobLedger":
        """Load any existing records (tolerating a torn trailing line from
        a mid-write SIGKILL, which is truncated away) and open the file
        for appending.  Returns self."""
        if os.path.exists(self.path):
            self._load()
        dirn = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(dirn, exist_ok=True)
        fresh = not os.path.exists(self.path)
        self._fh = open(self.path, "a")
        if fresh or not self.meta:
            self._append({"kind": "meta", "version": LEDGER_VERSION})
        return self

    def _load(self) -> None:
        with open(self.path, "rb") as f:
            data = f.read()
        # a torn trailing line (no final newline, or unparseable) never
        # committed: drop it AND truncate the file so the next append
        # starts on a clean line instead of corrupting two records
        keep = len(data)
        if data and not data.endswith(b"\n"):
            keep = data.rfind(b"\n") + 1
            self.torn_records += 1
        for line in data[:keep].splitlines():
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                self.torn_records += 1      # interior corruption: skip
                continue
            kind = rec.get("kind")
            if kind == "meta":
                self.meta = rec
                if rec.get("version", 1) > LEDGER_VERSION:
                    raise LedgerError(
                        f"ledger {self.path} written by a newer version "
                        f"({rec.get('version')} > {LEDGER_VERSION})")
            elif kind == "submit":
                self.submitted.append(rec["custom_id"])
            elif kind == "output":
                # first-wins: a duplicate append (crash between fsync and
                # scheduler advance) must not change the emitted row
                self.finished.setdefault(rec["custom_id"], rec["row"])
        if keep < len(data):
            with open(self.path, "ab") as f:
                f.truncate(keep)

    def _append(self, rec: Dict[str, Any]) -> None:
        assert self._fh is not None, "ledger not open"
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()
        os.fsync(self._fh.fileno())

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # ------------------------------------------------------------- protocol
    def record_submitted(self, custom_ids: Sequence[str]) -> None:
        """Write the job's request manifest (idempotent on resume: ids
        already in the ledger are not re-recorded)."""
        known = set(self.submitted)
        for cid in custom_ids:
            if cid not in known:
                self._append({"kind": "submit", "custom_id": cid})
                self.submitted.append(cid)

    def record_output(self, custom_id: str, row: Dict[str, Any]) -> bool:
        """Durably append one finished row BEFORE the caller treats the
        request as done.  Returns False (and writes nothing) if the id
        already has a committed row — exactly-once by first-wins."""
        if custom_id in self.finished:
            return False
        self._append({"kind": "output", "custom_id": custom_id, "row": row})
        self.finished[custom_id] = row
        return True

    def pending(self, custom_ids: Sequence[str]) -> List[str]:
        return [c for c in custom_ids if c not in self.finished]


# ---------------------------------------------------------------------------
# chunked segment rotation
# ---------------------------------------------------------------------------


def _read_clean_lines(path: str) -> Tuple[List[bytes], int]:
    """Read a ledger jsonl file tolerating a torn trailing line from a
    mid-write SIGKILL: the torn tail is truncated away (the record never
    committed) and the clean lines are returned.  Returns (lines,
    torn_count)."""
    with open(path, "rb") as f:
        data = f.read()
    torn = 0
    keep = len(data)
    if data and not data.endswith(b"\n"):
        keep = data.rfind(b"\n") + 1
        torn += 1
    if keep < len(data):
        with open(path, "ab") as f:
            f.truncate(keep)
    return data[:keep].splitlines(), torn


class SegmentedJobLedger:
    """Write-ahead output ledger with chunked segment rotation.

    Layout under ``root/``::

        index.jsonl       meta + one fsync'd "seal" record per sealed
                          segment: {"kind": "seal", "segment": k,
                          "records": n, "loc": [[custom_id, off, len], ..]}
        seg-00000000.jsonl  append-only output records (JobLedger format)
        seg-00000001.jsonl  ...

    ``open()`` loads the index and replays ONLY the live tail segment —
    ``replayed_segments`` reports how many segment files were actually
    parsed (the O(segment)-reopen acceptance bar).  Rows are not held in
    memory; ``finished`` maps ``custom_id -> (segment, offset, nbytes)``
    locators and ``read_row`` / ``write_merged`` fetch bodies on demand.

    ``fsync_every`` batches fsyncs (group commit): a crash can lose at
    most the last ``fsync_every`` *unsynced* rows, which simply re-run on
    resume — "finished" means durable, so correctness is unaffected.
    Seals and ``close()`` always fsync.
    """

    def __init__(self, root: str, *, rotate_records: int = 50_000,
                 rotate_bytes: int = 64 << 20, fsync_every: int = 64):
        assert rotate_records > 0 and rotate_bytes > 0
        self.root = root
        self.rotate_records = int(rotate_records)
        self.rotate_bytes = int(rotate_bytes)
        self.fsync_every = max(int(fsync_every), 1)
        self.finished: Dict[str, Tuple[int, int, int]] = {}   # cid -> loc
        # streaming partial progress: cid -> next expected token offset.
        # Advanced by ``record_partial``; carried through seals and tail
        # replay so a resumed run refuses re-emitted partial rows.
        self.partial_off: Dict[str, int] = {}
        self.meta: Dict[str, Any] = {}
        self.torn_records = 0
        self.replayed_segments = 0      # segment FILES parsed at open()
        self.sealed_segments = 0
        self.duplicates_refused = 0
        self.partial_duplicates_refused = 0
        self.partial_gaps = 0       # blocks journaled past the expected
        #                             offset (should be 0: a gap means a
        #                             producer skipped tokens)
        self._live_seg = 0
        self._seg_records = 0
        self._seg_bytes = 0
        self._seg_loc: List[List] = []      # [cid, off, nbytes] this segment
        self._unsynced = 0
        self._fh: Optional[IO[bytes]] = None
        self._idx_fh: Optional[IO[str]] = None
        self._readers: Dict[int, IO[bytes]] = {}

    # ------------------------------------------------------------------ paths
    def _seg_path(self, k: int) -> str:
        return os.path.join(self.root, f"seg-{k:08d}.jsonl")

    @property
    def _index_path(self) -> str:
        return os.path.join(self.root, "index.jsonl")

    @property
    def live_segment(self) -> int:
        return self._live_seg

    # ------------------------------------------------------------------ open
    def open(self) -> "SegmentedJobLedger":
        os.makedirs(self.root, exist_ok=True)
        fresh = not os.path.exists(self._index_path)
        if not fresh:
            self._load_index()
            self._replay_tail()
        self._idx_fh = open(self._index_path, "a")
        if fresh:
            self._append_index({"kind": "meta", "version": SEGMENT_VERSION,
                                "rotate_records": self.rotate_records,
                                "rotate_bytes": self.rotate_bytes})
        self._fh = open(self._seg_path(self._live_seg), "ab")
        return self

    def _load_index(self) -> None:
        """Sealed-segment state comes from the index alone: ids + locators,
        never row bodies.  A torn trailing seal (crash mid-seal) is
        truncated; its segment is then the live tail and replays fully."""
        lines, torn = _read_clean_lines(self._index_path)
        self.torn_records += torn
        for line in lines:
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                self.torn_records += 1
                continue
            kind = rec.get("kind")
            if kind == "meta":
                self.meta = rec
                if rec.get("version", 1) > SEGMENT_VERSION:
                    raise LedgerError(
                        f"segmented ledger {self.root} written by a newer "
                        f"version ({rec.get('version')} > {SEGMENT_VERSION})")
            elif kind == "seal":
                seg = int(rec["segment"])
                self.sealed_segments += 1
                self._live_seg = max(self._live_seg, seg + 1)
                for cid, off, n in rec["loc"]:
                    # first-wins across segments: the earliest committed
                    # locator is THE row for this custom_id
                    self.finished.setdefault(cid, (seg, int(off), int(n)))
                # seals snapshot the live partial-progress map so a resume
                # never re-reads sealed segment bodies to rebuild it;
                # later seals carry later snapshots and override
                for cid, off in rec.get("partial_off", {}).items():
                    self.partial_off[cid] = int(off)

    def _replay_tail(self) -> None:
        """Parse the one live (unsealed) tail segment — the only segment
        file a resume ever reads."""
        path = self._seg_path(self._live_seg)
        if not os.path.exists(path):
            return
        self.replayed_segments = 1
        lines, torn = _read_clean_lines(path)
        self.torn_records += torn
        off = 0
        for line in lines:
            nbytes = len(line) + 1          # + newline
            if line.strip():
                try:
                    rec = json.loads(line)
                except ValueError:
                    self.torn_records += 1
                    off += nbytes
                    continue
                if rec.get("kind") == "output":
                    cid = rec["custom_id"]
                    loc = (self._live_seg, off, nbytes)
                    if cid in self.finished:
                        self.duplicates_refused += 1
                    else:
                        self.finished[cid] = loc
                        self._seg_loc.append([cid, off, nbytes])
                    self.partial_off.pop(cid, None)
                elif rec.get("kind") == "partial":
                    cid = rec["custom_id"]
                    if cid not in self.finished:
                        self.partial_off[cid] = max(
                            self.partial_off.get(cid, 0),
                            int(rec["off"]) + len(rec["tokens"]))
                self._seg_records += 1
            off += nbytes
        self._seg_bytes = off

    # ------------------------------------------------------------------ write
    def record_output(self, custom_id: str, row: Dict[str, Any]) -> bool:
        """Durably append one finished row; False (nothing written) if the
        id already committed — exactly-once by first-wins, across
        segments and across a crashed run's requeue race.  The same
        first-wins gate dedupes hedged re-execution: when the scheduler
        races a straggler against a speculative clone, both finishers
        surface under one custom_id and only the first commits."""
        if custom_id in self.finished:
            self.duplicates_refused += 1
            return False
        assert self._fh is not None, "ledger not open"
        line = (json.dumps({"kind": "output", "custom_id": custom_id,
                            "row": row}) + "\n").encode()
        off = self._seg_bytes
        self._fh.write(line)
        self._fh.flush()
        self._unsynced += 1
        if self._unsynced >= self.fsync_every:
            os.fsync(self._fh.fileno())
            self._unsynced = 0
        self.finished[custom_id] = (self._live_seg, off, len(line))
        self._seg_loc.append([custom_id, off, len(line)])
        self.partial_off.pop(custom_id, None)   # full row supersedes
        self._seg_records += 1
        self._seg_bytes += len(line)
        if (self._seg_records >= self.rotate_records
                or self._seg_bytes >= self.rotate_bytes):
            self._rotate()
        return True

    def record_partial(self, custom_id: str, offset: int,
                       tokens: Sequence[int]) -> bool:
        """Journal a partial token block for a still-running request (the
        streaming driver flushes every ``TokenBlockEvent`` here, so a
        consumer tailing the segments sees tokens while the row is in
        flight).  Exactly-once per token offset: a block at an offset the
        ledger has already committed — a finished row, or a requeued
        recompute re-emitting its (bitwise-identical) prefix — is refused
        without writing.  Returns True iff the block was journaled."""
        if custom_id in self.finished:
            self.partial_duplicates_refused += 1
            return False
        expected = self.partial_off.get(custom_id, 0)
        if offset < expected:
            # a recompute (replica drain / crash resume) replays from
            # offset 0; determinism makes the refused prefix identical to
            # what is already durable, so dropping it loses nothing
            self.partial_duplicates_refused += 1
            return False
        if offset > expected:
            # journaled anyway (the tokens are real), but a skipped window
            # means some producer lost blocks — surface it in the report
            self.partial_gaps += 1
        assert self._fh is not None, "ledger not open"
        line = (json.dumps({"kind": "partial", "custom_id": custom_id,
                            "off": int(offset),
                            "tokens": [int(t) for t in tokens]})
                + "\n").encode()
        self._fh.write(line)
        self._fh.flush()
        self._unsynced += 1
        if self._unsynced >= self.fsync_every:
            os.fsync(self._fh.fileno())
            self._unsynced = 0
        self.partial_off[custom_id] = int(offset) + len(tokens)
        self._seg_records += 1
        self._seg_bytes += len(line)
        if (self._seg_records >= self.rotate_records
                or self._seg_bytes >= self.rotate_bytes):
            self._rotate()
        return True

    def _rotate(self) -> None:
        """Seal the live segment: fsync it, commit its locator line to the
        index, then start a fresh segment.  Crash windows are all safe —
        before the seal fsyncs, the old segment is simply the tail and
        replays; after, the (possibly not-yet-created) next segment is."""
        assert self._fh is not None
        os.fsync(self._fh.fileno())
        self._unsynced = 0
        self._fh.close()
        self._append_index({"kind": "seal", "segment": self._live_seg,
                            "records": self._seg_records,
                            "loc": self._seg_loc,
                            "partial_off": dict(self.partial_off)})
        self.sealed_segments += 1
        self._live_seg += 1
        self._seg_records = 0
        self._seg_bytes = 0
        self._seg_loc = []
        self._fh = open(self._seg_path(self._live_seg), "ab")

    def _append_index(self, rec: Dict[str, Any]) -> None:
        assert self._idx_fh is not None
        self._idx_fh.write(json.dumps(rec) + "\n")
        self._idx_fh.flush()
        os.fsync(self._idx_fh.fileno())

    def close(self) -> None:
        for fh in self._readers.values():
            fh.close()
        self._readers = {}
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self._unsynced = 0
            self._fh.close()
            self._fh = None
        if self._idx_fh is not None:
            self._idx_fh.close()
            self._idx_fh = None

    # ------------------------------------------------------------------ read
    def has(self, custom_id: str) -> bool:
        return custom_id in self.finished

    def __len__(self) -> int:
        return len(self.finished)

    def pending(self, custom_ids: Sequence[str]) -> List[str]:
        return [c for c in custom_ids if c not in self.finished]

    def _reader(self, seg: int) -> IO[bytes]:
        fh = self._readers.get(seg)
        if fh is None:
            fh = self._readers[seg] = open(self._seg_path(seg), "rb")
        return fh

    def read_record(self, custom_id: str) -> Optional[bytes]:
        """The raw committed ledger line for one finished id (locator
        pread — no segment scan)."""
        loc = self.finished.get(custom_id)
        if loc is None:
            return None
        seg, off, n = loc
        if seg == self._live_seg and self._fh is not None:
            self._fh.flush()
        fh = self._reader(seg)
        fh.seek(off)
        return fh.read(n)

    def read_row(self, custom_id: str) -> Optional[Dict[str, Any]]:
        raw = self.read_record(custom_id)
        if raw is None:
            return None
        return json.loads(raw)["row"]

    def write_merged(self, custom_ids: Iterable[str], out) -> int:
        """Stream the rows for ``custom_ids`` (typically the job's input
        order) to the text file object ``out`` as jsonl; ids without a
        committed row are skipped.  Returns rows written.  Deterministic
        given deterministic rows — the byte-identical-resume contract."""
        n = 0
        for cid in custom_ids:
            row = self.read_row(cid)
            if row is None:
                continue
            out.write(json.dumps(row) + "\n")
            n += 1
        return n

    def iter_finished(self) -> Iterator[str]:
        return iter(self.finished)


# ---------------------------------------------------------------------------
# resumable driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LedgerRunResult:
    rows: List[Dict[str, Any]]      # one per request, input order
    resumed: int                    # rows served from the ledger
    computed: int                   # rows decoded by this run
    report: Optional[Dict] = None   # scheduler report (None if no work)


def run_resumable(master, requests: Sequence, ledger_path: str,
                  max_ticks: int = 100000,
                  on_output=None) -> LedgerRunResult:
    """Run ``requests`` through ``master`` (a ``BatchMaster``) with
    write-ahead progress in ``ledger_path``.  On a fresh ledger this is a
    normal batch run that happens to journal every finish; after a crash,
    rerunning with the same arguments skips every journaled request (zero
    recompute of finished sequences) and decodes only the remainder.
    Returns all rows in input order — byte-identical to an uninterrupted
    run, because the runtime's decode is deterministic.

    ``on_output(custom_id, n_finished)`` fires after each row commits —
    chaos harnesses use it to SIGKILL the process at a deterministic
    point in the batch."""
    by_id: Dict[str, Any] = {}
    for r in requests:
        if r.custom_id in by_id:
            raise LedgerError(
                f"duplicate custom_id {r.custom_id!r}: the ledger keys "
                f"progress by custom_id, so ids must be unique per job")
        by_id[r.custom_id] = r
    led = JobLedger(ledger_path).open()
    try:
        led.record_submitted([r.custom_id for r in requests])
        todo = [by_id[cid] for cid in led.pending([r.custom_id
                                                   for r in requests])]
        resumed = len(requests) - len(todo)
        rep = None
        if todo:
            bid = master.submit(todo)
            for rec in master.stream(bid, max_ticks=max_ticks):
                if isinstance(rec, SeqFinishedEvent) \
                        and rec.custom_id is not None:
                    row = master.result_row(bid, rec.seq_id)
                    if row is not None and led.record_output(
                            rec.custom_id, row) and on_output is not None:
                        on_output(rec.custom_id, len(led.finished))
            bo = master.retrieve(bid)
            rep = {"status": bo.status,
                   "scheduler_status": getattr(bo, "scheduler_status", None),
                   "bct_s": getattr(bo, "bct_s", None)}
        rows = [led.finished[r.custom_id] for r in requests
                if r.custom_id in led.finished]
        return LedgerRunResult(rows=rows, resumed=resumed,
                               computed=len(led.finished) - resumed,
                               report=rep)
    finally:
        led.close()
