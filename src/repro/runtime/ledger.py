"""Crash-resumable job ledger: write-ahead output log for batch jobs (§5.6).

``runtime/checkpoint.py`` snapshots *engine* state (params, sequence pool,
host KV) — enough to warm-restart a process that shut down cleanly.  This
module covers the other half of preemption tolerance: a **job-level
write-ahead ledger** that survives a SIGKILL mid-batch.  It is the
"crash-resumable progress ledger" the ROADMAP's million-sequence streaming
driver calls for: at that scale a batch runs for days and WILL be
preempted; recomputing finished sequences on every restart makes the job
quadratic.

Design
------
One append-only jsonl file, fsync'd per record, three record kinds:

``{"kind": "meta", "version": 1, ...}``
    header written when the ledger is created.
``{"kind": "submit", "custom_id": ..., "n": ...}``
    the job's request manifest, written before any work starts (so a
    resume can detect a changed request set).
``{"kind": "output", "custom_id": ..., "row": {...}}``
    one finished request's full result row, appended the moment its
    ``SeqFinishedEvent`` lands — the write-ahead part: a request is
    "finished" iff its output record is durably in the ledger.

Crash semantics:

* A SIGKILL between records loses at most the in-flight request(s) — they
  re-run on resume.  Finished rows are never recomputed (the acceptance
  bar: zero recompute of finished sequences).
* A SIGKILL mid-write leaves a torn trailing line; ``JobLedger.open``
  truncates it (the record never committed — its request re-runs).
* **Exactly-once outputs**: ``record_output`` refuses duplicates
  (first-wins by ``custom_id``), so a crash after the write but before
  the scheduler advanced cannot double-emit a row, and a resumed run
  re-streaming a finished id is a no-op.

Determinism is what makes resume *correct*, not just convenient: greedy
decode and the token-addressable fold_in sampled stream are bitwise
reproducible across batch composition, so the rows a resumed run computes
for the unfinished remainder are identical to what the uninterrupted run
would have produced — the combined output file is byte-for-byte the same.

``run_resumable`` packages the protocol: load ledger → skip finished →
submit the remainder → append each finish as it lands → return all rows
in input order.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, IO, List, Optional, Sequence

from repro.core.events import SeqFinishedEvent

LEDGER_VERSION = 1


class LedgerError(RuntimeError):
    pass


class JobLedger:
    """Append-only jsonl write-ahead ledger for one batch job."""

    def __init__(self, path: str):
        self.path = path
        self._fh: Optional[IO[str]] = None
        self.submitted: List[str] = []      # custom_ids in submit order
        self.finished: Dict[str, Dict[str, Any]] = {}   # custom_id -> row
        self.meta: Dict[str, Any] = {}
        self.torn_records = 0

    # ------------------------------------------------------------------ io
    def open(self) -> "JobLedger":
        """Load any existing records (tolerating a torn trailing line from
        a mid-write SIGKILL, which is truncated away) and open the file
        for appending.  Returns self."""
        if os.path.exists(self.path):
            self._load()
        dirn = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(dirn, exist_ok=True)
        fresh = not os.path.exists(self.path)
        self._fh = open(self.path, "a")
        if fresh or not self.meta:
            self._append({"kind": "meta", "version": LEDGER_VERSION})
        return self

    def _load(self) -> None:
        with open(self.path, "rb") as f:
            data = f.read()
        # a torn trailing line (no final newline, or unparseable) never
        # committed: drop it AND truncate the file so the next append
        # starts on a clean line instead of corrupting two records
        keep = len(data)
        if data and not data.endswith(b"\n"):
            keep = data.rfind(b"\n") + 1
            self.torn_records += 1
        for line in data[:keep].splitlines():
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                self.torn_records += 1      # interior corruption: skip
                continue
            kind = rec.get("kind")
            if kind == "meta":
                self.meta = rec
                if rec.get("version", 1) > LEDGER_VERSION:
                    raise LedgerError(
                        f"ledger {self.path} written by a newer version "
                        f"({rec.get('version')} > {LEDGER_VERSION})")
            elif kind == "submit":
                self.submitted.append(rec["custom_id"])
            elif kind == "output":
                # first-wins: a duplicate append (crash between fsync and
                # scheduler advance) must not change the emitted row
                self.finished.setdefault(rec["custom_id"], rec["row"])
        if keep < len(data):
            with open(self.path, "ab") as f:
                f.truncate(keep)

    def _append(self, rec: Dict[str, Any]) -> None:
        assert self._fh is not None, "ledger not open"
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()
        os.fsync(self._fh.fileno())

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # ------------------------------------------------------------- protocol
    def record_submitted(self, custom_ids: Sequence[str]) -> None:
        """Write the job's request manifest (idempotent on resume: ids
        already in the ledger are not re-recorded)."""
        known = set(self.submitted)
        for cid in custom_ids:
            if cid not in known:
                self._append({"kind": "submit", "custom_id": cid})
                self.submitted.append(cid)

    def record_output(self, custom_id: str, row: Dict[str, Any]) -> bool:
        """Durably append one finished row BEFORE the caller treats the
        request as done.  Returns False (and writes nothing) if the id
        already has a committed row — exactly-once by first-wins."""
        if custom_id in self.finished:
            return False
        self._append({"kind": "output", "custom_id": custom_id, "row": row})
        self.finished[custom_id] = row
        return True

    def pending(self, custom_ids: Sequence[str]) -> List[str]:
        return [c for c in custom_ids if c not in self.finished]


# ---------------------------------------------------------------------------
# resumable driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LedgerRunResult:
    rows: List[Dict[str, Any]]      # one per request, input order
    resumed: int                    # rows served from the ledger
    computed: int                   # rows decoded by this run
    report: Optional[Dict] = None   # scheduler report (None if no work)


def run_resumable(master, requests: Sequence, ledger_path: str,
                  max_ticks: int = 100000,
                  on_output=None) -> LedgerRunResult:
    """Run ``requests`` through ``master`` (a ``BatchMaster``) with
    write-ahead progress in ``ledger_path``.  On a fresh ledger this is a
    normal batch run that happens to journal every finish; after a crash,
    rerunning with the same arguments skips every journaled request (zero
    recompute of finished sequences) and decodes only the remainder.
    Returns all rows in input order — byte-identical to an uninterrupted
    run, because the runtime's decode is deterministic.

    ``on_output(custom_id, n_finished)`` fires after each row commits —
    chaos harnesses use it to SIGKILL the process at a deterministic
    point in the batch."""
    by_id: Dict[str, Any] = {}
    for r in requests:
        if r.custom_id in by_id:
            raise LedgerError(
                f"duplicate custom_id {r.custom_id!r}: the ledger keys "
                f"progress by custom_id, so ids must be unique per job")
        by_id[r.custom_id] = r
    led = JobLedger(ledger_path).open()
    try:
        led.record_submitted([r.custom_id for r in requests])
        todo = [by_id[cid] for cid in led.pending([r.custom_id
                                                   for r in requests])]
        resumed = len(requests) - len(todo)
        rep = None
        if todo:
            bid = master.submit(todo)
            for rec in master.stream(bid, max_ticks=max_ticks):
                if isinstance(rec, SeqFinishedEvent) \
                        and rec.custom_id is not None:
                    row = master.result_row(bid, rec.seq_id)
                    if row is not None and led.record_output(
                            rec.custom_id, row) and on_output is not None:
                        on_output(rec.custom_id, len(led.finished))
            bo = master.retrieve(bid)
            rep = {"status": bo.status,
                   "scheduler_status": getattr(bo, "scheduler_status", None),
                   "bct_s": getattr(bo, "bct_s", None)}
        rows = [led.finished[r.custom_id] for r in requests
                if r.custom_id in led.finished]
        return LedgerRunResult(rows=rows, resumed=resumed,
                               computed=len(led.finished) - resumed,
                               report=rep)
    finally:
        led.close()
