"""Deterministic fault injection + transfer retry/backoff (§5.6 substrate).

Chaos engineering for the batch runtime: a ``FaultPlan`` is a *seeded,
replayable* schedule of faults keyed to scheduler rounds (ticks), never to
wall time — the exact same chaos run can be replayed from its seed, which
is what makes "bitwise-identical tokens under injected failures" a testable
property instead of a hope.  The scheduler threads per-node ``NodeFaults``
views onto each engine (``engine.faults``, part of the formal
``ExecutionBackend`` contract) and advances them at the start of every
round; engines consult the view at their event boundaries:

=====================  ====================================================
fault kind             honored at
=====================  ====================================================
``node_death``         ``heartbeat()`` turns unhealthy; ``decode_page`` /
                       ``prefill`` no-op; ``acquire_slot`` refuses — the
                       node is a zombie until the health monitor declares
                       it dead and NODE_FAILURE recovers its sequences
``stale_heartbeat``    ``heartbeat()`` returns None for ``duration`` ticks
                       (a network blip; >= ``dead_after`` consecutive
                       ticks triggers a spurious-but-safe failover)
``transfer_fail``      the next ``count`` guarded transfers of the matching
                       kind raise ``TransferError`` (retried with backoff)
``transfer_timeout``   same, raising ``TransferTimeout``
``straggler``          engine runs ``factor`` x slower for ``duration``
                       ticks.  SimEngine inflates its virtual clock; the
                       real engine cannot actually slow down, so it scales
                       its heartbeat ``tokens_out`` credit down by the
                       factor instead — either way the node's progress
                       rate drops by ``factor`` and the scheduler's
                       ``ProgressTracker`` sees the straggler.  Heartbeats
                       still ARRIVE: a straggler is slow, never dead, and
                       must raise NODE_SLOW, not NODE_FAILURE
``oom``                allocator exhaustion for ``duration`` ticks:
                       ``acquire_slot`` refuses admissions AND the
                       page-boundary extension alloc fails mid-flight —
                       the scheduler's governor preempts the affected
                       sequences (checkpoint → host → free pages) and
                       re-admits them through COMBINE when the fault
                       clears, with bitwise-identical tokens
=====================  ====================================================

Transfer retry envelope
-----------------------
``guarded_transfer`` is the single retry/timeout/dead-letter funnel every
engine routes its risky host transfers through (``ExecutionBackend.
transfer``): stage/drain d2h KV copies, ``install_slot`` scatters, and
``prim.migrate`` blob moves.  A failed attempt retries with bounded
exponential backoff (``RetryPolicy``); after ``max_attempts`` the transfer
is *dead-lettered* — the engine's ``dead_lettered`` flag is raised and
``TransferDeadLetter`` propagates so the caller can drop the lost blob,
and the scheduler escalates the node to NODE_FAILURE (§5.6 recovery)
immediately after the dispatching handler returns.  Every retry, timeout
and dead-letter is counted in ``engine.transfer_stats``.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, List, Optional, Sequence

FAULT_KINDS = ("node_death", "stale_heartbeat", "transfer_fail",
               "transfer_timeout", "straggler", "oom")
TRANSFER_KINDS = ("stage", "drain", "install", "migrate", "restore", "any")


class TransferError(RuntimeError):
    """A guarded host transfer failed (injected or real)."""


class TransferTimeout(TransferError):
    """A guarded host transfer exceeded its timeout budget."""


class TransferDeadLetter(TransferError):
    """A transfer exhausted its retry budget; the owning node must be
    escalated to NODE_FAILURE (the scheduler does this on seeing the
    engine's ``dead_lettered`` flag)."""

    def __init__(self, node: int, kind: str, attempts: int):
        super().__init__(
            f"transfer '{kind}' on node {node} dead-lettered after "
            f"{attempts} attempts")
        self.node = node
        self.kind = kind
        self.attempts = attempts


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.  ``at_tick`` is the scheduler round it arms."""
    kind: str
    node: int
    at_tick: int
    count: int = 1            # consecutive transfer faults to inject
    duration: int = 1         # ticks a windowed fault stays open
    factor: float = 4.0       # straggler slowdown multiplier
    transfer_kind: str = "any"   # stage | drain | install | migrate | any

    def __post_init__(self):
        assert self.kind in FAULT_KINDS, self.kind
        assert self.transfer_kind in TRANSFER_KINDS, self.transfer_kind


class NodeFaults:
    """Live per-node fault state, advanced by the scheduler each round.

    Deterministic: faults arm strictly by tick, transfer faults are
    consumed in schedule order, and nothing reads a clock."""

    def __init__(self, faults: Sequence[Fault] = ()):
        self._pending: List[Fault] = sorted(faults, key=lambda f: f.at_tick)
        self.armed: List[Fault] = []
        self.tick = -1
        self.dead = False
        self._stale_until = -1
        self._strag_until = -1
        self._strag_factor = 1.0
        self._oom_until = -1
        # [kind, transfer_kind, remaining] entries, consumed FIFO
        self._transfer: List[List] = []

    def advance(self, tick: int) -> None:
        """Arm every fault scheduled at or before ``tick`` (event boundary
        hook — the scheduler calls this once per round per node)."""
        self.tick = tick
        while self._pending and self._pending[0].at_tick <= tick:
            f = self._pending.pop(0)
            self.armed.append(f)
            until = tick + max(f.duration, 1)
            if f.kind == "node_death":
                self.dead = True
            elif f.kind == "stale_heartbeat":
                self._stale_until = max(self._stale_until, until)
            elif f.kind == "straggler":
                self._strag_until = max(self._strag_until, until)
                self._strag_factor = f.factor
            elif f.kind == "oom":
                self._oom_until = max(self._oom_until, until)
            else:   # transfer_fail / transfer_timeout
                self._transfer.append([f.kind, f.transfer_kind, f.count])

    # ---- queries engines consult at their event boundaries ---------------
    def heartbeat_suppressed(self) -> bool:
        return self.tick < self._stale_until

    def straggler_factor(self) -> float:
        return self._strag_factor if self.tick < self._strag_until else 1.0

    def oom_active(self) -> bool:
        return self.tick < self._oom_until

    def take_transfer_fault(self, kind: str) -> Optional[TransferError]:
        """Consume one armed transfer fault matching ``kind`` (or None).
        Called once per transfer *attempt*, so ``count`` is the number of
        consecutive failing attempts the fault injects."""
        for ent in self._transfer:
            fk, tk, rem = ent
            if rem > 0 and tk in ("any", kind):
                ent[2] -= 1
                if fk == "transfer_timeout":
                    return TransferTimeout(
                        f"injected timeout on '{kind}' transfer")
                return TransferError(f"injected failure on '{kind}' transfer")
        self._transfer = [e for e in self._transfer if e[2] > 0]
        return None


class FaultPlan:
    """A replayable schedule of faults.  Build explicitly from ``Fault``
    entries, or seed a random chaos matrix with ``FaultPlan.random`` —
    either way ``node_view`` hands each engine its deterministic slice."""

    def __init__(self, faults: Sequence[Fault] = (),
                 seed: Optional[int] = None):
        self.faults = sorted(faults, key=lambda f: (f.at_tick, f.node,
                                                    f.kind))
        self.seed = seed

    @classmethod
    def random(cls, seed: int, *, nodes: int, horizon: int = 24,
               n_faults: int = 4, kinds: Sequence[str] = FAULT_KINDS,
               max_deaths: Optional[int] = None) -> "FaultPlan":
        """Seeded chaos matrix: ``n_faults`` faults over ``horizon`` ticks.
        At most ``max_deaths`` (default: nodes - 1) distinct nodes die so a
        chaos run always keeps at least one survivor to recover onto."""
        rng = random.Random(seed)
        if max_deaths is None:
            max_deaths = max(nodes - 1, 0)
        killed: set = set()
        faults = []
        for _ in range(n_faults):
            kind = rng.choice(list(kinds))
            node = rng.randrange(nodes)
            if kind == "node_death":
                if node not in killed and len(killed) >= max_deaths:
                    kind = "straggler"      # keep a survivor
                else:
                    killed.add(node)
            faults.append(Fault(
                kind=kind, node=node, at_tick=rng.randrange(1, horizon),
                count=rng.randint(1, 3), duration=rng.randint(1, 4),
                factor=rng.choice([2.0, 4.0, 8.0]),
                transfer_kind=rng.choice(["any", "drain", "install"])))
        return cls(faults, seed=seed)

    @classmethod
    def straggler(cls, node: int, *, at_tick: int = 1, factor: float = 4.0,
                  duration: int = 10**9) -> "FaultPlan":
        """One persistently slow node (default: slow forever) — the
        canonical straggler-mitigation scenario."""
        return cls([Fault(kind="straggler", node=node, at_tick=at_tick,
                          factor=factor, duration=duration)])

    def node_view(self, node: int) -> NodeFaults:
        return NodeFaults([f for f in self.faults if f.node == node])

    def describe(self) -> str:
        return "\n".join(
            f"t={f.at_tick:>3} node={f.node} {f.kind}"
            + (f" x{f.count} ({f.transfer_kind})"
               if f.kind.startswith("transfer") else "")
            + (f" for {f.duration} ticks" if f.kind in
               ("stale_heartbeat", "straggler", "oom") else "")
            for f in self.faults)

    def __len__(self):
        return len(self.faults)


# ---------------------------------------------------------------------------
# retry / timeout / dead-letter envelope for guarded transfers
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded-exponential-backoff retry envelope for host transfers.

    ``timeout_s`` bounds one attempt: an *injected* ``transfer_timeout``
    fault exercises the retry path, while a real attempt that completes
    but overruns the budget is counted in ``transfer_stats['timeouts']``
    (its result is still valid — a synchronous copy cannot be abandoned
    mid-flight without threads, so slow-but-complete is accounting, not
    data loss)."""
    max_attempts: int = 4
    base_backoff_s: float = 2e-3
    max_backoff_s: float = 0.05
    timeout_s: float = 30.0

    def backoff(self, attempt: int) -> float:
        return min(self.base_backoff_s * (2 ** attempt), self.max_backoff_s)


def guarded_transfer(engine, kind: str, fn: Callable,
                     on_backoff: Optional[Callable[[float], None]] = None):
    """Run one host transfer under ``engine``'s fault injector + retry
    policy.  Returns ``fn()``'s result; raises ``TransferDeadLetter`` (and
    raises the engine's ``dead_lettered`` flag, which the scheduler
    escalates to NODE_FAILURE) after ``max_attempts`` failed attempts.

    Engine contract: ``retry_policy`` (RetryPolicy), ``transfer_stats``
    (dict with retries/timeouts/dead_letters), optional ``faults``
    (NodeFaults).  ``on_backoff`` defaults to ``time.sleep`` — virtual-
    clock engines pass their own (advance vclock instead of sleeping)."""
    pol = engine.retry_policy
    faults = getattr(engine, "faults", None)
    stats = engine.transfer_stats
    wait = on_backoff or time.sleep
    last: Optional[BaseException] = None
    for attempt in range(pol.max_attempts):
        exc = faults.take_transfer_fault(kind) if faults is not None else None
        if exc is None:
            t0 = time.perf_counter()
            try:
                out = fn()
            except TransferError as e:      # a real transfer failure
                exc = e
            else:
                if time.perf_counter() - t0 > pol.timeout_s:
                    stats["timeouts"] += 1      # slow-but-complete
                return out
        if isinstance(exc, TransferTimeout):
            stats["timeouts"] += 1
        last = exc
        stats["retries"] += 1
        if attempt + 1 < pol.max_attempts:
            wait(pol.backoff(attempt))
    stats["dead_letters"] += 1
    engine.dead_lettered = True
    raise TransferDeadLetter(engine.node_id, kind, pol.max_attempts) from last
