"""Health monitoring + failure recovery policy (paper §5.6).

Per-node heartbeats carry every device's status; a node missing
``dead_after`` consecutive heartbeats is declared failed and its sequences
are recovered by the migrate-vs-recompute cost model (``recovery_choice``,
wired into the scheduler's NODE_FAILURE handler as a policy hook).

The monitor supports two detection modes, used together or alone:

* **missed-beat counting** (always on): the scheduler collects heartbeats
  once per round via ``ExecutionBackend.heartbeat``; an engine that fails
  to produce one accrues a miss, and ``dead_after`` *consecutive* misses
  declare the node dead.  This is clock-free, so it works across
  SimEngine's per-node virtual clocks (which are NOT comparable to each
  other) exactly as well as on real nodes.
* **wall-clock staleness** (``interval_s`` not None): a healthy report
  also arms a timestamp; any node whose last-ok timestamp lags the
  reporting clock by more than ``dead_after * interval_s`` is declared
  dead.  ``last_ok`` is seeded lazily at the *first observation* of each
  node — seeding to 0.0 would declare every other node dead on the first
  real wall-clock report (time.time() >> 0).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from repro.core import plan as plan_lib
from repro.models.api import ModelConfig


@dataclasses.dataclass
class DeviceStatus:
    device_id: int
    healthy: bool = True
    hbm_used: float = 0.0
    temperature_c: float = 55.0


@dataclasses.dataclass
class Heartbeat:
    """One node's per-round liveness + progress beat.

    ``decode_steps`` / ``tokens`` are CUMULATIVE counters (decode steps
    run, tokens emitted since the engine was built) — the
    ``ProgressTracker`` differences consecutive beats against the
    node-local clock ``t`` to get a throughput, so the beat itself stays
    stateless and a lost beat only widens one delta window."""
    node: int
    t: float
    devices: List[DeviceStatus]
    decode_steps: int = 0           # cumulative decode steps completed
    tokens: float = 0.0             # cumulative effective tokens emitted

    @property
    def healthy(self) -> bool:
        return all(d.healthy for d in self.devices)


class HealthMonitor:
    """Declares nodes dead from missed/unhealthy heartbeats.

    ``interval_s=None`` disables the wall-clock staleness check and
    leaves only consecutive-miss counting (the scheduler's default: its
    rounds are the clock)."""

    def __init__(self, nodes: int, *, interval_s: Optional[float] = 5.0,
                 dead_after: int = 3):
        self.interval = interval_s
        self.dead_after = dead_after
        # None = never observed; seeded at first report so a live wall
        # clock can't compare against an epoch-zero default.
        self.last_ok: Dict[int, Optional[float]] = {
            n: None for n in range(nodes)}
        self.missed: Dict[int, int] = {n: 0 for n in range(nodes)}
        self.failed: Dict[int, bool] = {n: False for n in range(nodes)}
        self.on_failure: Optional[Callable[[int], None]] = None

    def ensure_node(self, node: int) -> None:
        """Start tracking a node added after construction (elastic
        scale-up)."""
        if node not in self.failed:
            self.last_ok[node] = None
            self.missed[node] = 0
            self.failed[node] = False

    def report(self, hb: Heartbeat):
        """One heartbeat arrived.  Healthy beats clear the miss counter;
        unhealthy beats (a sick device) count as misses."""
        self.ensure_node(hb.node)
        if self.failed[hb.node]:
            return
        if hb.healthy:
            if self.last_ok[hb.node] is None:
                # first observation: also seed every never-seen peer so
                # relative staleness is measured from a common origin,
                # not from 0.0
                for n, t0 in self.last_ok.items():
                    if t0 is None:
                        self.last_ok[n] = hb.t
            self.last_ok[hb.node] = hb.t
            self.missed[hb.node] = 0
        else:
            self._miss(hb.node)
        self._check(hb.t)

    def miss(self, node: int, now: Optional[float] = None) -> None:
        """No heartbeat arrived for ``node`` this round (the scheduler's
        per-round collection calls this when an engine returns None)."""
        self.ensure_node(node)
        if self.failed[node]:
            return
        self._miss(node)
        if now is not None:
            self._check(now)

    def _miss(self, node: int) -> None:
        self.missed[node] += 1
        if self.missed[node] >= self.dead_after:
            self._declare_failed(node)

    def _check(self, now: float):
        if self.interval is None:
            return
        for n, t_ok in self.last_ok.items():
            if self.failed[n] or t_ok is None:
                continue
            if now - t_ok > self.dead_after * self.interval:
                self._declare_failed(n)

    def _declare_failed(self, node: int) -> None:
        if self.failed.get(node):
            return
        self.failed[node] = True
        if self.on_failure is not None:
            self.on_failure(node)

    def mark_failed(self, node: int) -> None:
        """Administrative failure (dead-letter escalation, operator
        action): mark dead WITHOUT firing on_failure — the caller owns
        the NODE_FAILURE event."""
        self.ensure_node(node)
        self.failed[node] = True

    def alive(self) -> List[int]:
        return [n for n, f in self.failed.items() if not f]


class ProgressTracker:
    """Per-node EWMA throughput from heartbeat progress deltas — the
    detection half of straggler mitigation (the paper's "mitigate
    stragglers / reallocate work across devices" claim, §4).

    Each round the scheduler feeds it every heartbeat (``observe``) and
    then asks for verdicts (``evaluate``).  A node's rate is
    ``Δtokens / Δt`` on its OWN clock — rates are comparable across nodes
    of one engine family even though absolute clocks are not (SimEngine
    vclocks share the §5.4 performance model; NodeEngine deltas share the
    wall).  A node whose EWMA stays below ``slow_fraction`` x the fleet
    median for ``slow_rounds`` consecutive evaluations is flagged slow
    exactly once; hysteresis (``recover_fraction`` > ``slow_fraction``)
    unflags a recovered node, and a post-shed cooldown keeps a
    just-shedded node from being re-flagged while its EWMA is still
    polluted by the slow window.  Idle rounds (no new tokens) neither
    build nor reset a slow streak — idle is not slow."""

    def __init__(self, *, slow_fraction: float = 0.5, slow_rounds: int = 3,
                 cooldown: int = 10, recover_fraction: float = 0.8,
                 ewma_alpha: float = 0.5):
        self.slow_fraction = slow_fraction
        self.slow_rounds = slow_rounds
        self.cooldown = cooldown
        self.recover_fraction = recover_fraction
        self.alpha = ewma_alpha
        self.ewma: Dict[int, float] = {}
        self.flagged: Dict[int, bool] = {}
        self.flags_raised = 0
        self.flags_cleared = 0
        self._last: Dict[int, tuple] = {}       # node -> (t, tokens)
        self._streak: Dict[int, int] = {}
        self._cool_until: Dict[int, int] = {}
        self._fresh: Dict[int, float] = {}      # this round's rates

    def observe(self, hb: Heartbeat) -> None:
        """Feed one heartbeat (once per node per round)."""
        prev = self._last.get(hb.node)
        self._last[hb.node] = (hb.t, hb.tokens)
        if prev is None:
            return
        dt = hb.t - prev[0]
        dtok = hb.tokens - prev[1]
        if dtok <= 0 or dt <= 0:
            return                  # idle (or clock glitch): no evidence
        rate = dtok / dt
        old = self.ewma.get(hb.node)
        self.ewma[hb.node] = rate if old is None else (
            self.alpha * rate + (1.0 - self.alpha) * old)
        self._fresh[hb.node] = self.ewma[hb.node]

    def median_rate(self) -> Optional[float]:
        rates = sorted(self.ewma.values())
        if len(rates) < 2:
            return None             # a fleet of one has no peers to lag
        n = len(rates)
        mid = n // 2
        return rates[mid] if n % 2 else 0.5 * (rates[mid - 1] + rates[mid])

    def evaluate(self, round_no: int, nodes) -> List[int]:
        """End-of-collection verdicts; returns nodes NEWLY flagged slow.
        ``nodes`` is the live rotation — departed nodes are forgotten so
        a dead straggler can't skew the median forever."""
        alive = set(nodes)
        for d in (self.ewma, self._last, self._streak, self.flagged,
                  self._cool_until):
            for n in [k for k in d if k not in alive]:
                del d[n]
        med = self.median_rate()
        fresh, self._fresh = self._fresh, {}
        if med is None or med <= 0:
            return []
        newly: List[int] = []
        for node, rate in fresh.items():
            if self.flagged.get(node):
                if rate >= self.recover_fraction * med:
                    self.flagged[node] = False
                    self.flags_cleared += 1
                    self._streak[node] = 0
                continue
            if round_no < self._cool_until.get(node, -1):
                continue
            if rate < self.slow_fraction * med:
                self._streak[node] = self._streak.get(node, 0) + 1
                if self._streak[node] >= self.slow_rounds:
                    self.flagged[node] = True
                    self.flags_raised += 1
                    self._streak[node] = 0
                    newly.append(node)
            else:
                self._streak[node] = 0
        return newly

    def is_flagged(self, node: int) -> bool:
        return bool(self.flagged.get(node))

    def start_cooldown(self, node: int, round_no: int) -> None:
        """Arm the post-shed re-flag holdoff for ``node``."""
        self._cool_until[node] = round_no + self.cooldown

    def deficit(self, node: int) -> float:
        """How far below the fleet median this node runs, in [0, 1] —
        the shed fraction is proportional to it."""
        med = self.median_rate()
        rate = self.ewma.get(node)
        if med is None or med <= 0 or rate is None:
            return 0.0
        return min(max(1.0 - rate / med, 0.0), 1.0)

    def rate(self, node: int) -> float:
        return self.ewma.get(node, 0.0)


def recovery_choice(cfg: ModelConfig, hw: plan_lib.Hardware, *,
                    kv_len: int, prompt_len: int,
                    inter_node_bw: float = 25e9) -> str:
    """migrate vs recompute: transfer time of the KV snapshot vs re-prefill
    time (paper: 'migrating hundreds of gigabytes may be slower than
    regenerating')."""
    from repro.runtime.cluster import kv_bytes_per_token

    t_migrate = kv_len * kv_bytes_per_token(cfg) / inter_node_bw
    plan = plan_lib.Plan(1, 1, False, False, 0, 0.0)
    t_recompute = plan_lib.step_time(cfg, hw, plan, 1, kv_len,
                                     max(kv_len, prompt_len))
    return "migrate" if t_migrate < t_recompute else "recompute"
