"""Health monitoring + failure recovery policy (paper §5.6).

Per-node heartbeats carry every device's status; a node missing
``dead_after`` consecutive heartbeats is declared failed and its sequences
are recovered by the migrate-vs-recompute cost model (the performance model
estimates both and picks the faster path — implemented in
runtime/cluster.py::Cluster.fail_node).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from repro.core import plan as plan_lib
from repro.models.api import ModelConfig


@dataclasses.dataclass
class DeviceStatus:
    device_id: int
    healthy: bool = True
    hbm_used: float = 0.0
    temperature_c: float = 55.0


@dataclasses.dataclass
class Heartbeat:
    node: int
    t: float
    devices: List[DeviceStatus]

    @property
    def healthy(self) -> bool:
        return all(d.healthy for d in self.devices)


class HealthMonitor:
    def __init__(self, nodes: int, *, interval_s: float = 5.0,
                 dead_after: int = 3):
        self.interval = interval_s
        self.dead_after = dead_after
        self.last_ok: Dict[int, float] = {n: 0.0 for n in range(nodes)}
        self.failed: Dict[int, bool] = {n: False for n in range(nodes)}
        self.on_failure: Optional[Callable[[int], None]] = None

    def report(self, hb: Heartbeat):
        if hb.healthy:
            self.last_ok[hb.node] = hb.t
        self._check(hb.t)

    def _check(self, now: float):
        for n, t_ok in self.last_ok.items():
            if self.failed[n]:
                continue
            if now - t_ok > self.dead_after * self.interval:
                self.failed[n] = True
                if self.on_failure is not None:
                    self.on_failure(n)

    def alive(self) -> List[int]:
        return [n for n, f in self.failed.items() if not f]


def recovery_choice(cfg: ModelConfig, hw: plan_lib.Hardware, *,
                    kv_len: int, prompt_len: int,
                    inter_node_bw: float = 25e9) -> str:
    """migrate vs recompute: transfer time of the KV snapshot vs re-prefill
    time (paper: 'migrating hundreds of gigabytes may be slower than
    regenerating')."""
    from repro.runtime.cluster import kv_bytes_per_token

    t_migrate = kv_len * kv_bytes_per_token(cfg) / inter_node_bw
    plan = plan_lib.Plan(1, 1, False, False, 0, 0.0)
    t_recompute = plan_lib.step_time(cfg, hw, plan, 1, kv_len,
                                     max(kv_len, prompt_len))
    return "migrate" if t_migrate < t_recompute else "recompute"
