"""Checkpoint management: sharded save/restore + fast cold start (§5.6).

* ``save`` / ``restore`` — params (+ optimizer state + sequence-pool
  snapshot) as one flat ``.npz``-style directory of raw ``.bin`` files with
  a JSON manifest; every leaf is a separate file so a restore can be
  sharded (each host reads only its slice ranges).
* Fast cold start — files are written in the final in-memory layout and
  loaded with ``mmap_mode`` (the ServerlessLLM-style memory-mapped format
  the paper adopts); on multi-TB pools the paper pairs this with 2 MB huge
  pages, which is a host-configuration concern outside this process.
* Engine-level snapshot/restart — checkpoint/restart of an in-flight batch
  (sequence pool + host KV store) so a preempted spot instance resumes
  without recomputing finished work.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save(path: str, params, extra: Optional[Dict[str, Any]] = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(params)
    manifest = {}
    for name, leaf in flat.items():
        arr = np.asarray(leaf)
        fn = name.replace("/", ".") + ".bin"
        arr.tofile(os.path.join(path, fn))
        manifest[name] = {"file": fn, "dtype": str(arr.dtype),
                          "shape": list(arr.shape)}
    meta = {"manifest": manifest, "extra": extra or {},
            "saved_at": time.time()}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(meta, f)


def restore(path: str, *, mmap: bool = True,
            shard_filter=None) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Returns (flat param dict, extra).  With mmap=True leaves are
    memory-mapped — cold-start cost is page-in on first touch, not a full
    read (the ServerlessLLM loading model)."""
    with open(os.path.join(path, "manifest.json")) as f:
        meta = json.load(f)
    flat = {}
    for name, info in meta["manifest"].items():
        if shard_filter is not None and not shard_filter(name):
            continue
        fp = os.path.join(path, info["file"])
        dtype = np.dtype(info["dtype"])
        expect = dtype.itemsize * int(np.prod(info["shape"], dtype=np.int64))
        actual = os.path.getsize(fp)
        if actual != expect:
            raise ValueError(
                f"checkpoint leaf '{name}' is corrupt: {info['file']} is "
                f"{actual} bytes but manifest dtype={info['dtype']} "
                f"shape={tuple(info['shape'])} requires {expect} — the "
                f"checkpoint is truncated or was written by a different "
                f"config")
        if mmap:
            arr = np.memmap(fp, dtype=dtype, mode="r",
                            shape=tuple(info["shape"]))
        else:
            arr = np.fromfile(fp, dtype=dtype).reshape(info["shape"])
        flat[name] = arr
    return flat, meta["extra"]


def unflatten_into(tree, flat: Dict[str, np.ndarray], prefix=""):
    """Rebuild a pytree of jnp arrays matching `tree`'s structure."""
    import jax.numpy as jnp

    if isinstance(tree, dict):
        return {k: unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in tree.items()}
    name = prefix[:-1]
    return jnp.asarray(np.asarray(flat[name]))


# --------------------------------------------------------------------------
# in-flight batch snapshot (coroutine pool + host KV)
# --------------------------------------------------------------------------


def snapshot_pool(path: str, scheduler):
    os.makedirs(path, exist_ok=True)
    pool = []
    for co in scheduler.cos.values():
        pool.append({"seq_id": co.seq_id, "prompt": co.prompt,
                     "generated": co.generated, "max_out": co.max_out,
                     "status": co.status.value, "node": co.node,
                     "length": co.length, "last_token": co.last_token})
    with open(os.path.join(path, "pool.json"), "w") as f:
        json.dump(pool, f)


def restore_pool(path: str, scheduler):
    from repro.core.coroutine import SequenceCoroutine, Status

    with open(os.path.join(path, "pool.json")) as f:
        pool = json.load(f)
    for d in pool:
        co = SequenceCoroutine(seq_id=d["seq_id"], prompt=d["prompt"],
                               max_out=d["max_out"])
        co.generated = list(d["generated"])
        co.length = int(d["length"])
        co.last_token = int(d["last_token"])
        co.node = d["node"] % len(scheduler.engines)
        # active sequences lost their device state -> re-prefillable INIT,
        # inactive/done restore exactly
        st = Status(d["status"])
        co.status = Status.INIT if st == Status.ACTIVE else st
        if st == Status.INACTIVE and not any(
                scheduler.engines[e].host_store.has(co.seq_id)
                for e in range(len(scheduler.engines))):
            co.status = Status.INIT   # KV not persisted: recompute
        if co.status == Status.INIT:
            co.generated = []
            co.length = 0
        scheduler.cos[co.seq_id] = co
        scheduler._next_id = max(scheduler._next_id, co.seq_id + 1)
    return len(pool)
