"""Cluster-scale runtime: virtual-clock engines + failure/elasticity.

SimEngine implements the identical slot protocol as the real NodeEngine, so
the CoroutineScheduler code that decodes real tokens in the examples is the
same code that is measured here at 16-128 GPUs.  Compute time comes from
the §5.4 performance model (core/plan.py) — module-level rooflines composed
through the execution DAG — which is how the paper itself derives its
static plans.

Includes:
* long-tail workload generation matched to Fig. 2c statistics,
* node-failure injection with the §5.6 migrate-vs-recompute cost model,
* elastic scale-up/down (instances are independent; the master re-partitions
  the sequence pool),
* a baseline "static engine" scheduler (vLLM/SGLang-style fixed binding)
  for the paper's comparisons.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import plan as plan_lib
from repro.core.backend import validate_backend
from repro.core.coroutine import Phase, SequenceCoroutine, Status
from repro.core.events import EventKind, PrimitiveEvent
from repro.core.primitives import PrimitiveStats
from repro.core.scheduler import (CoroutineScheduler, SchedulerConfig,
                                  SchedulerPolicy)
from repro.memory.allocator import PageAllocator
from repro.memory.paged_kv import HostKVStore
from repro.models.api import ModelConfig
from repro.runtime.failure import DeviceStatus, Heartbeat
from repro.runtime.faults import (FaultPlan, NodeFaults, RetryPolicy,
                                  TransferDeadLetter, guarded_transfer)


def kv_bytes_per_token(cfg: ModelConfig) -> float:
    if cfg.use_mla:
        return 2.0 * (cfg.kv_lora_rank + cfg.rope_head_dim) * cfg.num_layers
    return 2.0 * 2 * cfg.num_kv_heads * cfg.head_dim * cfg.num_layers


class SimEngine:
    """Virtual-clock node engine (slot protocol compatible)."""

    def __init__(self, cfg: ModelConfig, hw: plan_lib.Hardware, *,
                 node_id: int = 0, num_devices: int = 8,
                 max_active: int = 64, max_len: int = 16384,
                 page_size: int = 64, plan: Optional[plan_lib.Plan] = None,
                 device_pages: Optional[int] = None,
                 partition_efficiency: float = 0.7,
                 reconfig_s: float = 7.0,
                 faults: Optional[NodeFaults] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 enable_prefix: bool = True):
        self.cfg = cfg
        self.hw = hw
        self.node_id = node_id
        self.num_devices = num_devices
        self.max_active = max_active
        self.max_len = max_len
        self.page_size = page_size
        self.partition_efficiency = partition_efficiency
        self.reconfig_s = reconfig_s
        self.plan = plan or plan_lib.search_plan(
            cfg, hw, ctx=max_len // 2, new_tokens=1, max_active=max_active)
        self.host_store = HostKVStore(page_size, enable_prefix=enable_prefix)
        # device_pages models the node's KV pool size: the governor's
        # oversubscription experiments shrink it well under the working
        # set; the default keeps the historical 4-pages-per-slot pool,
        # which is a soft modelling budget — only an explicit device_pages
        # is a real budget the governor may steer against
        self.allocator = PageAllocator(device_pages or max_active * 4,
                                       page_size,
                                       governed=device_pages is not None)
        self.kv_bytes_per_token = kv_bytes_per_token(cfg)
        self.stats = PrimitiveStats()
        self.vclock = 0.0
        self.busy_s = 0.0
        self.decode_steps = 0           # cumulative decode steps run
        self.tokens_out = 0.0           # cumulative tokens emitted — the
        #                                 heartbeat progress counter; raw
        #                                 counts, because an injected
        #                                 straggler inflates the vclock and
        #                                 the ProgressTracker's tokens/vclock
        #                                 rate drops by the same factor
        self.prefill_tokens = 0         # prompt tokens actually computed
        self.prefill_tokens_saved = 0   # served from fork dedupe / the index
        self.prefill_s = 0.0            # §5.4-model seconds spent in prefill
        self.failed = False
        self.slot_owner: List[Optional[int]] = [None] * max_active
        # pipelined host-KV staging (same two-stage protocol as the real
        # engine): entries are {"nbytes", "hidden"}; a decode between
        # stage and drain marks the blob hidden (its transfer overlapped
        # the compute).  plan.ring_buffer_bytes is the live gate.
        self._staged: List[Dict] = []
        self._staged_bytes = 0
        self.sync_stalls = 0
        # staged h2d restores (governor): seq_id -> {"nbytes", "length",
        # "hidden"} — the host→device mirror of the d2h pipeline above,
        # metered by its own h2d ring budget (a full-sequence restore
        # dwarfs a decode-page blob, and restore prefetch must never
        # starve the sync pipeline's staging room; two full sequences
        # deep, like the real engine's restore ring).  A decode between
        # stage and take marks the restore hidden (its transfer
        # overlapped compute).
        self._restore_staged: Dict[int, Dict] = {}
        self._restore_bytes = 0
        self._restore_cap = 2 * int(self.kv_bytes_per_token * max_len)
        self.restore_stages = 0
        self.restore_stalls = 0
        self.restore_wait_s = 0.0
        self.restore_stage_hidden_s = 0.0
        self.restore_staged_bytes = 0
        # §5.6 robustness: fault injection + guarded-transfer accounting
        # (identical surface to NodeEngine — same FaultPlan drives both)
        self.faults = faults
        self.retry_policy = retry_policy or RetryPolicy()
        self.transfer_stats = {"retries": 0, "timeouts": 0, "dead_letters": 0}
        self.dead_lettered = False
        self.oom_rejections = 0
        self.straggler_steps = 0
        self.abandoned_blobs = 0

    # ---------------------------------------------------------------- clock
    def clock(self) -> float:
        return self.vclock

    def idle_tick(self):
        self.vclock += 1e-3

    # ------------------------------------------------------------- protocol
    def heartbeat(self) -> Optional[Heartbeat]:
        """Liveness beat on the node's VIRTUAL clock.  The scheduler's
        monitor counts missed beats (interval_s=None) — per-node vclocks
        are never compared against each other."""
        if self.failed or (self.faults is not None and (
                self.faults.dead or self.faults.heartbeat_suppressed())):
            return None
        return Heartbeat(self.node_id, self.vclock,
                         [DeviceStatus(d) for d in range(self.num_devices)],
                         decode_steps=self.decode_steps,
                         tokens=self.tokens_out)

    def transfer(self, kind: str, fn):
        """Guarded transfer; retry backoff advances the virtual clock
        instead of sleeping."""
        return guarded_transfer(self, kind, fn, on_backoff=self._backoff)

    def _backoff(self, dt: float):
        self.vclock += dt

    def acquire_slot(self, co) -> Optional[int]:
        if self.faults is not None:
            if self.faults.dead:
                return None
            if self.faults.oom_active():
                self.oom_rejections += 1
                return None
        if not self.allocator.can_admit(2):
            return None         # page pool exhausted: admission waits
        for s, owner in enumerate(self.slot_owner):
            if owner is None:
                if self.allocator.alloc(co.seq_id, 2) is None:
                    return None
                self.slot_owner[s] = co.seq_id
                return s
        return None

    def free_slot(self, co):
        if co.slot is not None and co.slot < len(self.slot_owner) \
                and self.slot_owner[co.slot] == co.seq_id:
            self.slot_owner[co.slot] = None

    def extract_slot(self, co) -> Dict[str, np.ndarray]:
        return {}   # simulated: the host store tracks metadata only

    def install_slot(self, co, slices):
        # the simulated install is free, but it still passes through the
        # guarded-transfer envelope so injected install faults exercise
        # the same retry/dead-letter path as the real engine
        try:
            self.transfer("install", lambda: None)
        except TransferDeadLetter:
            pass        # scheduler escalates via the dead_lettered flag

    def reconfigure_partition(self, co, group):
        self.vclock += self.reconfig_s          # paper Table 2: 5-10 s

    # -------------------------------------------------------------- compute
    def decode_page(self, active: Sequence[SequenceCoroutine], P: int):
        if self.faults is not None and self.faults.dead:
            return              # zombie: no compute until failover
        for e in self._staged:          # this compute hides their transfer
            e["hidden"] = True
        for e in self._restore_staged.values():     # and the h2d prefetches
            e["hidden"] = True
        regular = [c for c in active if not c.partition_group]
        parts = [c for c in active if c.partition_group]
        steps = min(P, max(c.remaining for c in active))
        t_reg = 0.0
        if regular:
            ctx = float(np.mean([c.length for c in regular]))
            t_tok = plan_lib.step_time(self.cfg, self.hw, self.plan,
                                       len(regular), int(ctx), 1,
                                       ep_degree=min(self.num_devices, 8))
            t_reg = t_tok * steps
        t_part = 0.0
        for c in parts:
            g = max(len(c.partition_group), 1)
            t1 = plan_lib.step_time(self.cfg, self.hw, self.plan, 1,
                                    c.length, 1)
            t_part = max(t_part,
                         steps * t1 / max(g * self.partition_efficiency, 1.0))
        dt = max(t_reg, t_part)
        if self.faults is not None:
            f = self.faults.straggler_factor()
            if f > 1.0:
                self.straggler_steps += steps
                dt *= f         # same tokens, just slower — determinism
        self.vclock += dt
        self.busy_s += dt
        self.decode_steps += steps
        for c in active:
            n = min(steps, c.remaining)
            start = len(c.generated)
            toks, hit = c.sampling.truncate_at_stop(
                [self._sim_token(c, start + t) for t in range(n)])
            c.stopped = c.stopped or hit
            c.generated.extend(toks)
            c.length += len(toks)
            self.tokens_out += len(toks)
            self._sim_append_logprobs(c, start, toks)
        # host-store metadata so migrate/refill see real lengths
        for c in active:
            if not self.host_store.has(c.seq_id):
                self.host_store.checkpoint(c.seq_id, {}, c.length)
            else:
                self.host_store.seqs[c.seq_id].length = c.length

    @staticmethod
    def _sim_token(co: SequenceCoroutine, idx: int) -> int:
        """Virtual decode honors the sampling contract's *shape*: greedy
        sequences emit the constant 7; sampled ones emit a deterministic
        pseudo-stream of (effective seed, token index) — a pure function
        of per-sequence state, so migration/recovery replays identically."""
        sp = co.sampling
        if sp.temperature <= 0.0:
            return 7
        h = (sp.effective_seed(co.seq_id) * 2654435761 + idx * 40503) \
            & 0xFFFFFFFF
        return 7 + (h >> 16) % 89

    @staticmethod
    def _sim_logprob(co: SequenceCoroutine, idx: int) -> float:
        """Deterministic pseudo-logprob for the token at generated-index
        ``idx`` — like ``_sim_token``, a pure function of per-sequence
        state so streaming, replay and recovery all agree."""
        h = (co.sampling.effective_seed(co.seq_id) * 40503
             + idx * 2654435761) & 0xFFFFFFFF
        return -0.01 - (h >> 16) / 65536.0 * 8.0

    @classmethod
    def _sim_append_logprobs(cls, co: SequenceCoroutine, start: int,
                             toks) -> None:
        """Honor the logprobs surface in simulation: the virtual decode
        emits the same record shape as the real megastep's packed plane."""
        if not co.logprobs:
            return
        for t, tok in enumerate(toks):
            lp = cls._sim_logprob(co, start + t)
            co.token_logprobs.append(lp)
            if co.top_logprobs:
                co.top_token_logprobs.append(
                    [(int(tok) + j, lp - 0.5 * j)
                     for j in range(co.top_logprobs)])

    def sync_appends(self, active):
        # blocking sync: issue + land in one call (the page-boundary
        # barrier, 5-10 ms / 64 tokens cross-node sync, Table 2)
        self.stage_appends(active)
        self.drain_appends()

    def stage_appends(self, active):
        """Issue the page's KV transfer; cost is the dispatch only.  The
        §5.4 plan's ring_buffer_bytes gates in-flight bytes — a stage
        that would overflow it pays a synchronous drain first (the stall
        the configuration search sizes the buffer against), and a blob
        larger than the whole ring degrades to the blocking barrier with
        no overlap at all — the same fallback ladder as the real
        engine, so the simulator cannot report transfer hiding a given
        ring size would not actually deliver."""
        nbytes = int(len(active) * self.page_size
                     * kv_bytes_per_token(self.cfg))
        cap = max(int(self.plan.ring_buffer_bytes), 1)
        if self._staged_bytes + nbytes > cap:
            self.sync_stalls += 1
            self.drain_appends()
        if self._staged_bytes + nbytes <= cap:
            try:
                self.transfer("stage", lambda: None)
            except TransferDeadLetter:
                self.abandoned_blobs += 1   # sim KV is metadata-only:
                return                      # nothing to drop, just escalate
            self._staged.append({"nbytes": nbytes, "hidden": False})
            self._staged_bytes += nbytes
            self.vclock += 0.002
        else:
            # blob larger than the ring: synchronous stage + unhidden land.
            # Still a real d2h copy, so it rides the same guarded-drain
            # envelope the real engine's forced-synchronous path takes.
            try:
                self.transfer("drain", lambda: None)
            except TransferDeadLetter:
                self.abandoned_blobs += 1
                return
            self.vclock += 0.007    # synchronous: issue + unhidden land

    def drain_appends(self, keep_newest: int = 0):
        """Land staged blobs: a blob whose transfer overlapped a decode
        (hidden) pays only the residual barrier; a force-drained one pays
        the blocking remainder of the Table-2 sync cost."""
        while len(self._staged) > keep_newest:
            e = self._staged.pop(0)
            self._staged_bytes -= e["nbytes"]
            try:
                self.transfer("drain", lambda: None)
            except TransferDeadLetter:
                self.abandoned_blobs += 1
                continue
            self.vclock += 0.001 if e["hidden"] else 0.005

    # ------------------------------------- staged h2d restores (governor)
    _RESTORE_S = 0.004      # modeled h2d restore transfer (Table-2 scale)

    def stage_restore(self, co) -> bool:
        """Sim mirror of the real engine's restore prefetch: reserve the
        modeled restore bytes against the h2d restore-ring budget and
        issue the (virtual) host→device copy; the next decode marks it
        hidden."""
        ent = self._restore_staged.get(co.seq_id)
        if ent is not None:
            st = self.host_store.seqs.get(co.seq_id)
            if st is not None and st.length == ent["length"]:
                return True
            self.discard_restore(co.seq_id)     # stale: checkpoint advanced
        if not self.host_store.has(co.seq_id):
            return False
        length = self.host_store.seqs[co.seq_id].length
        nbytes = int(self.kv_bytes_per_token * length)
        if self._restore_bytes + nbytes > self._restore_cap:
            self.restore_stalls += 1
            return False
        try:
            self.transfer("restore", lambda: None)
        except TransferDeadLetter:
            return False
        self._restore_staged[co.seq_id] = {
            "nbytes": nbytes, "length": length, "hidden": False}
        self._restore_bytes += nbytes
        self.restore_stages += 1
        self.restore_staged_bytes += nbytes
        self.vclock += 0.001        # async issue: dispatch cost only
        return True

    def restore_ready(self, seq_id: int) -> bool:
        """True when the staged restore drained: a decode page ran since
        the (virtual) h2d copy was issued, so the transfer is hidden and
        COMBINE pays only the residual barrier."""
        ent = self._restore_staged.get(seq_id)
        st = self.host_store.seqs.get(seq_id)
        return (ent is not None and ent["hidden"]
                and st is not None and st.length == ent["length"])

    def take_restore(self, seq_id: int) -> Optional[Dict]:
        """Consume a staged restore at COMBINE: a hidden prefetch pays
        only the residual barrier (its transfer overlapped a decode); an
        unhidden or missing one pays the full modeled restore.  Returns
        ``{}`` (sim KV is metadata-only) or None without host state."""
        ent = self._restore_staged.pop(seq_id, None)
        st = self.host_store.seqs.get(seq_id)
        if ent is not None:
            self._restore_bytes -= ent["nbytes"]
            if st is not None and st.length == ent["length"]:
                self.restore_wait_s += self._RESTORE_S
                if ent["hidden"]:
                    self.restore_stage_hidden_s += self._RESTORE_S
                    self.vclock += 0.001
                else:
                    self.vclock += self._RESTORE_S
                return {}
        if st is None:
            return None
        self.restore_wait_s += self._RESTORE_S
        self.vclock += 0.001 + self._RESTORE_S      # synchronous restore
        return {}

    def discard_restore(self, seq_id: int) -> None:
        ent = self._restore_staged.pop(seq_id, None)
        if ent is not None:
            self._restore_bytes -= ent["nbytes"]

    def discard_restores(self) -> None:
        self._restore_staged.clear()
        self._restore_bytes = 0

    def prefill(self, cos: Sequence[SequenceCoroutine]):
        """Shared-prefix-aware prefill: identical prompts in the batch
        (fork groups or coincidental duplicates) run the virtual forward
        ONCE, and a prompt whose leading full pages match the node's
        PrefixIndex is charged only for its tail (§5.4 model) — at least
        one position is always recomputed so the last-token forward (and
        its logits, on the real engine) is genuine."""
        if self.faults is not None and self.faults.dead:
            return              # zombie: coroutines stay INIT for recovery
        if not cos:
            return
        P = self.page_size
        idx = self.host_store.prefix_index
        groups: Dict[tuple, List[SequenceCoroutine]] = {}
        for c in cos:
            # prefix reuse off => no fork dedupe either (naive baseline)
            key = tuple(c.prompt) if idx is not None else ("seq", c.seq_id)
            groups.setdefault(key, []).append(c)
        charged = 0
        max_tail = 0
        max_ctx = 0
        n_charged_groups = 0
        for group in groups.values():
            lead = group[0]
            chain = []
            if idx is not None and lead.prompt_len > 1:
                chain = idx.match(lead.prompt)
                chain = chain[: (lead.prompt_len - 1) // P]
            m = len(chain) * P
            tail = lead.prompt_len - m
            charged += tail
            n_charged_groups += 1
            max_tail = max(max_tail, tail)
            max_ctx = max(max_ctx, lead.prompt_len)
            if chain:
                st = self.host_store.attach_shared(lead.seq_id, chain)
                st.length = lead.prompt_len
                lead.prefix_hit_tokens = m
            else:
                self.host_store.checkpoint(lead.seq_id, {}, lead.prompt_len)
            if idx is not None:
                self.host_store.publish_prefix(lead.seq_id, lead.prompt)
            for sib in group[1:]:
                if idx is not None and \
                        self.host_store.seqs[lead.seq_id].prefix_node is not None:
                    st = self.host_store.clone_shared(lead.seq_id, sib.seq_id)
                    st.length = sib.prompt_len
                else:
                    self.host_store.checkpoint(sib.seq_id, {}, sib.prompt_len)
                sib.prefix_hit_tokens = sib.prompt_len
        if charged > 0:
            t = plan_lib.step_time(self.cfg, self.hw, self.plan,
                                   n_charged_groups, max_ctx, max_tail)
            self.vclock += t
            self.busy_s += t
            self.prefill_s += t
        self.prefill_tokens += charged
        self.prefill_tokens_saved += sum(c.prompt_len for c in cos) - charged
        for co in cos:
            co.length = co.prompt_len
            co.last_token = self._sim_token(co, 0)
            co.generated.append(co.last_token)
            self.tokens_out += 1
            self._sim_append_logprobs(co, 0, [co.last_token])
            if co.last_token in co.sampling.stop:
                co.stopped = True
            co.phase = Phase.DECODING
            co.status = Status.INACTIVE

    def utilization(self) -> float:
        return self.busy_s / max(self.vclock, 1e-9)


# SimEngine declares conformance to the same formal backend contract as
# the real NodeEngine — one scheduler code path drives both.
validate_backend(SimEngine)


# ---------------------------------------------------------------------------
# workloads (long-tail generation, Fig. 2c statistics)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Workload:
    prompts: List[List[int]]
    max_out: List[int]

    @property
    def n(self):
        return len(self.prompts)


def longtail_workload(n: int, *, mean_in: int = 2048, mean_out: int = 2048,
                      sigma: float = 1.0, seed: int = 0,
                      max_out_cap: int = 65536) -> Workload:
    """Lognormal output lengths; calibrated near Fig. 2c
    (P99/P95 ≈ 3.8x, max/P95 ≈ 9x at sigma≈1.0 for large n)."""
    rng = np.random.default_rng(seed)
    ins = np.maximum(rng.poisson(mean_in, n), 8)
    mu = math.log(mean_out) - sigma ** 2 / 2
    outs = np.minimum(np.maximum(
        rng.lognormal(mu, sigma, n).astype(int), 4), max_out_cap)
    prompts = [[1] * int(i) for i in ins]
    return Workload(prompts, [int(o) for o in outs])


def fixed_workload(n: int, in_len: int, out_len: int) -> Workload:
    return Workload([[1] * in_len for _ in range(n)], [out_len] * n)


# ---------------------------------------------------------------------------
# node groups (replica building block for the streaming driver)
# ---------------------------------------------------------------------------


def sim_node_group(cfg: ModelConfig, hw: plan_lib.Hardware, *,
                   nodes: int, first_node_id: int = 0,
                   devices_per_node: int = 8, max_active: int = 64,
                   max_len: int = 16384, page_size: int = 64,
                   plan: Optional[plan_lib.Plan] = None) -> List[SimEngine]:
    """A contiguous group of SimEngines sharing one static plan — the unit
    a data-parallel replica owns.  ``first_node_id`` keeps node ids unique
    across replicas so driver-level logs/reports never alias."""
    plan = plan or plan_lib.search_plan(cfg, hw, ctx=max_len // 2,
                                        new_tokens=1, max_active=max_active)
    return [SimEngine(cfg, hw, node_id=first_node_id + i,
                      num_devices=devices_per_node, max_active=max_active,
                      max_len=max_len, page_size=page_size, plan=plan)
            for i in range(nodes)]


# ---------------------------------------------------------------------------
# cluster with failures + elasticity
# ---------------------------------------------------------------------------


class Cluster:
    def __init__(self, cfg: ModelConfig, hw: plan_lib.Hardware, *,
                 nodes: int, devices_per_node: int = 8,
                 max_active: int = 64, max_len: int = 16384,
                 page_size: int = 64,
                 sched_cfg: Optional[SchedulerConfig] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 enable_prefix: bool = True,
                 device_pages: Optional[int] = None):
        self.cfg = cfg
        self.hw = hw
        plan = plan_lib.search_plan(cfg, hw, ctx=max_len // 2, new_tokens=1,
                                    max_active=max_active)
        self.engines = [SimEngine(cfg, hw, node_id=i,
                                  num_devices=devices_per_node,
                                  max_active=max_active, max_len=max_len,
                                  page_size=page_size, plan=plan,
                                  enable_prefix=enable_prefix,
                                  device_pages=device_pages)
                        for i in range(nodes)]
        self._inter_node_bw = 25e9
        # the §5.6 migrate-vs-recompute cost model rides the scheduler's
        # recovery_choice policy hook — ONE recovery code path (the
        # event-loop NODE_FAILURE handler) for sim and real engines
        policy = SchedulerPolicy(recovery_choice=self._recovery_choice)
        self.sched = CoroutineScheduler(
            self.engines, sched_cfg or SchedulerConfig(page_size=page_size),
            policy=policy, fault_plan=fault_plan)

    def run(self, wl: Workload, max_ticks: int = 200000, *,
            sampling=None, n: int = 1) -> Dict:
        """Run a workload to completion; ``n`` > 1 fans every prompt out
        into n forked siblings (see ``CoroutineScheduler.submit``)."""
        self.sched.submit(wl.prompts, wl.max_out, sampling=sampling, n=n)
        rep = self.sched.run(max_ticks=max_ticks)
        rep["utilization"] = float(np.mean(
            [e.utilization() for e in self.engines if not e.failed]))
        return rep

    # ---- §5.6 failure recovery ------------------------------------------
    def _recovery_choice(self, sched, co, failed, dst) -> str:
        """Migrate-vs-recompute cost model (the policy hook the scheduler
        consults per eligible sequence): KV transfer time over the
        inter-node link vs re-prefill time from the performance model.
        A chosen migrate also bills the transfer to the destination's
        virtual clock."""
        kv_bytes = co.length * kv_bytes_per_token(self.cfg)
        t_migrate = kv_bytes / self._inter_node_bw
        t_recompute = plan_lib.step_time(
            self.cfg, self.hw, dst.plan, 1, max(co.length, 1),
            max(co.length, 1))
        if t_migrate < t_recompute:
            dst.vclock += t_migrate
            return "migrate"
        return "recompute"

    def fail_node(self, node: int, *, inter_node_bw: float = 25e9) -> Dict:
        """Kill a node NOW: pushes NODE_FAILURE through the scheduler's
        event-loop handler — the same §5.6 recovery path a health-monitor
        declaration or a dead-lettered transfer takes — with this
        cluster's cost model deciding migrate-vs-recompute per sequence."""
        eng = self.engines[node]
        eng.failed = True
        self._inter_node_bw = inter_node_bw
        self.sched.health.mark_failed(node)
        self.sched.queue.push(EventKind.NODE_FAILURE, node,
                              payload="external")
        recs = list(self.sched._drain_queue())
        moved = sum(1 for r in recs if isinstance(r, PrimitiveEvent)
                    and r.primitive == "migrate" and r.detail == "failover")
        recomputed = sum(1 for r in recs if isinstance(r, PrimitiveEvent)
                         and r.primitive == "recompute"
                         and r.detail == "failover")
        return {"migrated": moved, "recomputed": recomputed}

    def drain_node(self, node: int) -> Dict:
        """Gracefully retire a node: pushes NODE_DRAIN through the
        scheduler's handler — every live sequence is checkpointed (fresh
        YIELD) and MIGRATEd to a survivor with zero recompute, then the
        node leaves the rotation.  Contrast ``fail_node``: that path may
        recompute; this one never should."""
        self.sched.queue.push(EventKind.NODE_DRAIN, node, payload="scale_down")
        recs = list(self.sched._drain_queue())
        moved = sum(1 for r in recs if isinstance(r, PrimitiveEvent)
                    and r.primitive == "migrate" and r.detail == "drain")
        return {"migrated": moved,
                "drained": node in self.sched.drained_nodes}

    # ---- elasticity -------------------------------------------------------
    def add_node(self) -> int:
        nid = len(self.engines)
        e = SimEngine(self.cfg, self.hw, node_id=nid,
                      num_devices=self.engines[0].num_devices,
                      max_active=self.engines[0].max_active,
                      max_len=self.engines[0].max_len,
                      page_size=self.engines[0].page_size,
                      plan=self.engines[0].plan)
        e.vclock = max(x.vclock for x in self.engines)
        self.engines.append(e)
        self.sched.engines = [x for x in self.engines if not x.failed]
        return nid


# ---------------------------------------------------------------------------
# static baseline (vLLM/SGLang-style fixed binding) for comparisons
# ---------------------------------------------------------------------------


def run_static_baseline(cfg: ModelConfig, hw: plan_lib.Hardware, wl: Workload,
                        *, nodes: int, max_active: int = 64,
                        max_len: int = 16384) -> Dict:
    """Sequences statically bound to nodes round-robin; no combine/migrate/
    partition; continuous batching within a node only; B_moe = whatever is
    active (no cross-phase accumulation)."""
    plan = plan_lib.Plan(b_attn=max_active, b_moe=max_active,
                         offload_kv=False, offload_params=False,
                         ring_buffer_bytes=0, layer_time_s=0.0)
    queues: List[List[Tuple[List[int], int]]] = [[] for _ in range(nodes)]
    for i, (p, o) in enumerate(zip(wl.prompts, wl.max_out)):
        queues[i % nodes].append((p, o))
    bct = 0.0
    busy = []
    for node_q in queues:
        t = 0.0
        work = 0.0
        pending = list(node_q)
        active: List[List] = []   # [remaining, length]
        while pending or active:
            while pending and len(active) < max_active:
                p, o = pending.pop(0)
                tp = plan_lib.step_time(cfg, hw, plan, 1, len(p), len(p))
                t += tp
                work += tp
                active.append([o, len(p)])
            ctx = float(np.mean([a[1] for a in active]))
            td = plan_lib.step_time(cfg, hw, plan, len(active), int(ctx), 1)
            t += td
            work += td * len(active) / max_active
            for a in active:
                a[0] -= 1
                a[1] += 1
            active = [a for a in active if a[0] > 0]
        bct = max(bct, t)
        busy.append(work / max(t, 1e-9))
    return {"bct_s": bct, "utilization": float(np.mean(busy))}
