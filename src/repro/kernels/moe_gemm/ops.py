"""Jitted grouped-GEMM MoE FFN: sort -> w1/w3 -> silu*u -> w2 -> unsort."""
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.moe_gemm.moe_gemm import (grouped_gemm_tpu,
                                             sort_tokens_by_expert)


@partial(jax.jit, static_argnames=("num_experts", "block_t", "interpret"))
def moe_ffn(xt, expert_ids, vals, w1, w3, w2, *, num_experts, block_t=128,
            interpret=False):
    """xt (T, D); expert_ids/vals (T, k); w1/w3 (E, D, F); w2 (E, F, D)."""
    T, D = xt.shape
    k = expert_ids.shape[1]
    flat_ids = expert_ids.reshape(-1)
    x_rep = jnp.repeat(xt, k, axis=0)
    xs, block_expert, slot_of, order, valid = sort_tokens_by_expert(
        x_rep, flat_ids, num_experts, block_t=block_t)
    g = grouped_gemm_tpu(xs, w1, block_expert, block_t=block_t,
                         interpret=interpret)
    u = grouped_gemm_tpu(xs, w3, block_expert, block_t=block_t,
                         interpret=interpret)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xs.dtype) * u
    y = grouped_gemm_tpu(h, w2, block_expert, block_t=block_t,
                         interpret=interpret)
    # unsort + weighted combine over k choices (slot_of maps original flat
    # choice order -> padded sorted slot)
    y_tok = y[slot_of]
    y_tok = y_tok * vals.reshape(-1)[:, None].astype(y_tok.dtype)
    return jnp.sum(y_tok.reshape(T, k, -1), axis=1)
