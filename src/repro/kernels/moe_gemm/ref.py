"""Oracle: per-expert dense loop."""
import jax.numpy as jnp


def ref_grouped_gemm(x, w, block_expert, block_t=128):
    T = x.shape[0]
    out = jnp.zeros((T, w.shape[-1]), x.dtype)
    for i in range(T // block_t):
        e = int(block_expert[i])
        sl = slice(i * block_t, (i + 1) * block_t)
        out = out.at[sl].set((x[sl].astype(jnp.float32)
                              @ w[e].astype(jnp.float32)).astype(x.dtype))
    return out


def ref_moe_ffn(xt, expert_ids, vals, w1, w3, w2):
    """Full routed-FFN oracle on unsorted tokens (top-k already chosen)."""
    import jax
    T, k = expert_ids.shape
    out = jnp.zeros((T, w2.shape[-1]), jnp.float32)
    for e in range(w1.shape[0]):
        g = jax.nn.silu(xt.astype(jnp.float32) @ w1[e].astype(jnp.float32))
        u = xt.astype(jnp.float32) @ w3[e].astype(jnp.float32)
        y = (g * u) @ w2[e].astype(jnp.float32)
        wmask = jnp.sum(jnp.where(expert_ids == e, vals, 0.0), axis=1)
        out = out + y * wmask[:, None]
    return out.astype(xt.dtype)
