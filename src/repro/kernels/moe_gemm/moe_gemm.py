"""Pallas TPU grouped expert GEMM (megablocks-style).

Local EP compute after dispatch: tokens sorted by expert, padded per expert
to token-block multiples.  A scalar-prefetched ``block_expert`` map assigns
each 128-token block to its expert, so the weight BlockSpec streams exactly
one expert's tile per block — a dense MXU matmul per (token-block, F-block)
with zero gather/scatter inside the kernel.

This is the compute core the paper's COMBINE primitive feeds: larger
combined batches -> more full token-blocks per expert -> higher MXU
occupancy (Fig. 2b).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _kernel(bexp_ref, x_ref, w_ref, o_ref):
    o_ref[...] = jax.lax.dot(
        x_ref[...], w_ref[0],
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def grouped_gemm_tpu(x, w, block_expert, *, block_t=128, block_f=128,
                     interpret=False):
    """x (T, D) tokens sorted/padded by expert; w (E, D, F);
    block_expert (T/block_t,) int32 expert id per token block.
    Returns (T, F)."""
    T, D = x.shape
    E, _, F = w.shape
    block_f = min(block_f, F)
    assert T % block_t == 0 and F % block_f == 0, (T, block_t, F, block_f)
    nT, nF = T // block_t, F // block_f

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nT, nF),
        in_specs=[
            pl.BlockSpec((block_t, D), lambda i, j, be: (i, 0)),
            pl.BlockSpec((1, D, block_f), lambda i, j, be: (be[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((block_t, block_f), lambda i, j, be: (i, j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, F), x.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(block_expert, x, w)


def sort_tokens_by_expert(xt, expert_ids, num_experts, *, block_t=128):
    """Host-side dispatch prep: sort token rows by expert, pad each
    expert's group to a block multiple.  Returns
    (x_sorted (Tp, D), block_expert (Tp/block,), inv_perm, valid mask)."""
    T = xt.shape[0]
    order = jnp.argsort(expert_ids, stable=True)
    sorted_ids = expert_ids[order]
    counts = jnp.bincount(expert_ids, length=num_experts)
    padded = ((counts + block_t - 1) // block_t) * block_t
    offs = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                            jnp.cumsum(padded)])[:-1]
    # position of each sorted token within its expert group
    grp_start = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                 jnp.cumsum(counts)])[:-1]
    pos_in_grp = jnp.arange(T) - grp_start[sorted_ids]
    dest = offs[sorted_ids] + pos_in_grp          # sorted position -> slot
    slot_of = jnp.zeros((T,), dest.dtype).at[order].set(dest)  # orig -> slot
    Tp = int(((int(T) + block_t - 1) // block_t + num_experts) * block_t)
    x_sorted = jnp.zeros((Tp, xt.shape[1]), xt.dtype).at[dest].set(xt[order])
    valid = jnp.zeros((Tp,), bool).at[dest].set(True)
    # block -> expert map
    blk = jnp.arange(Tp // block_t) * block_t
    cum = jnp.cumsum(padded)
    block_expert = jnp.searchsorted(cum, blk, side="right").astype(jnp.int32)
    block_expert = jnp.minimum(block_expert, num_experts - 1)
    return x_sorted, block_expert, slot_of, order, valid
