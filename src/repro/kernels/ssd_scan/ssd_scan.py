"""Pallas TPU kernel for the Mamba-2 SSD inter-chunk state recurrence.

The chunked SSD algorithm (models/ssm.py) reduces the sequential work to
    H_c = decay_c * H_{c-1} + S_c
over nc chunks with per-head state (N, P).  This kernel runs one (batch,
head) cell per grid step, keeping the running state in VMEM while looping
chunks with fori_loop, and emits the *previous* state per chunk (what the
intra-chunk term consumes) plus the final state (the decode handoff).

Whole-chunk-axis blocks: nc*N*P fp32 ≈ 2 MiB at production sizes — fits
VMEM comfortably.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _kernel(states_ref, decay_ref, prev_ref, final_ref):
    nc = states_ref.shape[2]          # block = (1, 1, nc, N, P)

    def body(c, h):
        prev_ref[0, 0, c] = h.astype(prev_ref.dtype)
        return h * decay_ref[0, 0, c] + states_ref[0, 0, c].astype(jnp.float32)

    h0 = jnp.zeros(states_ref.shape[3:], jnp.float32)
    h = jax.lax.fori_loop(0, nc, body, h0)
    final_ref[0, 0] = h.astype(final_ref.dtype)


def ssd_state_scan_tpu(states, decay, *, interpret=False):
    """states (B, H, nc, N, P); decay (B, H, nc) ->
    (prev_states (B, H, nc, N, P), final (B, H, N, P))."""
    B, H, nc, N, P = states.shape
    prev, final = pl.pallas_call(
        _kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, 1, nc, N, P), lambda b, h: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, nc), lambda b, h: (b, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, nc, N, P), lambda b, h: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, N, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(states, decay)
    return prev, final
