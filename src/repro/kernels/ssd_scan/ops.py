from functools import partial

import jax

from repro.kernels.ssd_scan.ssd_scan import ssd_state_scan_tpu


@partial(jax.jit, static_argnames=("interpret",))
def ssd_state_scan(states, decay, *, interpret=False):
    return ssd_state_scan_tpu(states, decay, interpret=interpret)
