"""Oracle: lax.scan state recurrence (same math as models/ssm.ssd_chunked)."""
import jax
import jax.numpy as jnp


def ref_state_scan(states, decay):
    B, H, nc, N, P = states.shape

    def f(h, xs):
        s, d = xs
        return h * d[..., None, None] + s, h

    s_t = jnp.moveaxis(states.astype(jnp.float32), 2, 0)
    d_t = jnp.moveaxis(decay.astype(jnp.float32), 2, 0)
    final, prev = jax.lax.scan(f, jnp.zeros((B, H, N, P), jnp.float32),
                               (s_t, d_t))
    return jnp.moveaxis(prev, 0, 2), final
