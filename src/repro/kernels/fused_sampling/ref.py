"""Pure-jnp oracle for the fused single-pass sampling kernel.

Specifies, in plain vectorised jnp, EXACTLY the math the Pallas kernel
(`fused_sampling.py`) runs tiled: an online-softmax stats pass, a joint
top-k/top-p/min-p keep-threshold derived by histogram refinement over
logit buckets (no sorted (B, V) temporaries), and a Gumbel-max draw over
the kept set.  The interpret-mode parity tests in
tests/test_fused_sampling.py hold the kernel to this file.

Threshold semantics (shared with processors.joint_threshold)
------------------------------------------------------------
All three filters are value thresholds, so their sequential composition
keeps exactly ``{x : x >= max(tau_k, tau_p, tau_m)}``:

* ``tau_k``  — the k-th largest logit, located by LEVELS rounds of
  NB-bucket histogram refinement over ``(m - SPAN, m]``: each round bins
  the current interval, walks the cumulative count from the top to the
  bucket where it crosses k, and recurses into that bucket.  Final
  resolution SPAN/NB^LEVELS (~2e-6 nats), i.e. near-ulp "ties" at the
  k-th value are kept — the tie-keeping the sort pipeline has, widened
  to the bucket width.
* ``tau_p``  — the nucleus edge of the top-k-filtered distribution:
  same refinement, crossing on cumulative exp-mass against
  ``p * Z_kept`` (``Z_kept`` = mass of the kept-by-top-k set, a free
  by-product of the tau_k refinement).  Level 0 reuses the coarse mass
  histogram (no extra pass); refinement levels mask to ``x >= tau_k``.
* ``tau_m``  — ``m + log(min_p)``: the renormalisation of earlier
  filters cancels on both sides of the min-p compare.

Values below ``m - SPAN`` carry exp-mass < e^-SPAN ~ 1e-14 and land in
the catch-all bottom bucket: a top-k whose k-th value sits that deep
keeps extra near-zero-probability tokens, which is invisible to the
sampled distribution — the documented approximation of this kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30          # filtered-logit sentinel (matches sampling/processors)
NB = 256             # histogram buckets per refinement level
SPAN = 32.0          # nats below the max covered by the coarse histogram
LEVELS = 3           # coarse + 2 refinements -> SPAN/NB**3 ~ 1.9e-6 nats


def _hist(x, w, sel, hi, width):
    """Bin ``w`` (weights) of the selected ``x <= hi`` into NB buckets of
    ``width`` below ``hi``; values under the interval clamp into the
    catch-all bucket NB-1 (the refinement recurses into it)."""
    sel = sel & (x <= hi)
    idx = jnp.clip(jnp.floor((hi - x) / width).astype(jnp.int32), 0, NB - 1)
    oh = (idx[:, None] == jnp.arange(NB)[None, :]) & sel[:, None]
    cnt = jnp.sum(oh, axis=0).astype(jnp.float32)
    mass = jnp.sum(jnp.where(oh, w[:, None], 0.0), axis=0)
    return cnt, mass


def _cross(cum, per, target):
    """First bucket where ``cum`` reaches ``target`` (bottom bucket when
    it never does), plus the cumulative weight strictly above it."""
    got = cum >= target
    b = jnp.where(jnp.any(got), jnp.argmax(got), NB - 1)
    return b, cum[b] - per[b]


def ref_stats(x):
    """(m, l, greedy): max, softmax denominator w.r.t. m, argmax."""
    m = jnp.max(x)
    return m, jnp.sum(jnp.exp(x - m)), jnp.argmax(x).astype(jnp.int32)


def ref_joint_threshold(x, k, p, min_p):
    """Histogram-refined joint threshold for one row ``x`` (V,) f32.

    Returns a dict with ``tau`` (the joint threshold), the per-filter
    ``tau_k``/``tau_p``/``tau_m`` (-inf when disabled), the softmax
    stats ``m``/``l`` and the kept-set mass ``z``."""
    V = x.shape[-1]
    m, l, _ = ref_stats(x)
    w = jnp.exp(x - m)
    true = jnp.ones_like(x, bool)

    # --- tau_k: count-crossing refinement (+ coarse mass kept for tau_p)
    hi, width = m, SPAN / NB
    rem = jnp.clip(k, 1, V).astype(jnp.float32)
    above_mass = jnp.float32(0.0)
    coarse_mass = None
    tau_k = in_mass = jnp.float32(0.0)
    for lvl in range(LEVELS):
        cnt, mass = _hist(x, w, true, hi, width)
        if lvl == 0:
            coarse_mass = mass
        b, above_cnt = _cross(jnp.cumsum(cnt), cnt, rem)
        above_mass += jnp.cumsum(mass)[b] - mass[b]
        rem = rem - above_cnt
        in_mass = mass[b]
        hi = hi - b.astype(jnp.float32) * width
        tau_k = hi - width
        width = width / NB
    z = jnp.where(k > 0, above_mass + in_mass, l)
    tau_k = jnp.where(k > 0, tau_k, -jnp.inf)

    # --- tau_p: mass-crossing refinement against p * z
    target = p * z
    b, above = _cross(jnp.cumsum(coarse_mass), coarse_mass, target)
    hi = m - b.astype(jnp.float32) * (SPAN / NB)
    tau_p, width = hi - SPAN / NB, SPAN / NB / NB
    kept = x >= tau_k
    for _ in range(1, LEVELS):
        _, mass = _hist(x, w, kept, hi, width)
        b, above_l = _cross(jnp.cumsum(mass), mass, target - above)
        above += above_l
        hi = hi - b.astype(jnp.float32) * width
        tau_p = hi - width
        width = width / NB
    tau_p = jnp.where(p < 1.0, tau_p, -jnp.inf)

    tau_m = jnp.where(min_p > 0.0, m + jnp.log(min_p), -jnp.inf)
    tau = jnp.maximum(jnp.maximum(tau_k, tau_p), tau_m)
    return {"tau": tau, "tau_k": tau_k, "tau_p": tau_p, "tau_m": tau_m,
            "m": m, "l": l, "z": z}


def ref_fused_sample(x, gumbel, k, p, min_p):
    """One row: joint threshold + Gumbel-max draw over the kept set.
    Returns dict(sampled, greedy, tau, m, l)."""
    th = ref_joint_threshold(x, k, p, min_p)
    m, _, greedy = ref_stats(x)
    s = jnp.where(x >= th["tau"], x + gumbel, NEG)
    return {"sampled": jnp.argmax(s).astype(jnp.int32), "greedy": greedy,
            "tau": th["tau"], "m": th["m"], "l": th["l"]}


def ref_lanes(raw, lp_k: int):
    """Raw-logit stats + top-K lanes for the logprob transfer plane.
    Values are raw logits (log-softmax = val - m_raw - log(l_raw));
    ties break to the lowest index, matching ``jax.lax.top_k``."""
    m = jnp.max(raw)
    l = jnp.sum(jnp.exp(raw - m))
    out = {"m_raw": m, "l_raw": l, "top_vals": None, "top_idx": None}
    if lp_k > 0:
        out["top_vals"], out["top_idx"] = jax.lax.top_k(raw, lp_k)
    return out
