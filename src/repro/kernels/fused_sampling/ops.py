"""Jitted wrapper for the fused-sampling kernel (padding + output dict)."""
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_sampling.fused_sampling import (TILE,
                                                         fused_sampling_tpu)
from repro.kernels.fused_sampling.ref import NEG


def _pad_rows(x, vp, fill):
    B, V = x.shape
    if V == vp:
        return x
    return jnp.concatenate(
        [x, jnp.full((B, vp - V), fill, x.dtype)], axis=1)


# VMEM budget for the parked logits row: park whenever the padded row
# fits (128k f32 vocab = 512 KiB; the cap leaves headroom for the
# histogram/lane scratch and double-buffered input tiles)
PARK_VMEM_LIMIT = 1 << 20


@partial(jax.jit,
         static_argnames=("lp_k", "with_lanes", "park_vmem", "interpret"))
def fused_sample(logits, gumbel, k, p, min_p, raw=None, *, lp_k: int = 0,
                 with_lanes: bool = False, park_vmem=None,
                 interpret: bool = False):
    """Single-pass sample for a (B, V) batch of processed logits.

    Pads V up to a TILE multiple with the NEG sentinel (padded tokens
    carry zero probability mass and can never win either argmax).
    ``park_vmem`` (default: auto — on whenever the padded row fits
    ``PARK_VMEM_LIMIT``) parks the logits row in VMEM across the kernel's
    phases so HBM reads it once instead of once per phase.
    Returns a dict with ``sampled``/``greedy`` (B,) i32, ``tau``/``m``/
    ``l`` (B,) f32, plus — when ``with_lanes`` — the raw-logit softmax
    stats ``m_raw``/``l_raw`` and, for ``lp_k > 0``, the ``top_vals``/
    ``top_idx`` lanes ((B, lp_k), raw-logit values with lax.top_k
    tie-breaking; log-softmax = top_vals - m_raw - log(l_raw)).
    """
    vp = -(-logits.shape[1] // TILE) * TILE
    if park_vmem is None:
        park_vmem = vp * 4 <= PARK_VMEM_LIMIT
    args = (_pad_rows(logits.astype(jnp.float32), vp, NEG),
            _pad_rows(gumbel.astype(jnp.float32), vp, 0.0),
            k, p, min_p)
    if with_lanes:
        args += (_pad_rows(raw.astype(jnp.float32), vp, NEG),)
    outs = fused_sampling_tpu(*args, lp_k=lp_k, with_lanes=with_lanes,
                              park_vmem=bool(park_vmem),
                              interpret=interpret)
    names = ["sampled", "greedy", "tau", "m", "l"]
    if with_lanes:
        names += ["m_raw", "l_raw"]
        if lp_k > 0:
            names += ["top_vals", "top_idx"]
    return dict(zip(names, outs))
