"""Pallas TPU fused-sampling kernel: joint top-k/top-p/min-p + Gumbel-max.

Replaces the sampling hot path's sorted (B, V) temporaries with tiled
streaming passes over the vocab.  Grid = (B, 7 phases, V/TILE tiles);
the batch axis is parallel, phases and tiles are sequential so all
per-row state lives in VMEM/SMEM scratch (the paged_attention pattern):

  phase 0  online-softmax stats: running max m, denominator l, greedy
           argmax — plus, when logprob lanes are requested, the raw-logit
           stats and a streaming top-K merge (the PR 3 transfer plane's
           pre-filter lanes, fused into the same kernel launch).
  phase 1  coarse NB-bucket histogram (counts + exp-mass) of
           ``(m - SPAN, m]``; pick the bucket where the cumulative count
           crosses k; the mass histogram is kept for tau_p's level 0.
  phase 2/3  two count-crossing refinements -> tau_k and Z_kept (the
           kept set's softmax mass) at SPAN/NB^3 ~ 2e-6 nat resolution.
  phase 4/5  two mass-crossing refinements against p * Z_kept -> tau_p
           (level 0 reused phase 1's histogram: no extra pass).
  phase 6  Gumbel-max over the kept set ``x >= max(tau_k, tau_p, tau_m)``.

The Gumbel noise is an INPUT (the token-addressed
``sampling.sample.token_gumbel`` rows — one threefry hash of
``fold_in(step_key, token_id)`` per token), not kernel-generated: the
draw stays bitwise-shared with every XLA fallback tier, the
per-sequence stream stays a pure function of (seed, t, token), and the
kernel stays deterministic — which is what lets
tests/test_fused_sampling.py hold it exactly to ``ref.py``.

Histogram binning is scatter-free (bucket-index compare against a
broadcasted iota, then a lane reduction): O(TILE * NB) VPU work per
tile, but only O(V) HBM traffic per phase — the trade "Mind the Memory
Gap" calls for in the bandwidth-bound decode regime.

VMEM row parking (``park_vmem=True``, the default whenever the row fits
— 128k f32 = 512 KB): phase 0 copies each logits tile into a (1, V)
VMEM scratch row and phases 1-6 read from the scratch, collapsing the
seven HBM reads of the logits to ONE.  The phase-idle inputs stop
streaming too: each input's BlockSpec index map pins its block while the
phase doesn't consume it (logits after phase 0, the raw row after phase
0, the Gumbel row before phase 6), so the pipeline fetches every operand
from HBM exactly once.  The math is bit-identical to the unparked kernel
— both are held to ``ref.py`` by the interpret-mode parity tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels.fused_sampling.ref import LEVELS, NB, NEG, SPAN

assert LEVELS == 3, "kernel phase layout is built for 3 histogram levels"

TILE = 512

# SMEM scalar-state slots (one row's state across the sequential phases)
_M, _L, _HI, _W, _REM, _ABOVE, _INB, _TAU_K, _Z, _TARGET, _ABOVE_P, \
    _TAU_P, _TAU, _GVAL, _GIDX, _SVAL, _SIDX, _M_RAW, _L_RAW = range(19)
_NST = 19

_PH_STATS, _PH_COARSE, _PH_K1, _PH_K2, _PH_P1, _PH_P2, _PH_SAMPLE = range(7)
_NPH = 7


def _kernel(k_ref, p_ref, minp_ref, x_ref, g_ref, *rest,
            tiles: int, lanes_k: int, park: bool):
    if park:                    # parked logits row is the LAST scratch arg
        rest, xv = rest[:-1], rest[-1]
    if lanes_k >= 0:
        raw_ref = rest[0]
        outs = rest[1:]
        o_sam, o_greedy, o_tau, o_m, o_l, o_mr, o_lr = outs[:7]
        if lanes_k > 0:
            o_tv, o_ti = outs[7:9]
            st, hist_cnt, hist_mass, coarse_mass, topv, topi = outs[9:]
        else:
            st, hist_cnt, hist_mass, coarse_mass = outs[7:]
    else:
        o_sam, o_greedy, o_tau, o_m, o_l = rest[:5]
        st, hist_cnt, hist_mass, coarse_mass = rest[5:]

    b = pl.program_id(0)
    ph = pl.program_id(1)
    j = pl.program_id(2)
    x_in = x_ref[0].astype(jnp.float32)                    # (TILE,)
    if park:
        # phase 0 parks each tile in the VMEM row; later phases read the
        # scratch (x_ref is pinned to block 0 then — its value is only
        # selected during phase 0, so the stale block is harmless)
        @pl.when(ph == _PH_STATS)
        def _park_tile():
            xv[0, pl.ds(j * TILE, TILE)] = x_in
        x = jnp.where(ph == _PH_STATS, x_in,
                      xv[0, pl.ds(j * TILE, TILE)])
    else:
        x = x_in
    pos = j * TILE + jax.lax.broadcasted_iota(
        jnp.int32, (TILE, 1), 0)[:, 0]

    @pl.when((ph == _PH_STATS) & (j == 0))
    def _init():
        st[_M] = jnp.float32(-jnp.inf)
        st[_L] = jnp.float32(0.0)
        st[_GVAL] = jnp.float32(-jnp.inf)
        st[_GIDX] = jnp.float32(0.0)
        if lanes_k >= 0:
            st[_M_RAW] = jnp.float32(-jnp.inf)
            st[_L_RAW] = jnp.float32(0.0)
            if lanes_k > 0:
                topv[...] = jnp.full_like(topv, NEG)
                topi[...] = jnp.zeros_like(topi)

    # ---------------------------------------------------- phase 0: stats
    @pl.when(ph == _PH_STATS)
    def _stats():
        m_prev = st[_M]
        tmax = jnp.max(x)
        m_new = jnp.maximum(m_prev, tmax)
        st[_L] = st[_L] * jnp.exp(m_prev - m_new) + jnp.sum(jnp.exp(x - m_new))
        st[_M] = m_new

        @pl.when(tmax > st[_GVAL])
        def _():
            st[_GVAL] = tmax
            st[_GIDX] = (j * TILE + jnp.argmax(x)).astype(jnp.float32)

        if lanes_k >= 0:
            r = raw_ref[0].astype(jnp.float32)
            mr_prev = st[_M_RAW]
            mr_new = jnp.maximum(mr_prev, jnp.max(r))
            st[_L_RAW] = (st[_L_RAW] * jnp.exp(mr_prev - mr_new)
                          + jnp.sum(jnp.exp(r - mr_new)))
            st[_M_RAW] = mr_new
            if lanes_k > 0:
                # streaming top-K merge; first-occurrence argmax keeps the
                # lax.top_k lowest-index tie-breaking (prev lanes, from
                # earlier tiles, come first in the candidate row)
                cv = jnp.concatenate([topv[0], r])
                ci = jnp.concatenate([topi[0], pos.astype(jnp.float32)])
                sel = jax.lax.broadcasted_iota(
                    jnp.int32, (lanes_k + TILE, 1), 0)[:, 0]
                nv, ni = [], []
                for _kk in range(lanes_k):
                    a = jnp.argmax(cv)
                    nv.append(cv[a])
                    ni.append(ci[a])
                    cv = jnp.where(sel == a, NEG, cv)
                topv[0] = jnp.stack(nv)
                topi[0] = jnp.stack(ni)

    # ------------------------------------------- histogram accumulation
    def _bin(sel):
        hi, width = st[_HI], st[_W]
        sel = sel & (x <= hi)
        idx = jnp.clip(jnp.floor((hi - x) / width), 0, NB - 1).astype(
            jnp.int32)
        oh = ((idx[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (TILE, NB), 1)) & sel[:, None])
        hist_cnt[0] = hist_cnt[0] + jnp.sum(oh.astype(jnp.float32), axis=0)
        w = jnp.exp(x - st[_M])
        hist_mass[0] = hist_mass[0] + jnp.sum(
            jnp.where(oh, w[:, None], 0.0), axis=0)

    def _zero_hist():
        hist_cnt[...] = jnp.zeros_like(hist_cnt)
        hist_mass[...] = jnp.zeros_like(hist_mass)

    def _crossing(cum, per, target):
        got = cum >= target
        bk = jnp.where(jnp.any(got), jnp.argmax(got), NB - 1)
        return bk, cum[bk] - per[bk]

    @pl.when((ph == _PH_STATS) & (j == tiles - 1))
    def _open_coarse():
        st[_HI] = st[_M]
        st[_W] = jnp.float32(SPAN / NB)
        st[_REM] = jnp.clip(k_ref[b], 1, tiles * TILE).astype(jnp.float32)
        st[_ABOVE] = jnp.float32(0.0)
        _zero_hist()

    @pl.when((ph == _PH_COARSE) | (ph == _PH_K1) | (ph == _PH_K2))
    def _bin_k():
        _bin(jnp.ones_like(x, bool))

    def _k_level_update():
        cnt, mass = hist_cnt[0], hist_mass[0]
        bk, above_cnt = _crossing(jnp.cumsum(cnt), cnt, st[_REM])
        st[_ABOVE] = st[_ABOVE] + jnp.cumsum(mass)[bk] - mass[bk]
        st[_REM] = st[_REM] - above_cnt
        st[_INB] = mass[bk]
        st[_HI] = st[_HI] - bk.astype(jnp.float32) * st[_W]
        st[_TAU_K] = st[_HI] - st[_W]
        st[_W] = st[_W] / NB
        _zero_hist()

    @pl.when((ph == _PH_COARSE) & (j == tiles - 1))
    def _end_coarse():
        coarse_mass[...] = hist_mass[...]
        _k_level_update()

    @pl.when(((ph == _PH_K1) | (ph == _PH_K2)) & (j == tiles - 1))
    def _end_k():
        _k_level_update()

        @pl.when(ph == _PH_K2)
        def _open_p():
            kk = k_ref[b]
            st[_TAU_K] = jnp.where(kk > 0, st[_TAU_K], -jnp.inf)
            st[_Z] = jnp.where(kk > 0, st[_ABOVE] + st[_INB], st[_L])
            st[_TARGET] = p_ref[b] * st[_Z]
            cm = coarse_mass[0]
            bp, above = _crossing(jnp.cumsum(cm), cm, st[_TARGET])
            st[_ABOVE_P] = above
            w0 = jnp.float32(SPAN / NB)
            st[_HI] = st[_M] - bp.astype(jnp.float32) * w0
            st[_TAU_P] = st[_HI] - w0
            st[_W] = w0 / NB

    @pl.when((ph == _PH_P1) | (ph == _PH_P2))
    def _bin_p():
        _bin(x >= st[_TAU_K])

    @pl.when(((ph == _PH_P1) | (ph == _PH_P2)) & (j == tiles - 1))
    def _end_p():
        mass = hist_mass[0]
        bp, above_l = _crossing(jnp.cumsum(mass), mass,
                                st[_TARGET] - st[_ABOVE_P])
        st[_ABOVE_P] = st[_ABOVE_P] + above_l
        st[_HI] = st[_HI] - bp.astype(jnp.float32) * st[_W]
        st[_TAU_P] = st[_HI] - st[_W]
        st[_W] = st[_W] / NB
        _zero_hist()

        @pl.when(ph == _PH_P2)
        def _close_tau():
            tau_p = jnp.where(p_ref[b] < 1.0, st[_TAU_P], -jnp.inf)
            tau_m = jnp.where(minp_ref[b] > 0.0,
                              st[_M] + jnp.log(minp_ref[b]), -jnp.inf)
            st[_TAU] = jnp.maximum(jnp.maximum(st[_TAU_K], tau_p), tau_m)
            st[_SVAL] = jnp.float32(-jnp.inf)
            st[_SIDX] = jnp.float32(0.0)

    # ------------------------------------------- phase 6: Gumbel-max draw
    @pl.when(ph == _PH_SAMPLE)
    def _draw():
        s = jnp.where(x >= st[_TAU], x + g_ref[0].astype(jnp.float32), NEG)
        tmax = jnp.max(s)

        @pl.when(tmax > st[_SVAL])
        def _():
            st[_SVAL] = tmax
            st[_SIDX] = (j * TILE + jnp.argmax(s)).astype(jnp.float32)

    @pl.when((ph == _PH_SAMPLE) & (j == tiles - 1))
    def _flush():
        o_sam[0] = st[_SIDX].astype(jnp.int32)
        o_greedy[0] = st[_GIDX].astype(jnp.int32)
        o_tau[0] = st[_TAU]
        o_m[0] = st[_M]
        o_l[0] = st[_L]
        if lanes_k >= 0:
            o_mr[0] = st[_M_RAW]
            o_lr[0] = st[_L_RAW]
            if lanes_k > 0:
                o_tv[0] = topv[0]
                o_ti[0] = topi[0].astype(jnp.int32)


def fused_sampling_tpu(logits, gumbel, k, p, min_p, raw=None, *,
                       lp_k: int = 0, with_lanes: bool = False,
                       park_vmem: bool = False, interpret: bool = False):
    """logits/gumbel (B, V) f32 with V a multiple of TILE (pad with the
    NEG sentinel / zeros — see ops.fused_sample); k (B,) i32, p/min_p
    (B,) f32 scalar-prefetch rows; raw (B, V) only when ``with_lanes``.
    ``park_vmem`` parks the logits row in a (1, V) VMEM scratch across
    the phases (caller checks the row fits — V * 4 bytes of VMEM).

    Returns (sampled, greedy, tau, m, l[, m_raw, l_raw[, top_vals,
    top_idx]]).
    """
    B, V = logits.shape
    assert V % TILE == 0, V
    tiles = V // TILE
    lanes_k = (max(lp_k, 0) if with_lanes else -1)

    row = pl.BlockSpec((1, TILE), lambda bb, ph, jj, kk, pp, mm: (bb, jj))
    scalar = pl.BlockSpec((1,), lambda bb, ph, jj, kk, pp, mm: (bb,))
    lane = pl.BlockSpec((1, max(lp_k, 1)),
                        lambda bb, ph, jj, kk, pp, mm: (bb, 0))

    def _phase_pinned(active_ph):
        """Stream the row's tiles only while ``active_ph`` consumes them;
        every other phase pins the block index so the pipeline does not
        re-fetch the operand from HBM."""
        return pl.BlockSpec(
            (1, TILE),
            lambda bb, ph, jj, kk, pp, mm: (
                bb, jnp.where(ph == active_ph, jj, 0)))

    if park_vmem:
        in_specs = [_phase_pinned(_PH_STATS), _phase_pinned(_PH_SAMPLE)] \
            + ([_phase_pinned(_PH_STATS)] if with_lanes else [])
    else:
        in_specs = [row, row] + ([row] if with_lanes else [])
    out_shapes = [jax.ShapeDtypeStruct((B,), jnp.int32),      # sampled
                  jax.ShapeDtypeStruct((B,), jnp.int32),      # greedy
                  jax.ShapeDtypeStruct((B,), jnp.float32),    # tau
                  jax.ShapeDtypeStruct((B,), jnp.float32),    # m
                  jax.ShapeDtypeStruct((B,), jnp.float32)]    # l
    out_specs = [scalar] * 5
    if with_lanes:
        out_shapes += [jax.ShapeDtypeStruct((B,), jnp.float32),   # m_raw
                       jax.ShapeDtypeStruct((B,), jnp.float32)]   # l_raw
        out_specs += [scalar, scalar]
        if lp_k > 0:
            out_shapes += [jax.ShapeDtypeStruct((B, lp_k), jnp.float32),
                           jax.ShapeDtypeStruct((B, lp_k), jnp.int32)]
            out_specs += [lane, lane]

    scratch = [pltpu.SMEM((_NST,), jnp.float32),
               pltpu.VMEM((1, NB), jnp.float32),     # hist counts
               pltpu.VMEM((1, NB), jnp.float32),     # hist mass
               pltpu.VMEM((1, NB), jnp.float32)]     # coarse mass (tau_p L0)
    if lanes_k > 0:
        scratch += [pltpu.VMEM((1, lanes_k), jnp.float32),
                    pltpu.VMEM((1, lanes_k), jnp.float32)]
    if park_vmem:
        scratch += [pltpu.VMEM((1, V), jnp.float32)]   # parked logits row

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, _NPH, tiles),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    kernel = functools.partial(_kernel, tiles=tiles, lanes_k=lanes_k,
                               park=park_vmem)
    args = (k.astype(jnp.int32), p.astype(jnp.float32),
            min_p.astype(jnp.float32), logits, gumbel)
    if with_lanes:
        args += (raw,)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(*args)
