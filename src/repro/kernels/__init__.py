"""Pallas kernel registry.

Each entry is an ``<name>.py`` (kernel) + ``ops.py`` (jitted wrapper) +
``ref.py`` (pure-jnp oracle) triple; tests/test_kernels.py and
tests/test_fused_sampling.py hold every kernel to its oracle in
interpret mode.  ``get_kernel(name)`` resolves the wrapped entry point
and its reference lazily so importing the package never pulls Pallas in.
"""
import importlib

# name -> (ops entry point, reference oracle)
KERNELS = {
    "flash_attention": ("flash_attention", "ref_attention"),
    "paged_attention": ("paged_attention", "ref_paged_attention"),
    "moe_gemm": ("moe_ffn", "ref_moe_ffn"),
    "ssd_scan": ("ssd_state_scan", "ref_state_scan"),
    "fused_sampling": ("fused_sample", "ref_fused_sample"),
}


def get_kernel(name: str):
    """(jitted op, pure-jnp reference) for a registered kernel."""
    op_name, ref_name = KERNELS[name]
    ops = importlib.import_module(f"repro.kernels.{name}.ops")
    ref = importlib.import_module(f"repro.kernels.{name}.ref")
    return getattr(ops, op_name), getattr(ref, ref_name)
