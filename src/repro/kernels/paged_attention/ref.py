"""Pure-jnp oracle: gather pages into a dense cache, run masked softmax."""
import math

import jax.numpy as jnp
import jax


def ref_paged_attention(q, k_pool, v_pool, page_table, lengths):
    B, H, dh = q.shape
    num_pages, page, Hkv, _ = k_pool.shape
    max_pages = page_table.shape[1]
    G = H // Hkv
    k = k_pool[page_table].reshape(B, max_pages * page, Hkv, dh)
    v = v_pool[page_table].reshape(B, max_pages * page, Hkv, dh)
    kf = jnp.repeat(k.astype(jnp.float32), G, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), G, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), kf) / math.sqrt(dh)
    pos = jnp.arange(max_pages * page)[None, :]
    s = jnp.where((pos < lengths[:, None])[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, vf).astype(q.dtype)
