"""Pallas TPU paged-attention (decode) kernel.

One new token per sequence attends to its KV history stored in a shared
page pool (the two-page lazy allocation layout of §5.2).  The page table is
a scalar-prefetch operand: the BlockSpec index_map reads ``table[b, j]`` to
stream exactly that sequence's pages from HBM — no gather materialization.
Grid = (B, max_pages) with the page axis sequential so the online-softmax
state lives in VMEM scratch.

Pages are (page_size, Hkv, dh) tiles; page_size is chosen as a multiple of
the 128-lane register width by the memory planner.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG = -1e30


def _kernel(table_ref, len_ref, q_ref, kpool_ref, vpool_ref, o_ref,
            m_scr, l_scr, acc_scr, *, page_size: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    npages = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]
    page_start = j * page_size

    @pl.when(page_start < length)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale       # (H, dh)
        k = kpool_ref[0].astype(jnp.float32)           # (page, Hkv, dh)
        v = vpool_ref[0].astype(jnp.float32)
        H, dh = q.shape
        page, Hkv, _ = k.shape
        G = H // Hkv
        qg = q.reshape(Hkv, G, dh)
        s = jnp.einsum("hgd,phd->hgp", qg, k,
                       preferred_element_type=jnp.float32)
        pos = page_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(pos < length, s, NEG)
        m_prev = m_scr[...]                            # (H, 1)
        sm = s.reshape(H, page)
        m_new = jnp.maximum(m_prev, jnp.max(sm, axis=1, keepdims=True))
        p = jnp.exp(sm - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new
        pv = jnp.einsum("hgp,phd->hgd", p.reshape(Hkv, G, page), v,
                        preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv.reshape(H, dh)

    @pl.when(j == npages - 1)
    def _flush():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_attention_tpu(q, k_pool, v_pool, page_table, lengths, *,
                        interpret=False):
    """q (B, H, dh); pools (num_pages, page, Hkv, dh);
    page_table (B, max_pages) int32; lengths (B,) int32."""
    B, H, dh = q.shape
    num_pages, page, Hkv, _ = k_pool.shape
    max_pages = page_table.shape[1]
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(_kernel, page_size=page, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, H, dh), lambda b, j, tab, ln: (b, 0, 0)),
            pl.BlockSpec((1, page, Hkv, dh),
                         lambda b, j, tab, ln: (tab[b, j], 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv, dh),
                         lambda b, j, tab, ln: (tab[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, dh), lambda b, j, tab, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, dh), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, lengths, q, k_pool, v_pool)
    return out
