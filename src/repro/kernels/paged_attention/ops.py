"""Jitted wrapper for the paged-attention decode kernel."""
from functools import partial

import jax

from repro.kernels.paged_attention.paged_attention import paged_attention_tpu


@partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pool, v_pool, page_table, lengths, *,
                    interpret=False):
    return paged_attention_tpu(q, k_pool, v_pool, page_table, lengths,
                               interpret=interpret)
