"""Pallas TPU flash-attention (prefill) kernel.

Canonical TPU tiling: grid = (batch*kv_heads*q_per_kv, num_q_blocks,
num_kv_blocks) with the kv axis 'arbitrary' (sequential) so the online
softmax state (m, l, acc) persists in VMEM scratch across kv blocks.
Block shapes are MXU-aligned (q_block x head_dim, kv_block x head_dim).
Causal + sliding-window masking via block-local iota; whole kv blocks that
cannot contribute are skipped with @pl.when.

Validated in interpret mode against ref.py (pure-jnp oracle) over
shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, scale: float, block_q: int,
            block_k: int, seq_q: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, dh)
        k = k_ref[0].astype(jnp.float32)                  # (bk, dh)
        v = v_ref[0].astype(jnp.float32)                  # (bk, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v).astype(jnp.float32)

    if causal:
        # skip kv blocks entirely above the diagonal
        pl.when(k_start <= q_start + block_q - 1)(_body)
    else:
        _body()

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_tpu(q, k, v, *, causal=True, window=0, block_q=128,
                        block_k=128, interpret=False):
    """q (B, Sq, H, dh); k/v (B, Skv, Hkv, dh_v); GQA via head folding.

    Returns (B, Sq, H, dv).  Sq/Skv are padded to block multiples
    internally by ops.py — this entry requires aligned shapes.
    """
    B, Sq, H, dh = q.shape
    Skv, Hkv, dv = k.shape[1], k.shape[2], v.shape[-1]
    G = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    scale = 1.0 / math.sqrt(dh)

    # fold: one grid row per (b, h)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, dh)
    kf = jnp.repeat(k, G, axis=2).transpose(0, 2, 1, 3).reshape(B * H, Skv, dh)
    vf = jnp.repeat(v, G, axis=2).transpose(0, 2, 1, 3).reshape(B * H, Skv, dv)

    grid = (B * H, Sq // block_q, Skv // block_k)
    kernel = functools.partial(_kernel, causal=causal, window=window,
                               scale=scale, block_q=block_q, block_k=block_k,
                               seq_q=Sq, seq_k=Skv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, dv), jnp.float32),  # acc
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, dv).transpose(0, 2, 1, 3)
