"""Pure-jnp oracle for the flash attention kernel."""
from repro.models.flash import naive_attention
import jax.numpy as jnp


def ref_attention(q, k, v, *, causal=True, window=0):
    B, Sq = q.shape[0], q.shape[1]
    Skv = k.shape[1]
    qp = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None], (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32)[None], (B, Skv))
    return naive_attention(q, k, v, qp, kp, causal=causal, window=window)
