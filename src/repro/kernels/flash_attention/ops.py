"""Jitted wrapper: pads sequences to block multiples and dispatches to the
Pallas kernel (TPU) or the pure-JAX flash path (interpret/CPU)."""
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_tpu


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=False):
    B, Sq, H, dh = q.shape
    Skv = k.shape[1]
    bq = min(block_q, max(8, 1 << (Sq - 1).bit_length()))
    bk = min(block_k, max(8, 1 << (Skv - 1).bit_length()))
    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    out = flash_attention_tpu(qp, kp, vp, causal=causal, window=window,
                              block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :Sq]
