"""Train a ~100M-class model (SmolLM-360M family, width-reduced to fit this
CPU container) for a few hundred steps with the production train_step:
microbatched grad accumulation + ZeRO-1 AdamW + remat + flash attention.

    PYTHONPATH=src python examples/train_smollm.py [--steps 200]
"""
import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import get_config
from repro.models import transformer as T
from repro.models.api import MeshAxes

AXES = MeshAxes()


def synthetic_lm_batch(rng, B, S, vocab):
    """Markov-chain synthetic data so the loss has learnable structure."""
    trans = rng.integers(2, vocab, (vocab,))
    toks = np.zeros((B, S), np.int32)
    toks[:, 0] = rng.integers(2, vocab, B)
    for t in range(1, S):
        toks[:, t] = np.where(rng.random(B) < 0.8, trans[toks[:, t - 1]],
                              rng.integers(2, vocab, B))
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config("smollm_360m"), num_layers=6,
                              d_model=256, num_heads=8, num_kv_heads=4,
                              head_dim=32, d_ff=512, vocab_size=1024)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n/1e6:.1f}M params")
    ocfg = optim.AdamWConfig(lr=1e-3, zero1=False, weight_decay=0.01)
    opt = optim.init_opt_state(params, n_dev=1)

    @jax.jit
    def train_step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.forward_loss(cfg, AXES, p, batch, remat=True))(params)
        params, opt, gnorm = optim.apply_updates(ocfg, params, grads, opt, 1)
        return params, opt, loss, gnorm

    rng = np.random.default_rng(0)
    t0 = time.time()
    for step in range(args.steps):
        batch = synthetic_lm_batch(rng, args.batch, args.seq, cfg.vocab_size)
        params, opt, loss, gnorm = train_step(params, opt, batch)
        if step % 20 == 0 or step == args.steps - 1:
            toks = args.batch * args.seq * (step + 1)
            print(f"step {step:4d} loss={float(loss):7.4f} "
                  f"gnorm={float(gnorm):6.2f} "
                  f"tok/s={toks/(time.time()-t0):8.0f}")
    print("done — loss should have dropped well below ln(vocab)=6.9")


if __name__ == "__main__":
    main()
