"""RL-rollout acceleration (paper §6.3): fixed batch of 256 rollouts on a
simulated 16-GPU cluster; ON_LONG_TAIL PARTITION reclaims idle devices when
the batch drains.

    PYTHONPATH=src python examples/rl_rollout.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.core.scheduler import SchedulerConfig
from repro.runtime.cluster import Cluster, Workload


def rollout_workload(n=256, seed=0):
    rng = np.random.default_rng(seed)
    # heavily long-tailed generation lengths (paper: final seqs reach >40K)
    outs = np.minimum(np.maximum(
        rng.lognormal(np.log(2048), 1.3, n).astype(int), 64), 49152)
    return Workload([[1] * 512 for _ in range(n)], [int(o) for o in outs])


def main():
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    wl = rollout_workload()
    for partition in (False, True):
        sc = SchedulerConfig(page_size=64,
                             longtail_active=8 if partition else 0,
                             longtail_min_remaining=4096)
        cl = Cluster(cfg, hw, nodes=2, devices_per_node=8, max_active=256,
                     max_len=51200, sched_cfg=sc)
        rep = cl.run(wl)
        parts = sum(e.stats.counts["partition"] for e in cl.engines)
        print(f"partition={'ON ' if partition else 'OFF'} "
              f"rollout_time={rep['bct_s']:8.1f}s util={rep['utilization']:.3f} "
              f"partitions={parts}")
        if partition:
            t_on = rep["bct_s"]
        else:
            t_off = rep["bct_s"]
    print(f"-> PARTITION cut rollout time {100*(1-t_on/t_off):.1f}% "
          f"(paper: 5-10% per iteration)")


if __name__ == "__main__":
    main()
