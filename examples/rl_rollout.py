"""RL-rollout acceleration (paper §6.3): GRPO-style groups — 16 distinct
prompts each fanned into 16 forked rollouts (256 sequences) on a simulated
16-GPU cluster.  ``submit(n=16)`` prefills each prompt ONCE and forks the
group off the shared prefix pages, so prompt FLOPs drop ~16x; ON_LONG_TAIL
PARTITION then reclaims idle devices when the batch drains.

    PYTHONPATH=src python examples/rl_rollout.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.core.scheduler import SchedulerConfig
from repro.runtime.cluster import Cluster, Workload

GROUP = 16      # rollouts per prompt (the GRPO group size)


def rollout_workload(n_prompts=16, seed=0):
    rng = np.random.default_rng(seed)
    # heavily long-tailed generation lengths (paper: final seqs reach >40K);
    # one max_out per FORKED rollout is drawn inside submit via broadcast,
    # so draw per-prompt here — the group shares a budget like real GRPO
    outs = np.minimum(np.maximum(
        rng.lognormal(np.log(2048), 1.3, n_prompts).astype(int), 64), 49152)
    prompts = [[1 + i] * 512 for i in range(n_prompts)]
    return Workload(prompts, [int(o) for o in outs])


def main():
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    wl = rollout_workload()
    for partition in (False, True):
        # the drained-batch tail is whole fork groups (siblings stay on one
        # node), so the long-tail knob is sized in groups, not sequences
        sc = SchedulerConfig(page_size=64,
                             longtail_active=2 * GROUP if partition else 0,
                             longtail_min_remaining=4096)
        cl = Cluster(cfg, hw, nodes=2, devices_per_node=8, max_active=256,
                     max_len=51200, sched_cfg=sc)
        rep = cl.run(wl, n=GROUP)
        parts = sum(e.stats.counts["partition"] for e in cl.engines)
        computed = sum(e.prefill_tokens for e in cl.engines)
        saved = sum(e.prefill_tokens_saved for e in cl.engines)
        print(f"partition={'ON ' if partition else 'OFF'} "
              f"rollout_time={rep['bct_s']:8.1f}s util={rep['utilization']:.3f} "
              f"partitions={parts}")
        print(f"  prefix reuse: {wl.n}x{GROUP} rollouts, prompt tokens "
              f"computed={computed} saved={saved} "
              f"({(computed + saved) / max(computed, 1):.1f}x fewer "
              f"prefill FLOPs)")
        if partition:
            t_on = rep["bct_s"]
        else:
            t_off = rep["bct_s"]
    print(f"-> PARTITION cut rollout time {100*(1-t_on/t_off):.1f}% "
          f"(paper: 5-10% per iteration)")


if __name__ == "__main__":
    main()
