"""Crash-resumable batch job (§5.6): SIGKILL a run mid-batch, resume it,
get byte-identical output with zero recompute of finished sequences.

The write-ahead ``JobLedger`` journals every finished request the moment
its ``SeqFinishedEvent`` comes off the ``BatchMaster`` stream; a rerun
with the same ledger skips the journaled requests and decodes only the
remainder.  Because the runtime's decode is deterministic (greedy +
token-addressable fold_in sampling), the stitched output equals the
uninterrupted run byte for byte.

    PYTHONPATH=src python examples/resumable_batch.py              # demo
    PYTHONPATH=src python examples/resumable_batch.py --selftest   # CI smoke

``--selftest`` runs the full kill-and-resume protocol in subprocesses:
an uninterrupted reference run, a run SIGKILLed after K finishes (real
signal 9 — no atexit, no flush), and a resumed run; it asserts the
resumed output file equals the reference byte for byte and that the
resume skipped every journaled request.
"""
import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import reduced_config
from repro.runtime.api import BatchMaster, BatchRequest
from repro.runtime.engine import NodeEngine
from repro.runtime.ledger import JobLedger, run_resumable
from repro.sampling import SamplingParams

N_REQ = 8
MAX_TOKENS = 12


def make_requests():
    cfg = reduced_config("phi3_5_moe")
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(N_REQ):
        prompt = [int(t) for t in rng.integers(2, cfg.vocab_size,
                                               int(rng.integers(4, 10)))]
        # mix greedy and seeded-sampled rows: both must be reproducible
        sp = (SamplingParams() if i % 2 == 0
              else SamplingParams(temperature=0.8, top_k=40, seed=100 + i))
        reqs.append(BatchRequest(custom_id=f"req-{i}", prompt=prompt,
                                 max_tokens=MAX_TOKENS, sampling=sp))
    return reqs


def make_master():
    cfg = reduced_config("phi3_5_moe")
    eng = NodeEngine(cfg, node_id=0, max_active=4, max_len=128,
                     page_size=16, seed=0)
    return BatchMaster([eng])


def worker(ledger: str, out: str, kill_after: int):
    """One resumable pass.  With ``kill_after`` > 0, SIGKILL ourselves the
    instant the K-th output row commits to the ledger — a real crash in
    the middle of the batch, after durable progress exists."""
    def maybe_kill(_cid, n_done):
        if kill_after and n_done >= kill_after:
            os.kill(os.getpid(), signal.SIGKILL)

    res = run_resumable(make_master(), make_requests(), ledger,
                        on_output=maybe_kill)
    with open(out, "w") as f:
        f.write("\n".join(json.dumps(r) for r in res.rows) + "\n")
    print(f"[worker] resumed={res.resumed} computed={res.computed} "
          f"rows={len(res.rows)}")
    return res


def selftest():
    py = sys.executable
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    me = os.path.abspath(__file__)
    with tempfile.TemporaryDirectory() as td:
        ref_out = os.path.join(td, "ref.jsonl")
        led = os.path.join(td, "job.ledger.jsonl")
        res_out = os.path.join(td, "resumed.jsonl")

        def run(args):
            return subprocess.run([py, me] + args, env=env,
                                  capture_output=True, text=True)

        # 1) uninterrupted reference (its own ledger)
        r = run(["--worker", "--ledger", os.path.join(td, "ref.ledger"),
                 "--out", ref_out])
        assert r.returncode == 0, r.stderr
        # 2) crash mid-batch: SIGKILL after 3 committed rows
        r = run(["--worker", "--ledger", led, "--out", res_out,
                 "--kill-after", "3"])
        assert r.returncode == -signal.SIGKILL, \
            f"expected SIGKILL, got rc={r.returncode}\n{r.stderr}"
        assert not os.path.exists(res_out), "killed run must not emit output"
        survivors = JobLedger(led)
        survivors._load()
        n_journaled = len(survivors.finished)
        assert n_journaled >= 3, f"ledger lost rows: {n_journaled}"
        # 3) resume: skips journaled rows, decodes the rest
        r = run(["--worker", "--ledger", led, "--out", res_out])
        assert r.returncode == 0, r.stderr
        assert f"resumed={n_journaled}" in r.stdout, \
            f"resume recomputed finished work:\n{r.stdout}"
        with open(ref_out, "rb") as f:
            ref = f.read()
        with open(res_out, "rb") as f:
            got = f.read()
        assert got == ref, "resumed output differs from uninterrupted run"
        print(f"[selftest] PASS: killed after {n_journaled}/{N_REQ} rows, "
              f"resume skipped all {n_journaled}, output byte-identical")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--selftest", action="store_true")
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--ledger", default="/tmp/resumable_batch.ledger.jsonl")
    ap.add_argument("--out", default="/tmp/resumable_batch.out.jsonl")
    ap.add_argument("--kill-after", type=int, default=0)
    args = ap.parse_args()
    if args.selftest:
        selftest()
    elif args.worker:
        worker(args.ledger, args.out, args.kill_after)
    else:
        # demo: fresh ledger, run, then rerun to show the no-op resume
        if os.path.exists(args.ledger):
            os.unlink(args.ledger)
        worker(args.ledger, args.out, 0)
        res = worker(args.ledger, args.out, 0)
        assert res.resumed == N_REQ and res.computed == 0
        print(f"[demo] second pass served all {N_REQ} rows from the ledger")


if __name__ == "__main__":
    main()
