"""Quickstart: batch inference through the OpenAI-compatible Batch API on a
reduced llama config (CPU, ~1 min).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import reduced_config
from repro.core.scheduler import SchedulerConfig
from repro.runtime.api import BatchMaster, BatchRequest
from repro.runtime.engine import NodeEngine


def main():
    cfg = reduced_config("llama3_2_1b")
    rng = np.random.default_rng(0)

    # one node, 4 device slots, paged host store
    engine = NodeEngine(cfg, max_active=4, max_len=128, page_size=16)
    master = BatchMaster([engine], SchedulerConfig(page_size=16))

    requests = [
        BatchRequest(custom_id=f"req-{i}",
                     prompt=list(rng.integers(2, cfg.vocab_size, 8)),
                     max_tokens=int(rng.integers(4, 24)))
        for i in range(10)
    ]
    bid = master.submit(requests)
    batch = master.run(bid)

    print(f"batch {batch.id}: {batch.status}, "
          f"{batch.request_counts['completed']}/{batch.request_counts['total']} "
          f"completed, BCT={batch.bct_s:.2f}s")
    print(master.output_file(bid).splitlines()[0])
    print(f"primitives: {engine.stats.counts}")


if __name__ == "__main__":
    main()
