"""End-to-end driver (deliverable b): serve a small MoE model with batched
requests through the full coroutine runtime — two nodes, long-tail output
lengths, eviction under memory pressure, migration, straggler PARTITION —
and compare against disabling the coroutine features.  A sampled section
decodes per-sequence temperature/top-k/top-p/seed/stop through the fused
megastep and demonstrates seed reproducibility; an ONLINE section submits
new requests while a batch is mid-flight on the live event loop
(``sched.stream()``) and shows COMBINE absorbing them without restarting.

    PYTHONPATH=src python examples/batch_inference.py

JSONL mode runs the streaming job driver end to end on real NodeEngine
replicas: a batch-input file in, a merged input-order results file out,
journaled through a segment-rotated ledger in ``OUT.ledger/`` — kill it
mid-job and re-run the same command to resume (finished requests are
skipped; the merged output is byte-identical):

    PYTHONPATH=src python examples/batch_inference.py \\
        --jsonl requests.jsonl results.jsonl [--gen 200] [--replicas 2]
"""
import argparse
import os
import time

import numpy as np

from repro.configs import default_sampling, reduced_config
from repro.core.events import SeqFinishedEvent, TokenBlockEvent
from repro.core.scheduler import CoroutineScheduler, SchedulerConfig
from repro.runtime.engine import NodeEngine
from repro.sampling import SamplingParams


def longtail_lengths(rng, n, mean=12, sigma=1.0, cap=80):
    return np.minimum(np.maximum(
        rng.lognormal(np.log(mean), sigma, n).astype(int), 2), cap)


def run(enable_coroutines: bool):
    cfg = reduced_config("phi3_5_moe")
    rng = np.random.default_rng(1)
    engines = [NodeEngine(cfg, node_id=i, max_active=4, max_len=128,
                          page_size=16, seed=0) for i in range(2)]
    # OFF baseline: threshold -> 0+ means refill only when the node is
    # completely drained (static batch-at-a-time), no mid-flight COMBINE
    sc = SchedulerConfig(page_size=16,
                         refill_threshold=0.75 if enable_coroutines else 1e-9,
                         longtail_active=2 if enable_coroutines else 0,
                         migrate_imbalance=2 if enable_coroutines else 10**9)
    sched = CoroutineScheduler(engines, sc)
    prompts = [list(rng.integers(2, cfg.vocab_size, int(n)))
               for n in rng.integers(4, 12, 24)]
    outs = longtail_lengths(rng, 24)
    sched.submit(prompts, [int(o) for o in outs])
    t0 = time.monotonic()
    rep = sched.run(max_ticks=2000)
    wall = time.monotonic() - t0
    return rep, wall, engines


def run_sampled():
    """Mixed sampled workload: per-sequence decoding configs (the variety
    an elastic batch system must absorb) through the fused megastep —
    still one device->host transfer per decode page."""
    cfg = reduced_config("phi3_5_moe")
    rng = np.random.default_rng(2)
    prompts = [list(rng.integers(2, cfg.vocab_size, int(n)))
               for n in rng.integers(4, 12, 8)]
    sps = [
        default_sampling("phi3_5_moe", seed=11),          # model default
        SamplingParams(temperature=0.9, top_k=40, seed=12),
        SamplingParams(temperature=0.8, top_p=0.9, min_p=0.05, seed=13),
        SamplingParams(),                                 # greedy rider
        SamplingParams(temperature=1.2, repetition_penalty=1.3, seed=14),
        SamplingParams(temperature=0.7, stop=(7, 11), seed=15),
        SamplingParams(temperature=0.6, frequency_penalty=0.4, seed=16),
        SamplingParams(temperature=0.9, seed=17),
    ]

    def once():
        eng = NodeEngine(cfg, max_active=4, max_len=128, page_size=16,
                         seed=0)
        sched = CoroutineScheduler([eng], SchedulerConfig(page_size=16))
        ids = sched.submit(prompts, [24] * 8, sampling=sps)
        sched.run(max_ticks=2000)
        return [sched.cos[i] for i in ids], eng

    cos, eng = once()
    cos2, _ = once()
    assert all(a.generated == b.generated for a, b in zip(cos, cos2)), \
        "fixed seeds must reproduce identical sampled streams"
    print(f"[sampled      ] 8 seqs, per-seq configs, "
          f"d2h_transfers={eng.d2h_transfers} "
          f"(1/page + prefill), reproducible across runs: yes")
    for c in cos[:3]:
        print(f"  seq{c.seq_id}: T={c.sampling.temperature} "
              f"first tokens={c.generated[:6]} finish={c.finish_reason}")


def run_online():
    """Online mode: requests arrive WHILE a batch is in flight.  The
    event-driven loop is consumed through ``sched.stream()``; mid-stream
    ``submit()`` drops new sequences into the pool and the next round's
    REFILL event COMBINEs them into the running batch — no restart, no
    separate online engine."""
    cfg = reduced_config("phi3_5_moe")
    rng = np.random.default_rng(3)
    eng = NodeEngine(cfg, max_active=4, max_len=128, page_size=16, seed=0)
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=16))
    first = [list(rng.integers(2, cfg.vocab_size, 6)) for _ in range(4)]
    ids = sched.submit(first, [24] * 4)
    late_ids, finished_order = [], []
    for rec in sched.stream(max_ticks=2000):
        if isinstance(rec, SeqFinishedEvent):
            finished_order.append(rec.seq_id)
        if (not late_ids and isinstance(rec, TokenBlockEvent)
                and rec.offset > 0):
            # the batch is provably mid-flight: submit two more requests
            late = [list(rng.integers(2, cfg.vocab_size, 5))
                    for _ in range(2)]
            late_ids = sched.submit(late, [10, 10])
    assert late_ids and all(sched.cos[i].done for i in late_ids), \
        "mid-stream submissions must complete on the live loop"
    combines = eng.stats.counts["combine"]
    print(f"[online       ] {len(ids)} initial + {len(late_ids)} mid-stream "
          f"requests, all {sched.report()['completed']} completed; "
          f"combine={combines} finish order={finished_order}")


def run_jsonl(inp: str, out: str, *, gen: int = 0, replicas: int = 1,
              window: int = 64):
    """The documented file-in/file-out entry point: stream ``inp``
    through the elastic job driver on NodeEngine replicas (real JAX
    decode, reduced model) and merge results to ``out`` in input order."""
    from repro.data.pipeline import LongTailRequestStream
    from repro.driver import DriverConfig, StreamingJobDriver

    cfg = reduced_config("phi3_5_moe")
    if gen and not os.path.exists(inp):
        n = LongTailRequestStream(gen, seed=0, mean_in=8, mean_out=10,
                                  max_in_cap=48, max_out_cap=48,
                                  vocab=cfg.vocab_size).write_jsonl(inp)
        print(f"[jsonl] generated {n} long-tail requests -> {inp}")

    def factory(rid):       # one replica = two NodeEngine nodes
        return [NodeEngine(cfg, node_id=rid * 100 + i, max_active=4,
                           max_len=128, page_size=16, seed=0)
                for i in range(2)]

    drv = StreamingJobDriver(
        inp, out, out + ".ledger", factory,
        cfg=DriverConfig(window=window, replicas=replicas,
                         rotate_records=64),
        sched_cfg=SchedulerConfig(page_size=16))
    t0 = time.monotonic()
    res = drv.run()
    print(f"[jsonl] {res.status}: {res.merged_records} rows -> {out} "
          f"({time.monotonic() - t0:.1f}s wall)")
    print(f"[jsonl] computed={res.completed} resumed={res.skipped_resume} "
          f"peak_resident={res.peak_resident}/{window} "
          f"segments={res.report['ledger']['sealed_segments']} sealed")
    return res


def main():
    rep, wall, engines = run(enable_coroutines=True)
    print(f"[coroutine ON ] BCT={wall:6.2f}s completed={rep['completed']}/"
          f"{rep['total']} decode_steps={sum(e.decode_steps for e in engines)}")
    for i, e in enumerate(engines):
        print(f"  node{i}: primitives={e.stats.counts} "
              f"host_store={e.host_store.nbytes()/2**20:.1f}MiB "
              f"d2h_transfers={e.d2h_transfers} (fused: ~2/page)")
    print(f"  events: {rep['log_tail']}")
    rep2, wall2, engines2 = run(enable_coroutines=False)
    print(f"[coroutine OFF] BCT={wall2:6.2f}s completed={rep2['completed']}/"
          f"{rep2['total']} decode_steps={sum(e.decode_steps for e in engines2)}")
    print(f"-> coroutine scheduling used "
          f"{sum(e.decode_steps for e in engines)} vs "
          f"{sum(e.decode_steps for e in engines2)} decode steps "
          f"(refill keeps slots full; fewer wasted lockstep steps)")
    run_sampled()
    run_online()


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jsonl", nargs=2, metavar=("IN", "OUT"),
                    help="run the streaming job driver: IN.jsonl -> OUT")
    ap.add_argument("--gen", type=int, default=0,
                    help="generate IN with this many synthetic requests "
                         "if it does not exist")
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--window", type=int, default=64)
    args = ap.parse_args()
    if args.jsonl:
        run_jsonl(args.jsonl[0], args.jsonl[1], gen=args.gen,
                  replicas=args.replicas, window=args.window)
    else:
        main()
