"""End-to-end driver (deliverable b): serve a small MoE model with batched
requests through the full coroutine runtime — two nodes, long-tail output
lengths, eviction under memory pressure, migration, straggler PARTITION —
and compare against disabling the coroutine features.

    PYTHONPATH=src python examples/batch_inference.py
"""
import time

import numpy as np

from repro.configs import reduced_config
from repro.core.scheduler import CoroutineScheduler, SchedulerConfig
from repro.runtime.engine import NodeEngine


def longtail_lengths(rng, n, mean=12, sigma=1.0, cap=80):
    return np.minimum(np.maximum(
        rng.lognormal(np.log(mean), sigma, n).astype(int), 2), cap)


def run(enable_coroutines: bool):
    cfg = reduced_config("phi3_5_moe")
    rng = np.random.default_rng(1)
    engines = [NodeEngine(cfg, node_id=i, max_active=4, max_len=128,
                          page_size=16, seed=0) for i in range(2)]
    # OFF baseline: threshold -> 0+ means refill only when the node is
    # completely drained (static batch-at-a-time), no mid-flight COMBINE
    sc = SchedulerConfig(page_size=16,
                         refill_threshold=0.75 if enable_coroutines else 1e-9,
                         longtail_active=2 if enable_coroutines else 0,
                         migrate_imbalance=2 if enable_coroutines else 10**9)
    sched = CoroutineScheduler(engines, sc)
    prompts = [list(rng.integers(2, cfg.vocab_size, int(n)))
               for n in rng.integers(4, 12, 24)]
    outs = longtail_lengths(rng, 24)
    sched.submit(prompts, [int(o) for o in outs])
    t0 = time.monotonic()
    rep = sched.run(max_ticks=2000)
    wall = time.monotonic() - t0
    return rep, wall, engines


def main():
    rep, wall, engines = run(enable_coroutines=True)
    print(f"[coroutine ON ] BCT={wall:6.2f}s completed={rep['completed']}/"
          f"{rep['total']} decode_steps={sum(e.decode_steps for e in engines)}")
    for i, e in enumerate(engines):
        print(f"  node{i}: primitives={e.stats.counts} "
              f"host_store={e.host_store.nbytes()/2**20:.1f}MiB "
              f"d2h_transfers={e.d2h_transfers} (fused: ~2/page)")
    print(f"  events: {rep['log_tail']}")
    rep2, wall2, engines2 = run(enable_coroutines=False)
    print(f"[coroutine OFF] BCT={wall2:6.2f}s completed={rep2['completed']}/"
          f"{rep2['total']} decode_steps={sum(e.decode_steps for e in engines2)}")
    print(f"-> coroutine scheduling used "
          f"{sum(e.decode_steps for e in engines)} vs "
          f"{sum(e.decode_steps for e in engines2)} decode steps "
          f"(refill keeps slots full; fewer wasted lockstep steps)")


if __name__ == "__main__":
    main()
