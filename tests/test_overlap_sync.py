"""Pipelined host-KV DMA (stage_appends / drain_appends / SYNC_DRAIN).

The two-stage sync pipeline must be TRANSPARENT: identical generated
tokens and bitwise-identical host-store contents vs the blocking sync it
replaces, while actually overlapping — the gather is issued before the
next megastep and the blob materializes after it.  Every consumer of
host-store state (evict, migrate, failure recovery) forces a drain
first, and the ring-buffer gate degrades to synchronous sync when the
staged bytes would overflow it.
"""
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core import primitives as prim
from repro.core.coroutine import SequenceCoroutine, Status
from repro.core.events import EventKind
from repro.core.scheduler import CoroutineScheduler, SchedulerConfig
from repro.runtime.engine import NodeEngine


def _mk_engine(cfg, overlap, **kw):
    kw.setdefault("max_active", 3)
    kw.setdefault("max_len", 128)
    kw.setdefault("page_size", 8)
    return NodeEngine(cfg, seed=0, overlap=overlap, **kw)


def _mk_cos(prompts, max_out):
    return [SequenceCoroutine(seq_id=i, prompt=list(p), max_out=mo)
            for i, (p, mo) in enumerate(zip(prompts, max_out))]


def _host_state(store):
    """Materialized host-store contents for bitwise comparison."""
    out = {}
    for sid, st in sorted(store.seqs.items()):
        out[sid] = (st.length,
                    {k: [np.asarray(p) for p in ps]
                     for k, ps in sorted(st.pages.items())},
                    {k: np.asarray(v) for k, v in sorted(st.whole.items())})
    return out


def _assert_same_host_state(a, b):
    assert set(a) == set(b)
    for sid in a:
        la, pa, wa = a[sid]
        lb, pb, wb = b[sid]
        assert la == lb, f"seq {sid}: length {la} != {lb}"
        assert set(pa) == set(pb) and set(wa) == set(wb)
        for k in pa:
            assert len(pa[k]) == len(pb[k]), (sid, k)
            for i, (x, y) in enumerate(zip(pa[k], pb[k])):
                assert np.array_equal(x, y), f"seq {sid} leaf {k} page {i}"
        for k in wa:
            assert np.array_equal(wa[k], wb[k]), (sid, k)


def _drive_direct(eng, prompts, max_out, pages, P=8):
    """Engine-level page loop: pipelined engines stage + drain(keep=1)
    like the scheduler's SYNC/SYNC_DRAIN phases; blocking engines pay
    sync_appends.  Fully drained at the end so host stores compare."""
    cos = _mk_cos(prompts, max_out)
    eng.prefill(cos)
    prim.combine(cos, eng)
    for _ in range(pages):
        active = [c for c in cos if c.remaining > 0]
        if not active:
            break
        eng.decode_page(active, P)
        if eng.overlap:
            eng.stage_appends(active)
            eng.drain_appends(keep_newest=1)
        else:
            eng.sync_appends(active)
    eng.drain_appends()
    return cos


@pytest.mark.parametrize("arch", ["llama3_2_1b", "phi3_5_moe"])
def test_token_and_host_store_parity(arch, rng):
    """Pipelined vs blocking sync: identical tokens AND bitwise-identical
    host-store pages, including ragged finishes mid-page."""
    cfg = reduced_config(arch)
    prompts = [list(rng.integers(2, cfg.vocab_size, int(n)))
               for n in rng.integers(4, 12, 3)]
    max_out = [21, 9, 14]
    eng_o = _mk_engine(cfg, True)
    cos_o = _drive_direct(eng_o, prompts, max_out, pages=4)
    eng_b = _mk_engine(cfg, False)
    cos_b = _drive_direct(eng_b, prompts, max_out, pages=4)
    assert [c.generated for c in cos_o] == [c.generated for c in cos_b]
    assert eng_o.sync_stages > 0, "pipelined path never staged"
    _assert_same_host_state(_host_state(eng_o.host_store),
                            _host_state(eng_b.host_store))


def test_scheduler_parity_with_combine_and_yield(rng):
    """Full scheduler runs (eviction pressure -> yield/combine round
    trips) produce identical token streams with overlap on and off."""
    cfg = reduced_config("llama3_2_1b")
    prompts = [list(rng.integers(2, cfg.vocab_size, int(n)))
               for n in rng.integers(4, 12, 7)]
    max_out = [12, 5, 9, 20, 7, 3, 16]

    def run(overlap):
        eng = _mk_engine(cfg, overlap)
        sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8))
        ids = sched.submit(prompts, max_out)
        rep = sched.run(max_ticks=500)
        assert rep["completed"] == len(prompts)
        return [sched.cos[i].generated for i in ids]

    assert run(True) == run(False)


def test_gather_issued_before_next_megastep_drained_after():
    """Transfer-spy: in the scheduler's steady state, page N's blob is
    STAGED before page N+1's megastep is dispatched and MATERIALIZED
    after it — the §5.2/§5.3 compute/transfer overlap."""
    cfg = reduced_config("llama3_2_1b")
    eng = _mk_engine(cfg, True)
    trace = []
    orig_decode = eng.decode_page
    orig_stage = eng.stage_appends
    orig_mat = eng._materialize

    def spy_decode(active, P):
        trace.append(("decode", None))
        return orig_decode(active, P)

    def spy_stage(active):
        orig_stage(active)
        if eng._inflight:
            trace.append(("stage", eng._inflight[-1].name))

    def spy_mat(ent):
        trace.append(("drain", ent.name))
        return orig_mat(ent)

    eng.decode_page = spy_decode
    eng.stage_appends = spy_stage
    eng._materialize = spy_mat
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8))
    sched.submit([[2, 3, 4, 5]] * 3, [24] * 3)
    rep = sched.run(max_ticks=300)
    assert rep["completed"] == 3

    decode_i = [i for i, (kind, _) in enumerate(trace) if kind == "decode"]
    assert len(decode_i) >= 3
    staged = {name: i for i, (kind, name) in enumerate(trace)
              if kind == "stage"}
    drained = {name: i for i, (kind, name) in enumerate(trace)
               if kind == "drain"}
    assert set(staged) == set(drained) and staged, trace
    hidden = 0
    for name, si in staged.items():
        di = drained[name]
        assert si < di, f"{name} drained before staged"
        # a blob is hidden when a megastep was dispatched between its
        # stage and its drain
        if any(si < d < di for d in decode_i):
            hidden += 1
    # every blob except the final page's (force-drained when the batch
    # finishes) must ride behind the next megastep
    assert hidden >= len(staged) - 1, trace


def test_drain_lands_before_evict_and_migrate():
    """Any host_store.drop (eviction or migration source) must observe an
    EMPTY in-flight pipeline — a staged window may never be outrun by a
    consumer of host-store state."""
    cfg = reduced_config("llama3_2_1b")
    engs = [_mk_engine(cfg, True, node_id=0), _mk_engine(cfg, True, node_id=1)]
    for eng in engs:
        orig_drop = eng.host_store.drop

        def spy_drop(seq_id, _eng=eng, _orig=orig_drop):
            assert not _eng._inflight, \
                f"drop(seq {seq_id}) with {len(_eng._inflight)} in flight"
            return _orig(seq_id)
        eng.host_store.drop = spy_drop
    sched = CoroutineScheduler(engs, SchedulerConfig(page_size=8))
    ids = sched.submit([[2, 3, 4, 5]] * 8, [10, 4, 16, 7, 12, 5, 9, 14])
    # skew the load so the MIGRATE handler actually fires
    for i in ids:
        sched.cos[i].node = 0
    rep = sched.run(max_ticks=500)
    assert rep["completed"] == 8
    assert any("migrate" in line for line in sched.log), sched.log


def test_node_failure_with_inflight_blob_parity():
    """NODE_FAILURE while a staged blob is in flight: the blob lands
    before recovery consumes host-store state, and the recovered batch
    finishes with the exact tokens of an identically-timed blocking
    run."""
    cfg = reduced_config("llama3_2_1b")

    def run(overlap):
        engs = [_mk_engine(cfg, overlap, node_id=0, max_active=2),
                _mk_engine(cfg, overlap, node_id=1, max_active=2)]
        sched = CoroutineScheduler(engs, SchedulerConfig(page_size=8))
        ids = sched.submit([[2, 3, 4, 5]] * 4, [24] * 4)
        for _ in range(2):
            sched.step()
        if overlap:
            # the failure must race a genuinely in-flight blob
            assert engs[0]._inflight, "no in-flight blob at failure time"
        sched.queue.push(EventKind.NODE_FAILURE, node=0)
        rep = sched.run(max_ticks=500)
        assert rep["completed"] == 4
        return [sched.cos[i].generated for i in ids]

    assert run(True) == run(False)


def test_ring_backpressure_falls_back_to_blocking(rng):
    """A ring buffer too small for even one blob: every stage degrades to
    the synchronous path (stall counted) and parity still holds."""
    cfg = reduced_config("llama3_2_1b")
    prompts = [list(rng.integers(2, cfg.vocab_size, int(n)))
               for n in rng.integers(4, 10, 3)]
    max_out = [18, 11, 14]
    eng_t = _mk_engine(cfg, True, ring_buffer_bytes=1)
    cos_t = _drive_direct(eng_t, prompts, max_out, pages=4)
    eng_b = _mk_engine(cfg, False)
    cos_b = _drive_direct(eng_b, prompts, max_out, pages=4)
    assert eng_t.sync_stalls > 0, "tiny ring never stalled"
    assert eng_t.sync_stages == 0, "tiny ring should not admit a blob"
    assert [c.generated for c in cos_t] == [c.generated for c in cos_b]
    _assert_same_host_state(_host_state(eng_t.host_store),
                            _host_state(eng_b.host_store))


def test_sim_engine_stage_drain_protocol():
    """SimEngine conforms: stage/drain cost accounting, hidden-transfer
    discount when a decode overlaps the staged blob, and the plan's
    ring_buffer_bytes gating staged bytes."""
    from repro.core import plan as plan_lib
    from repro.runtime.cluster import SimEngine, fixed_workload

    cfg = reduced_config("llama3_2_1b")
    hw = plan_lib.Hardware()
    eng = SimEngine(cfg, hw, max_active=4, max_len=512, page_size=64)
    wl = fixed_workload(4, 16, 128)
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=64))
    sched.submit(wl.prompts, wl.max_out)
    rep = sched.run(max_ticks=2000)
    assert rep["completed"] == 4
    assert not eng._staged, "sim pipeline left blobs in flight"

    # forced stall: gate capacity below one page's staged bytes
    tiny_plan = plan_lib.Plan(b_attn=4, b_moe=4, offload_kv=False,
                              offload_params=False, ring_buffer_bytes=1,
                              layer_time_s=1e-4)
    eng2 = SimEngine(cfg, hw, max_active=4, max_len=512, page_size=64,
                     plan=tiny_plan)
    cos = _mk_cos(wl.prompts[:2], [64, 64])
    eng2.prefill(cos)
    prim.combine(cos, eng2)
    for _ in range(3):
        eng2.decode_page(cos, 64)
        eng2.stage_appends(cos)
        eng2.drain_appends(keep_newest=1)
    assert eng2.sync_stalls > 0
