"""Runtime tests: batch API, checkpoint/cold-start, failure recovery,
cluster simulation + elasticity, job ledger, plan optimizer."""
import json
import os

import numpy as np
import jax
import pytest

from repro.configs import get_config, reduced_config
from repro.core import plan as plan_lib
from repro.core.scheduler import CoroutineScheduler, SchedulerConfig
from repro.models import transformer as T
from repro.runtime import checkpoint as ckpt
from repro.runtime.api import BatchMaster, BatchRequest
from repro.runtime.cluster import (Cluster, SimEngine, fixed_workload,
                                   longtail_workload, run_static_baseline)
from repro.runtime.engine import NodeEngine
from repro.runtime.failure import HealthMonitor, Heartbeat, DeviceStatus, \
    recovery_choice
from repro.runtime.ledger import (JobLedger, LedgerError,
                                  SegmentedJobLedger, run_resumable)


def test_batch_api_order_and_completion(rng):
    cfg = reduced_config("llama3_2_1b")
    eng = NodeEngine(cfg, max_active=3, max_len=64, page_size=8)
    master = BatchMaster([eng], SchedulerConfig(page_size=8))
    reqs = [BatchRequest(custom_id=f"r{i}",
                         prompt=list(rng.integers(2, 100, 5)),
                         max_tokens=int(rng.integers(2, 8)))
            for i in range(5)]
    bid = master.submit(reqs)
    bo = master.run(bid)
    assert bo.status == "completed"
    assert [r["custom_id"] for r in bo.results] == [f"r{i}" for i in range(5)]
    assert bo.request_counts["completed"] == 5
    assert all(len(r["response"]["tokens"]) == reqs[i].max_tokens
               for i, r in enumerate(bo.results))


def test_checkpoint_roundtrip(tmp_path, rng):
    cfg = reduced_config("qwen2_0_5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path / "c1"), params, extra={"step": 7})
    flat, extra = ckpt.restore(str(tmp_path / "c1"), mmap=True)
    assert extra["step"] == 7
    restored = ckpt.unflatten_into(params, flat)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pool_snapshot_restart(tmp_path, rng):
    cfg = reduced_config("llama3_2_1b")
    eng = NodeEngine(cfg, max_active=2, max_len=64, page_size=8)
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8))
    sched.submit([[2, 3, 4]] * 4, [6] * 4)
    # run a few ticks only (mid-batch), snapshot, restart fresh engine
    for _ in range(2):
        sched._node_tick(0, eng)
    ckpt.snapshot_pool(str(tmp_path / "pool"), sched)
    eng2 = NodeEngine(cfg, max_active=2, max_len=64, page_size=8)
    sched2 = CoroutineScheduler([eng2], SchedulerConfig(page_size=8))
    n = ckpt.restore_pool(str(tmp_path / "pool"), sched2)
    assert n == 4
    rep = sched2.run(max_ticks=300)
    assert rep["completed"] == 4


def test_health_monitor_detects_failure():
    hm = HealthMonitor(nodes=3, interval_s=1.0, dead_after=3)
    failures = []
    hm.on_failure = failures.append
    for t in range(10):
        for n in range(3):
            if n == 1 and t >= 2:
                continue       # node 1 stops heartbeating at t=2
            hm.report(Heartbeat(n, float(t), [DeviceStatus(0)]))
    assert failures == [1]
    assert hm.alive() == [0, 2]


def test_health_monitor_first_report_seeds_origin():
    """Regression: ``last_ok`` used to default to 0.0, so the first real
    wall-clock heartbeat (t >> 0) instantly declared every OTHER node
    stale-dead.  The first observation must seed a common origin."""
    hm = HealthMonitor(nodes=3, interval_s=1.0, dead_after=3)
    failures = []
    hm.on_failure = failures.append
    hm.report(Heartbeat(0, 1_000_000.0, [DeviceStatus(0)]))
    assert failures == [], "peers must not die on the first report"
    hm.report(Heartbeat(0, 1_000_002.9, [DeviceStatus(0)]))
    assert failures == []
    # now nodes 1/2 really are stale relative to the common origin
    hm.report(Heartbeat(0, 1_000_003.5, [DeviceStatus(0)]))
    assert sorted(failures) == [1, 2]
    assert hm.alive() == [0]


def test_cluster_failure_recovery():
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    cl = Cluster(cfg, hw, nodes=4, max_active=32, max_len=8192)
    wl = fixed_workload(64, 512, 256)
    cl.sched.submit(wl.prompts, wl.max_out)
    for _ in range(3):
        for node, eng in enumerate(cl.sched.engines):
            cl.sched._node_tick(node, eng)
    r = cl.fail_node(1)
    assert r["migrated"] + r["recomputed"] > 0
    # fail_node routes through the event-loop NODE_FAILURE handler — the
    # single §5.6 recovery path — so the monitor and report reflect it
    assert not cl.sched.health.failed.get(0) and cl.sched.health.failed[1]
    rep = cl.sched.run(max_ticks=50000)
    assert rep["completed"] == 64, "all sequences survive a node failure"
    assert rep["robustness"]["failed_nodes"] == [1]


def test_cluster_drain_node_graceful_handoff():
    """NODE_DRAIN (elastic scale-down) checkpoints + migrates every live
    sequence to a survivor — zero recompute, unlike NODE_FAILURE — and
    retires the node from rotation."""
    cfg = get_config("qwen3_moe_30b")
    cl = Cluster(cfg, plan_lib.Hardware(), nodes=2, max_active=32,
                 max_len=8192)
    wl = fixed_workload(24, 256, 2048)      # long enough to be mid-flight
    cl.sched.submit(wl.prompts, wl.max_out)
    for node, eng in enumerate(cl.sched.engines):
        cl.sched._node_tick(node, eng)
    r = cl.drain_node(1)
    assert r["drained"] and r["migrated"] > 0
    assert len(cl.sched.engines) == 1
    rep = cl.sched.run(max_ticks=50000)
    assert rep["completed"] == 24, "drain loses zero sequences"
    assert rep["robustness"]["drained_nodes"] == [1]
    assert not cl.sched.health.failed.get(1), \
        "a drained node is retired, not failed"
    # no survivor: the drain must refuse rather than strand the work
    cl2 = Cluster(cfg, plan_lib.Hardware(), nodes=1, max_active=32,
                  max_len=8192)
    cl2.sched.submit(wl.prompts[:4], [8] * 4)
    r2 = cl2.drain_node(0)
    assert not r2["drained"] and len(cl2.sched.engines) == 1


def test_cluster_elastic_scale_up():
    cfg = get_config("qwen3_moe_30b")
    cl = Cluster(cfg, plan_lib.Hardware(), nodes=2, max_active=32,
                 max_len=8192)
    wl = fixed_workload(48, 256, 128)
    cl.sched.submit(wl.prompts, wl.max_out)
    cl.add_node()
    rep = cl.sched.run(max_ticks=50000)
    assert rep["completed"] == 48
    assert len(cl.sched.engines) == 3


def test_checkpoint_restore_detects_corruption(tmp_path):
    cfg = reduced_config("qwen2_0_5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path / "c"), params)
    with open(str(tmp_path / "c" / "manifest.json")) as f:
        name, info = next(iter(json.load(f)["manifest"].items()))
    victim = str(tmp_path / "c" / info["file"])
    with open(victim, "r+b") as f:
        f.truncate(os.path.getsize(victim) // 2)
    with pytest.raises(ValueError, match=name.split("/")[0]):
        ckpt.restore(str(tmp_path / "c"))


# ---------------------------------------------------------------------------
# crash-resumable job ledger
# ---------------------------------------------------------------------------


def _ledger_master():
    cfg = reduced_config("llama3_2_1b")
    eng = NodeEngine(cfg, max_active=3, max_len=64, page_size=8, seed=0)
    return BatchMaster([eng], SchedulerConfig(page_size=8))


def _ledger_reqs(rng, n=6):
    return [BatchRequest(custom_id=f"r{i}",
                         prompt=list(rng.integers(2, 100, 5)),
                         max_tokens=6) for i in range(n)]


def test_job_ledger_exactly_once(tmp_path):
    p = str(tmp_path / "led.jsonl")
    led = JobLedger(p).open()
    led.record_submitted(["a", "b"])
    assert led.record_output("a", {"v": 1})
    assert not led.record_output("a", {"v": 2}), "duplicate must be refused"
    led.close()
    led2 = JobLedger(p).open()
    assert led2.finished == {"a": {"v": 1}}, "first write wins"
    assert led2.pending(["a", "b"]) == ["b"]
    led2.close()


def test_job_ledger_truncates_torn_trailing_line(tmp_path):
    p = str(tmp_path / "led.jsonl")
    led = JobLedger(p).open()
    led.record_output("a", {"v": 1})
    led.close()
    with open(p, "a") as f:        # SIGKILL mid-write: no trailing newline
        f.write('{"kind": "output", "custom_id": "b", "ro')
    led2 = JobLedger(p).open()
    assert led2.finished == {"a": {"v": 1}} and led2.torn_records == 1
    led2.record_output("c", {"v": 3})     # append lands on a clean line
    led2.close()
    led3 = JobLedger(p).open()
    assert set(led3.finished) == {"a", "c"}
    led3.close()


def test_job_ledger_resume_skips_finished(tmp_path, rng):
    """Kill-and-resume protocol, in process: a ledger holding the first 3
    committed rows of a 6-request batch resumes to the same bytes as the
    uninterrupted run, recomputing only the 3 unfinished requests."""
    reqs = _ledger_reqs(rng)
    full = run_resumable(_ledger_master(), reqs,
                         str(tmp_path / "full.jsonl"))
    assert full.resumed == 0 and full.computed == 6 and len(full.rows) == 6
    # craft the post-crash ledger: manifest + first 3 output records
    kept, dropped = 0, 0
    with open(str(tmp_path / "full.jsonl")) as f, \
            open(str(tmp_path / "crash.jsonl"), "w") as g:
        for line in f:
            if json.loads(line).get("kind") == "output":
                if kept >= 3:
                    dropped += 1
                    continue
                kept += 1
            g.write(line)
    assert kept == 3 and dropped == 3
    res = run_resumable(_ledger_master(), reqs,
                        str(tmp_path / "crash.jsonl"))
    assert res.resumed == 3 and res.computed == 3
    assert res.rows == full.rows, \
        "resumed output must equal the uninterrupted run"
    again = run_resumable(_ledger_master(), reqs,
                          str(tmp_path / "crash.jsonl"))
    assert again.resumed == 6 and again.computed == 0, \
        "a completed ledger is a no-op resume (zero recompute)"
    assert again.rows == full.rows


def test_job_ledger_rejects_duplicate_custom_ids(tmp_path, rng):
    reqs = _ledger_reqs(rng, 2)
    reqs[1].custom_id = reqs[0].custom_id
    with pytest.raises(LedgerError, match="duplicate custom_id"):
        run_resumable(_ledger_master(), reqs, str(tmp_path / "led.jsonl"))


# ---------------------------------------------------------------------------
# segmented ledger (chunked rotation, O(tail) resume)
# ---------------------------------------------------------------------------


def _seg_led(tmp_path, **kw):
    kw.setdefault("rotate_records", 4)
    kw.setdefault("fsync_every", 1)
    return SegmentedJobLedger(str(tmp_path / "led"), **kw)


def test_segmented_ledger_rotation_boundary_exact(tmp_path):
    led = _seg_led(tmp_path).open()
    for i in range(10):
        assert led.record_output(f"r{i}", {"v": i})
    # 10 rows at rotate_records=4 -> exactly 2 sealed segments + 2 live
    assert led.sealed_segments == 2 and led.live_segment == 2
    root = led.root
    led.close()
    for k, nrec in ((0, 4), (1, 4), (2, 2)):
        with open(os.path.join(root, f"seg-{k:08d}.jsonl")) as f:
            assert len(f.read().splitlines()) == nrec
    led2 = _seg_led(tmp_path).open()
    assert len(led2) == 10 and led2.sealed_segments == 2
    assert led2.replayed_segments == 1, "index resume parses only the tail"
    assert all(led2.read_row(f"r{i}") == {"v": i} for i in range(10)), \
        "locator reads must work for sealed AND live rows"
    led2.close()


def test_segmented_ledger_torn_line_newest_segment_only(tmp_path):
    led = _seg_led(tmp_path).open()
    for i in range(6):
        led.record_output(f"r{i}", {"v": i})    # seg0 sealed, seg1 live(2)
    led.close()
    sealed = os.path.join(led.root, "seg-00000000.jsonl")
    live = os.path.join(led.root, "seg-00000001.jsonl")
    # SIGKILL mid-write tears the LIVE tail; sealed files are never
    # re-read (index locators own them), so garbage there must survive
    # reopen untouched — proof the resume is O(tail), not O(job)
    with open(live, "a") as f:
        f.write('{"kind": "output", "custom_id": "r9", "ro')
    with open(sealed, "a") as f:
        f.write("SEALED-FILE-GARBAGE")
    led2 = _seg_led(tmp_path).open()
    assert led2.torn_records == 1 and len(led2) == 6
    assert open(sealed).read().endswith("SEALED-FILE-GARBAGE"), \
        "reopen must not touch sealed segments"
    assert not open(live, "rb").read().endswith(b"ro"), \
        "torn live tail must be truncated on disk"
    assert led2.read_row("r5") == {"v": 5}
    led2.close()


def test_segmented_ledger_sigkill_resume_across_boundary(tmp_path):
    """Real SIGKILL between rotations: every row sealed before the crash
    is durable (seals fsync) and a fresh process resumes with zero
    recompute of sealed rows, replaying only the tail segment."""
    import subprocess
    import sys as _sys
    prog = (
        "import os, signal, sys\n"
        "sys.path.insert(0, %r)\n"
        "from repro.runtime.ledger import SegmentedJobLedger\n"
        "led = SegmentedJobLedger(sys.argv[1], rotate_records=4,\n"
        "                         fsync_every=1000)\n"
        "led.open()\n"
        "for i in range(11):\n"
        "    led.record_output(f'r{i}', {'v': i})\n"
        "os.kill(os.getpid(), signal.SIGKILL)\n"
        % os.path.join(os.path.dirname(__file__), "..", "src"))
    root = str(tmp_path / "led")
    p = subprocess.run([_sys.executable, "-c", prog, root],
                       capture_output=True)
    assert p.returncode == -9, p.stderr.decode()[-1000:]
    led = SegmentedJobLedger(root, rotate_records=4).open()
    # rows r0..r7 crossed two seal boundaries -> durable despite the huge
    # fsync_every (seals always fsync); r8..r10 were unsynced tail rows
    # and may or may not have landed — they are allowed to re-run
    assert led.sealed_segments == 2 and led.replayed_segments <= 1
    assert all(led.has(f"r{i}") for i in range(8)), \
        "sealed rows must never recompute"
    assert led.pending([f"r{i}" for i in range(8)]) == []
    led.close()


def test_segmented_ledger_duplicate_first_wins_across_segments(tmp_path):
    led = _seg_led(tmp_path).open()
    for i in range(5):
        led.record_output(f"r{i}", {"v": i})    # r0..r3 sealed, r4 live
    assert not led.record_output("r0", {"v": 999}), "in-memory refusal"
    assert led.duplicates_refused == 1
    led.close()
    # a crashed run's requeue race can append a duplicate to a LATER
    # segment; replay must keep the first committed row
    live = os.path.join(led.root, "seg-00000001.jsonl")
    with open(live, "a") as f:
        f.write(json.dumps({"kind": "output", "custom_id": "r0",
                            "row": {"v": 777}}) + "\n")
    led2 = _seg_led(tmp_path).open()
    assert led2.duplicates_refused == 1, "replay refuses the late copy"
    assert led2.read_row("r0") == {"v": 0}, "first write wins"
    assert len(led2) == 5
    led2.close()


def test_recovery_choice_crossover():
    cfg = get_config("llama3_2_1b")
    hw = plan_lib.Hardware()
    slow = recovery_choice(cfg, hw, kv_len=8192, prompt_len=8192,
                           inter_node_bw=0.05e9)
    fast = recovery_choice(cfg, hw, kv_len=8192, prompt_len=8192,
                           inter_node_bw=200e9)
    assert slow == "recompute"   # congested link: regenerate is faster
    assert fast == "migrate"     # fast link: move the KV snapshot


def test_plan_search_prefers_combine_for_moe():
    """The §5.4 search must pick B_moe >> B_attn-level batches for sparse
    models (the paper's core claim) and B_attn <= B_moe."""
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    plan = plan_lib.search_plan(cfg, hw, ctx=8192, new_tokens=1,
                                max_active=512)
    assert plan.b_moe == 512
    assert plan.b_attn <= plan.b_moe
    # per-token layer time must improve with combined batch size
    t_small = plan_lib.step_time(cfg, hw, plan, 8, 8192, 1) / 8
    t_big = plan_lib.step_time(cfg, hw, plan, 512, 8192, 1) / 512
    assert t_big < t_small / 4, "expert batching must amortize weight reads"


def test_dag_critical_path():
    d = plan_lib.DAG()
    d.add("a", 1.0)
    d.add("b", 2.0, ["a"])
    d.add("c", 0.5, ["a"])
    d.add("d", 1.0, ["b", "c"])
    t, path = d.critical_path()
    assert t == 4.0 and path == ["a", "b", "d"]


def test_coroutine_beats_static_on_longtail():
    """Headline reproduction: coroutine scheduling reduces BCT vs static
    binding on a long-tail workload (paper Table 5 direction)."""
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    wl = longtail_workload(256, mean_in=1024, mean_out=1024, sigma=1.2,
                           seed=3)
    cl = Cluster(cfg, hw, nodes=4, max_active=64, max_len=16384)
    rep = cl.run(wl)
    base = run_static_baseline(cfg, hw, wl, nodes=4)
    assert rep["completed"] == wl.n
    assert rep["bct_s"] < base["bct_s"], \
        f"coroutine {rep['bct_s']:.0f}s !< static {base['bct_s']:.0f}s"
