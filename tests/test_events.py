"""Event-driven runtime API: queue priority dispatch, policy-table
completeness, the formal ExecutionBackend contract, streaming-vs-run()
parity on both backends, and the on-device logprob plane (one transfer
per page, extended from the test_decode_fused spy pattern)."""
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core import plan as plan_lib
from repro.core.backend import ExecutionBackend, validate_backend
from repro.core.events import (EventKind, EventQueue, SeqFinishedEvent,
                               TokenBlockEvent)
from repro.core.scheduler import (CoroutineScheduler, SchedulerConfig,
                                  SchedulerPolicy)
from repro.runtime.api import BatchMaster, BatchRequest
from repro.runtime.cluster import SimEngine
from repro.runtime.engine import NodeEngine
from repro.sampling import SamplingParams


# ---------------------------------------------------------------------------
# event queue + policy table
# ---------------------------------------------------------------------------


def test_event_queue_priority_order_under_contention():
    """SYNC (correctness) pops before REFILL (utilization) pops before
    MIGRATE (opportunistic), regardless of push order; equal priority is
    FIFO."""
    q = EventQueue()
    q.push(EventKind.MIGRATE)
    q.push(EventKind.REFILL, node=1)
    q.push(EventKind.SYNC)
    q.push(EventKind.REFILL, node=2)
    popped = [q.pop() for _ in range(4)]
    assert [e.kind for e in popped] == [EventKind.SYNC, EventKind.REFILL,
                                        EventKind.REFILL, EventKind.MIGRATE]
    assert [e.node for e in popped if e.kind == EventKind.REFILL] == [1, 2]
    assert q.pop() is None


def test_scheduler_drains_queue_in_priority_order():
    """One scheduler round on a live node dispatches refill -> decode ->
    sync -> sync_drain -> evict -> extend -> refill -> longtail, sequenced
    purely by the queue's EventKind priorities (no inline phase calls)."""
    order = []

    def wrap(label, fn):
        def h(sched, ev):
            order.append(label)
            fn(sched, ev)
        return h

    base = SchedulerPolicy()
    pol = SchedulerPolicy(
        sync=wrap("sync", base.sync),
        sync_drain=wrap("sync_drain", base.sync_drain),
        seq_done=wrap("seq_done", base.seq_done),
        page_boundary=wrap("page_boundary", base.page_boundary),
        module_ready=wrap("module_ready", base.module_ready),
        refill=wrap("refill", base.refill),
        long_tail=wrap("long_tail", base.long_tail),
        migrate=wrap("migrate", base.migrate),
        node_failure=wrap("node_failure", base.node_failure))
    cfg = reduced_config("llama3_2_1b")
    eng = NodeEngine(cfg, max_active=2, max_len=64, page_size=8, seed=0)
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8),
                               policy=pol)
    sched.submit([[2, 3, 4]] * 2, [10] * 2)
    sched.step()
    assert order == ["refill", "module_ready", "sync", "sync_drain",
                     "seq_done", "page_boundary", "refill", "long_tail"]


def test_every_eventkind_has_a_default_handler():
    """No orphan event kinds: the default policy registers a handler for
    every EventKind member (the queue was dead code before this table)."""
    table = SchedulerPolicy().table()
    assert set(table) == set(EventKind)
    assert all(callable(h) for h in table.values())


# ---------------------------------------------------------------------------
# ExecutionBackend contract
# ---------------------------------------------------------------------------


def test_validate_backend_rejects_missing_members():
    class Bogus:
        node_id = 0
        max_active = 1

        def clock(self):
            return 0.0

    with pytest.raises(TypeError, match="decode_page"):
        validate_backend(Bogus())
    with pytest.raises(TypeError):
        CoroutineScheduler([Bogus()])


def test_engines_conform_to_backend_protocol():
    cfg = reduced_config("llama3_2_1b")
    eng = NodeEngine(cfg, max_active=2, max_len=64, page_size=8)
    assert validate_backend(eng) is eng
    assert isinstance(eng, ExecutionBackend)
    sim = SimEngine(get_config("llama3_2_1b"), plan_lib.Hardware(),
                    max_active=8, max_len=1024)
    assert validate_backend(sim) is sim
    assert isinstance(sim, ExecutionBackend)


# ---------------------------------------------------------------------------
# streaming-vs-run() parity
# ---------------------------------------------------------------------------


def _assert_stream_matches_run(make_sched, submit):
    """Two identical schedulers: run() on one, stream() on the other; the
    concatenated TokenBlockEvents must reproduce run()'s tokens exactly,
    with contiguous offsets and one SeqFinishedEvent per sequence."""
    s_run = make_sched()
    ids_run = submit(s_run)
    rep = s_run.run(max_ticks=500)
    assert rep["completed"] == len(ids_run)
    assert rep["status"] == "completed"

    s_str = make_sched()
    ids_str = submit(s_str)
    streamed = {i: [] for i in ids_str}
    finished = set()
    for rec in s_str.stream(max_ticks=500):
        if isinstance(rec, TokenBlockEvent):
            assert rec.offset == len(streamed[rec.seq_id])
            streamed[rec.seq_id] += rec.tokens
        elif isinstance(rec, SeqFinishedEvent):
            finished.add(rec.seq_id)
    assert finished == set(ids_str)
    for ir, is_ in zip(ids_run, ids_str):
        assert streamed[is_] == s_run.cos[ir].generated
        assert streamed[is_] == s_str.cos[is_].generated


def test_stream_matches_run_node_engine(rng):
    cfg = reduced_config("llama3_2_1b")
    prompts = [list(rng.integers(2, cfg.vocab_size, int(n)))
               for n in rng.integers(4, 10, 5)]
    max_out = [12, 5, 9, 16, 7]
    sps = [SamplingParams()] * 3 + [SamplingParams(temperature=0.9,
                                                   top_k=30, seed=7)] * 2

    def make():
        eng = NodeEngine(cfg, max_active=3, max_len=128, page_size=8,
                         seed=0)
        return CoroutineScheduler([eng], SchedulerConfig(page_size=8))

    _assert_stream_matches_run(
        make, lambda s: s.submit(prompts, max_out, sampling=sps))


def test_stream_matches_run_sim_engine():
    cfg = get_config("llama3_2_1b")
    hw = plan_lib.Hardware()
    plan = plan_lib.search_plan(cfg, hw, ctx=512, new_tokens=1,
                                max_active=16)
    prompts = [[1] * 32] * 8
    max_out = [40, 12, 25, 60, 8, 33, 17, 50]
    sp = SamplingParams(temperature=1.0, seed=5)   # pseudo-stream tokens

    def make():
        engines = [SimEngine(cfg, hw, node_id=i, max_active=4,
                             max_len=1024, page_size=16, plan=plan)
                   for i in range(2)]
        return CoroutineScheduler(engines, SchedulerConfig(page_size=16))

    _assert_stream_matches_run(
        make, lambda s: s.submit(prompts, max_out, sampling=sp,
                                 logprobs=True))


def test_abandoned_stream_never_reports_completed():
    """Breaking out of stream() must not leave a normal-looking report:
    status is derived from live sequence state, not from loop exit."""
    cfg = get_config("llama3_2_1b")
    eng = SimEngine(cfg, plan_lib.Hardware(), max_active=4, max_len=4096,
                    page_size=16)
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=16))
    sched.submit([[1] * 16] * 2, [500] * 2)
    for _ in sched.stream():
        break                      # consumer abandons the stream
    rep = sched.report()
    assert rep["status"] == "exhausted"
    assert rep["completed"] < rep["total"]


def test_run_reports_exhausted_status(caplog):
    cfg = get_config("llama3_2_1b")
    hw = plan_lib.Hardware()
    eng = SimEngine(cfg, hw, max_active=4, max_len=4096, page_size=16)
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=16))
    sched.submit([[1] * 16] * 2, [500] * 2)
    with caplog.at_level("WARNING"):
        rep = sched.run(max_ticks=2)
    assert rep["status"] == "exhausted"
    assert rep["completed"] < rep["total"]
    assert any("exhausted" in r.message for r in caplog.records)
    # finishing the batch flips the status back
    rep = sched.run(max_ticks=10000)
    assert rep["status"] == "completed"
    assert rep["completed"] == rep["total"]


# ---------------------------------------------------------------------------
# logprobs plane: one transfer per page (extended spy pattern)
# ---------------------------------------------------------------------------


def test_logprobs_one_transfer_per_decode_page():
    """Transfer-spy: requesting logprobs + top-logprobs must NOT add any
    device->host transfer — the (P, B) logprob plane rides the page's one
    packed block."""
    cfg = reduced_config("llama3_2_1b")
    eng = NodeEngine(cfg, max_active=3, max_len=128, page_size=8, seed=0)
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8))
    ids = sched.submit([[2, 3, 4, 5]] * 3, [20] * 3, logprobs=True,
                       top_logprobs=2)

    calls = []
    in_page = [False]
    orig_decode, orig_to_host = eng.decode_page, eng._to_host

    def spy_to_host(arr):
        if in_page[0]:              # ignore prefill/sync transfers
            calls[-1] += 1
        return orig_to_host(arr)

    def spy_decode(active, P):
        calls.append(0)
        in_page[0] = True
        try:
            return orig_decode(active, P)
        finally:
            in_page[0] = False

    eng.decode_page, eng._to_host = spy_decode, spy_to_host
    rep = sched.run(max_ticks=300)
    assert rep["completed"] == 3
    assert calls and all(c == 1 for c in calls), calls
    for i in ids:
        co = sched.cos[i]
        assert len(co.token_logprobs) == len(co.generated) == 20
        assert len(co.top_token_logprobs) == 20
        assert all(len(row) == 2 for row in co.top_token_logprobs)
        # greedy: the top-1 alternative IS the chosen token
        for tok, lp, row in zip(co.generated, co.token_logprobs,
                                co.top_token_logprobs):
            assert row[0][0] == tok
            np.testing.assert_allclose(row[0][1], lp, atol=1e-5)
        assert all(lp <= 1e-6 for lp in co.token_logprobs)


def test_logprobs_fused_matches_looped(rng):
    """The on-device logprob plane agrees with the host-side looped
    baseline (same tokens, logprobs equal to float32 tolerance)."""
    cfg = reduced_config("llama3_2_1b")
    prompts = [list(rng.integers(2, cfg.vocab_size, int(n)))
               for n in rng.integers(4, 10, 3)]

    def run(fused):
        eng = NodeEngine(cfg, max_active=3, max_len=64, page_size=8,
                         seed=0, fused=fused)
        sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8))
        ids = sched.submit(prompts, [9, 6, 12], logprobs=True,
                           top_logprobs=3)
        rep = sched.run(max_ticks=200)
        assert rep["completed"] == 3
        return [sched.cos[i] for i in ids]

    f, l = run(True), run(False)
    assert [c.generated for c in f] == [c.generated for c in l]
    for a, b in zip(f, l):
        np.testing.assert_allclose(a.token_logprobs, b.token_logprobs,
                                   atol=2e-4)
        for ra, rb in zip(a.top_token_logprobs, b.top_token_logprobs):
            assert [t for t, _ in ra] == [t for t, _ in rb]
            np.testing.assert_allclose([x for _, x in ra],
                                       [x for _, x in rb], atol=2e-4)


def test_logprobs_do_not_perturb_token_stream(rng):
    cfg = reduced_config("llama3_2_1b")
    prompts = [list(rng.integers(2, cfg.vocab_size, 5)) for _ in range(3)]
    sp = SamplingParams(temperature=0.8, top_p=0.9, seed=11)

    def run(lp):
        eng = NodeEngine(cfg, max_active=3, max_len=64, page_size=8, seed=0)
        sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8))
        ids = sched.submit(prompts, [10] * 3, sampling=sp, logprobs=lp)
        assert sched.run(max_ticks=200)["completed"] == 3
        return [sched.cos[i].generated for i in ids]

    assert run(False) == run(True)


# ---------------------------------------------------------------------------
# batch API: stream-first results + logprobs surface
# ---------------------------------------------------------------------------


def test_batch_master_stream_first(rng):
    cfg = reduced_config("llama3_2_1b")
    eng = NodeEngine(cfg, max_active=3, max_len=64, page_size=8)
    master = BatchMaster([eng], SchedulerConfig(page_size=8))
    reqs = [BatchRequest(custom_id=f"r{i}",
                         prompt=list(rng.integers(2, 100, 5)),
                         max_tokens=4 + i, logprobs=True,
                         top_logprobs=2 if i % 2 else 0)
            for i in range(5)]
    bid = master.submit(reqs)
    streamed: dict = {r.custom_id: [] for r in reqs}
    seen_partial = False
    bo = master.batches[bid]
    for rec in master.stream(bid):
        assert rec.custom_id in streamed
        if isinstance(rec, TokenBlockEvent):
            streamed[rec.custom_id] += rec.tokens
            if rec.logprobs is not None:
                assert len(rec.logprobs) == len(rec.tokens)
        if isinstance(rec, SeqFinishedEvent):
            seen_partial = seen_partial or (
                0 < len(bo.results) < len(reqs))
    assert seen_partial, "results must fill incrementally during the stream"
    assert bo.status == "completed"
    assert [r["custom_id"] for r in bo.results] == [f"r{i}" for i in range(5)]
    for i, row in enumerate(bo.results):
        assert row["response"]["tokens"] == streamed[f"r{i}"]
        assert len(row["response"]["tokens"]) == reqs[i].max_tokens
        lp = row["response"]["logprobs"]
        assert len(lp["token_logprobs"]) == reqs[i].max_tokens
        if reqs[i].top_logprobs:
            assert all(len(t) == 2 for t in lp["top_logprobs"][0:1])
    assert bo.request_counts == {"total": 5, "completed": 5, "failed": 0}


def test_batch_master_duplicate_custom_ids_kept_separate(rng):
    """Result rows are keyed by seq_id internally, so two requests sharing
    a custom_id each keep their own output."""
    cfg = reduced_config("llama3_2_1b")
    eng = NodeEngine(cfg, max_active=3, max_len=64, page_size=8)
    master = BatchMaster([eng], SchedulerConfig(page_size=8))
    reqs = [BatchRequest(custom_id="dup", prompt=[2, 3, 4, 5],
                         max_tokens=4),
            BatchRequest(custom_id="dup", prompt=[6, 7, 8],
                         max_tokens=6)]
    bid = master.submit(reqs)
    bo = master.run(bid)
    assert [len(r["response"]["tokens"]) for r in bo.results] == [4, 6]
    # per-batch working state (scheduler + coroutines) is released
    assert bid not in master._scheds and bid not in master._rows


def test_batch_master_abandoned_stream_then_rerun(rng):
    """Abandoning stream() mid-flight must not corrupt a later run():
    the fresh pass resets results/counts, and run() on a finalized batch
    is idempotent (no KeyError, no re-decode)."""
    cfg = reduced_config("llama3_2_1b")
    eng = NodeEngine(cfg, max_active=3, max_len=64, page_size=8)
    master = BatchMaster([eng], SchedulerConfig(page_size=8))
    reqs = [BatchRequest(custom_id=f"r{i}",
                         prompt=list(rng.integers(2, 100, 4)),
                         max_tokens=3 + i) for i in range(4)]
    bid = master.submit(reqs)
    for rec in master.stream(bid):
        if isinstance(rec, SeqFinishedEvent):
            break                      # client disconnects mid-batch
    assert master.batches[bid].status == "in_progress"
    bo = master.run(bid)               # recovery pass
    assert bo.status == "completed"
    assert bo.request_counts == {"total": 4, "completed": 4, "failed": 0}
    assert [r["custom_id"] for r in bo.results] == [f"r{i}" for i in range(4)]
    bo2 = master.run(bid)              # idempotent on finalized batch
    assert bo2 is bo
    with pytest.raises(ValueError, match="finalized"):
        next(iter(master.stream(bid)))


def test_looped_module_granularity_logprobs(rng):
    """fused=False + module_granularity: logprobs must stay aligned with
    the generated stream (the baseline path computes them host-side)."""
    cfg = reduced_config("phi3_5_moe")
    prompts = [list(rng.integers(2, cfg.vocab_size, 5)) for _ in range(2)]

    def run(fused):
        eng = NodeEngine(cfg, max_active=2, max_len=64, page_size=8,
                         seed=0, fused=fused, module_granularity=True,
                         b_attn=1)
        sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8))
        ids = sched.submit(prompts, [7, 5], logprobs=True)
        assert sched.run(max_ticks=200)["completed"] == 2
        return [sched.cos[i] for i in ids]

    f, l = run(True), run(False)
    assert [c.generated for c in f] == [c.generated for c in l]
    for a, b in zip(f, l):
        assert len(a.token_logprobs) == len(a.generated)
        assert len(b.token_logprobs) == len(b.generated)
        np.testing.assert_allclose(a.token_logprobs, b.token_logprobs,
                                   atol=2e-4)


# ---------------------------------------------------------------------------
# NODE_FAILURE event through the default policy
# ---------------------------------------------------------------------------


def test_node_failure_event_recovers_sequences():
    cfg = reduced_config("llama3_2_1b")
    engs = [NodeEngine(cfg, node_id=i, max_active=3, max_len=64,
                       page_size=8, seed=0) for i in range(2)]
    sched = CoroutineScheduler(engs, SchedulerConfig(page_size=8))
    ids = sched.submit([[2, 3, 4]] * 6, [10] * 6)
    sched.step()                       # both nodes prefill + first page
    sched.queue.push(EventKind.NODE_FAILURE, node=0)
    rep = sched.run(max_ticks=300)
    assert rep["completed"] == 6, "all sequences survive the failure"
    assert [e.node_id for e in sched.engines] == [1]
    assert all(sched.cos[i].done for i in ids)
    # everything that was still in flight at failure time moved to node 1
    # (sequences already finished on node 0 keep their historical placement)
    assert any(sched.cos[i].node == 1 for i in ids)
