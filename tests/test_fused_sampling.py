"""Fused single-pass sampling: Pallas kernel vs its pure-jnp oracle,
the shared-sort XLA fallback vs the sequential per-filter pipeline, tier
agreement, chi-square distribution checks against the PR 2 three-sort
semantics, and the pre-filter logprob-lane contract."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from scipy import stats as sp_stats

from repro import sampling as S
from repro.kernels import get_kernel
from repro.kernels.fused_sampling import ref as R
from repro.kernels.fused_sampling.ops import fused_sample
from repro.sampling import SampleFlags, SamplingParams


def _rows(rng, B, V, scale=2.0):
    return jnp.asarray(rng.normal(0.0, scale, (B, V)), jnp.float32)


def _params(rng, B):
    k = jnp.asarray(rng.choice([0, 1, 5, 40, 300], B), jnp.int32)
    p = jnp.asarray(rng.choice([1.0, 0.95, 0.9, 0.5], B), jnp.float32)
    mp = jnp.asarray(rng.choice([0.0, 0.02, 0.1], B), jnp.float32)
    return k, p, mp


# ---------------------------------------------------------------------------
# kernel vs ref.py oracle (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("V,B", [(512, 4), (4096, 3), (1000, 4), (131072, 2)])
def test_kernel_matches_ref(V, B):
    """Interpret-mode kernel == pure-jnp oracle across pow2, odd and
    128k-sized vocabularies (odd V exercises the NEG padding)."""
    rng = np.random.default_rng(V)
    x, g = _rows(rng, B, V), _rows(rng, B, V, 1.0)
    k, p, mp = _params(rng, B)
    out = fused_sample(x, g, k, p, mp, interpret=True)
    # the oracle sees the padded row the kernel binned (catch-all bucket
    # counts include padding; thresholds must still agree exactly)
    pad = (-x.shape[1]) % 512
    xp = jnp.pad(x, ((0, 0), (0, pad)), constant_values=R.NEG)
    gp = jnp.pad(g, ((0, 0), (0, pad)))
    ref = jax.vmap(R.ref_fused_sample)(xp, gp, k, p, mp)
    np.testing.assert_array_equal(out["sampled"], ref["sampled"])
    np.testing.assert_array_equal(out["greedy"], ref["greedy"])
    for key in ("tau", "m", "l"):
        np.testing.assert_allclose(out[key], ref[key], rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("V,B,lp_k", [(700, 3, 0), (1536, 4, 4)])
def test_vmem_parked_row_is_bit_identical(V, B, lp_k):
    """``park_vmem=True`` (logits row held in VMEM scratch across the 7
    phases, phase-idle inputs pinned so HBM reads each operand once) is
    bit-identical to the streaming kernel on every output — incl. the
    fused logprob lanes and odd-V NEG padding."""
    rng = np.random.default_rng(V)
    x, g = _rows(rng, B, V), _rows(rng, B, V, 1.0)
    raw = _rows(rng, B, V, 1.0)
    k, p, mp = _params(rng, B)
    kw = dict(lp_k=lp_k, with_lanes=lp_k > 0,
              raw=raw if lp_k > 0 else None)
    parked = fused_sample(x, g, k, p, mp, park_vmem=True, interpret=True,
                          **kw)
    streamed = fused_sample(x, g, k, p, mp, park_vmem=False,
                            interpret=True, **kw)
    assert set(parked) == set(streamed)
    for key in parked:
        np.testing.assert_array_equal(np.asarray(parked[key]),
                                      np.asarray(streamed[key]), err_msg=key)


def test_kernel_matches_xla_fallback_tokens():
    """Same fold_in-derived Gumbel rows through the kernel and the
    shared-sort fallback -> identical sampled tokens (the threshold
    refinement is exact to ~2e-6 nats, far inside the logit spacing)."""
    rng = np.random.default_rng(0)
    B, V = 8, 512
    x = _rows(rng, B, V)
    keys = S.step_keys(S.base_keys(np.arange(B, dtype=np.uint32)),
                       jnp.arange(B, dtype=jnp.int32))
    g = S.token_gumbel(keys, jnp.broadcast_to(
        jnp.arange(V, dtype=jnp.int32)[None], (B, V)))
    k, p, mp = _params(rng, B)
    kern = fused_sample(x, g, k, p, mp, interpret=True)
    from repro.sampling.processors import _NEG_INF, joint_threshold
    tau = joint_threshold(x, k, p, mp, 0)
    masked = jnp.where(x >= tau[:, None], x, _NEG_INF)
    np.testing.assert_array_equal(np.asarray(kern["sampled"]),
                                  np.asarray(jnp.argmax(masked + g, -1)))


def test_kernel_logprob_lanes_match_topk():
    """The kernel's fused raw-logit lanes reproduce the transfer plane's
    log_softmax + lax.top_k math (values and tie-broken indices)."""
    rng = np.random.default_rng(1)
    B, V, K = 3, 700, 4
    x, g = _rows(rng, B, V), _rows(rng, B, V, 1.0)
    raw = _rows(rng, B, V, 1.0)
    out = fused_sample(x, g, jnp.zeros((B,), jnp.int32), jnp.ones((B,)),
                       jnp.zeros((B,)), raw=raw, lp_k=K, with_lanes=True,
                       interpret=True)
    lp = jax.nn.log_softmax(raw, axis=-1)
    v_ref, i_ref = jax.lax.top_k(lp, K)
    logz = out["m_raw"] + jnp.log(out["l_raw"])
    np.testing.assert_array_equal(out["top_idx"], i_ref)
    np.testing.assert_allclose(out["top_vals"] - logz[:, None], v_ref,
                               atol=1e-5)
    chosen = jnp.take_along_axis(
        lp, out["sampled"][:, None].astype(jnp.int32), axis=1)[:, 0]
    recomputed = jnp.take_along_axis(
        raw, out["sampled"][:, None].astype(jnp.int32),
        axis=1)[:, 0] - logz
    np.testing.assert_allclose(recomputed, chosen, atol=1e-5)


def test_kernel_registry():
    op, ref = get_kernel("fused_sampling")
    assert op is fused_sample and ref is R.ref_fused_sample
    assert get_kernel("paged_attention")[0].__name__ == "paged_attention"


# ---------------------------------------------------------------------------
# joint threshold vs the sequential per-filter composition
# ---------------------------------------------------------------------------


def _old_pipeline(x, k, p, mp):
    return S.apply_min_p(S.apply_top_p(S.apply_top_k(
        x, jnp.asarray(k)), jnp.asarray(p)), jnp.asarray(mp))


@pytest.mark.parametrize("k,p,mp", [
    (0, 1.0, 0.0), (5, 1.0, 0.0), (0, 0.7, 0.0), (0, 1.0, 0.05),
    (40, 0.9, 0.02), (3, 0.5, 0.2), (511, 0.99, 0.0), (1, 0.1, 0.5),
])
def test_joint_threshold_equals_sequential_filters(k, p, mp):
    rng = np.random.default_rng(7)
    for trial in range(20):
        x = jnp.asarray(rng.normal(0, 2.0, 512), jnp.float32)
        old_keep = np.asarray(_old_pipeline(x, k, p, mp)) > -1e29
        new = S.joint_filter(x, jnp.asarray(k), jnp.asarray(p),
                             jnp.asarray(mp), 0)
        np.testing.assert_array_equal(np.asarray(new) > -1e29, old_keep)
        # exact passthrough of kept values (identity contract)
        np.testing.assert_array_equal(np.asarray(new)[old_keep],
                                      np.asarray(x)[old_keep])


def test_disabled_defaults_identity_all_tiers():
    """k=0 / p=1 / min_p=0 -> joint filter is a bitwise identity in the
    full-sort, partial-sort and sortless tiers."""
    x = jnp.asarray(np.random.default_rng(3).normal(0, 3.0, 640),
                    jnp.float32)
    for kc in (0, 64, -1):
        out = S.joint_filter(x, jnp.asarray(0), jnp.asarray(1.0),
                             jnp.asarray(0.0), kc)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_topk_tier_matches_full_tier_tokens():
    """The lane tier and the full-sort tier keep the same set AND —
    because the Gumbel noise is token-indexed on every tier — realize
    the identical token stream for the same fold_in keys, so a batch
    composition that flips the tier cannot perturb a sequence's
    stream."""
    rng = np.random.default_rng(11)
    B, V = 16, 256
    x = jnp.asarray(rng.normal(0, 2.0, (B, V)), jnp.float32)
    tau_full = S.joint_threshold(x[0], jnp.asarray(40), jnp.asarray(0.9),
                                 jnp.asarray(0.0), 0)
    tau_lane = S.joint_threshold(x[0], jnp.asarray(40), jnp.asarray(0.9),
                                 jnp.asarray(0.0), 64)
    assert np.array_equal(np.asarray(x[0] >= tau_full),
                          np.asarray(x[0] >= tau_lane))
    row = {"temperature": 0.9, "top_k": 40, "top_p": 0.9, "min_p": 0.0,
           "repetition_penalty": 1.0, "presence_penalty": 0.0,
           "frequency_penalty": 0.0}
    sp = {k: jnp.full((B,), v, jnp.int32 if k == "top_k" else jnp.float32)
          for k, v in row.items()}
    zeros = jnp.zeros((B, V), jnp.int32)
    keys = _keys(B, 31)
    toks = [np.asarray(S.sample(x, zeros, zeros, sp, keys,
                                SampleFlags("xla", False, kc, False,
                                            False)))
            for kc in (0, 64)]
    np.testing.assert_array_equal(toks[0], toks[1])


def test_lane_tier_requires_topk_on_every_drawing_row():
    """Regression: a filterless (temperature-only) row co-batched with a
    top-k row must force the full tier — the lane tier would silently
    truncate its draw to the top-kc logits."""
    f = S.flags_for([SamplingParams(temperature=0.8, top_k=30, seed=0),
                     SamplingParams(temperature=1.1, seed=1)], 4096)
    assert f.kc == 0
    # and the filterless row really does draw outside any small cap:
    rng = np.random.default_rng(2)
    V, n = 64, 2000
    x = jnp.broadcast_to(jnp.asarray(rng.normal(0, 1.0, V), jnp.float32),
                         (n, V))
    row = {"temperature": 1.1, "top_k": 0, "top_p": 1.0, "min_p": 0.0,
           "repetition_penalty": 1.0, "presence_penalty": 0.0,
           "frequency_penalty": 0.0}
    sp = {k: jnp.full((n,), v, jnp.int32 if k == "top_k" else jnp.float32)
          for k, v in row.items()}
    zeros = jnp.zeros((n, V), jnp.int32)
    toks = np.asarray(S.sample(x, zeros, zeros, sp, _keys(n, 3),
                               SampleFlags("xla", False, 0, False, False)))
    assert len(np.unique(toks)) > 32     # mass well outside any kc=32 cap


# ---------------------------------------------------------------------------
# distribution: chi-square vs the PR 2 three-sort pipeline semantics
# ---------------------------------------------------------------------------


def _ref_probs(logits, temperature=1.0, top_k=0, top_p=1.0, min_p=0.0):
    """NumPy ground truth with the sequential three-filter semantics."""
    l = np.asarray(logits, np.float64) / temperature
    if top_k > 0:
        kth = np.sort(l)[::-1][min(top_k, len(l)) - 1]
        l = np.where(l >= kth, l, -np.inf)
    if top_p < 1.0:
        order = np.argsort(l)[::-1]
        pr = np.exp(l[order] - np.max(l))
        pr /= pr.sum()
        cum_excl = np.cumsum(pr) - pr
        l = np.where(l >= l[order][cum_excl < top_p].min(), l, -np.inf)
    if min_p > 0.0:
        pm = np.where(np.isfinite(l),
                      np.exp(l - np.nanmax(np.where(np.isfinite(l), l,
                                                    np.nan))), 0.0)
        l = np.where(pm >= min_p * pm.max(), l, -np.inf)
    pr = np.exp(l - np.max(l[np.isfinite(l)]))
    pr[~np.isfinite(l)] = 0.0
    return pr / pr.sum()


def _chi_square(tokens, probs, alpha=1e-3):
    obs = np.bincount(tokens, minlength=len(probs)).astype(np.float64)
    assert obs[probs == 0].sum() == 0, "drew a filtered (p=0) token"
    exp = len(tokens) * probs
    live = exp > 0
    chi2 = float(((obs[live] - exp[live]) ** 2 / exp[live]).sum())
    crit = float(sp_stats.chi2.ppf(1 - alpha, int(live.sum()) - 1))
    assert chi2 < crit, f"chi2={chi2:.1f} >= crit={crit:.1f}"


def _keys(n, seed):
    return S.step_keys(S.base_keys(np.full((n,), seed, np.uint32)),
                       jnp.arange(n, dtype=jnp.int32))


N_DRAWS = 4000


@pytest.mark.parametrize("kw,kc", [
    ({"temperature": 0.8, "top_k": 10, "top_p": 0.9}, 16),
    ({"temperature": 0.8, "top_k": 10, "top_p": 0.9}, 0),
    ({"temperature": 1.2, "top_p": 0.7}, 0),
    ({"temperature": 0.9, "min_p": 0.05}, -1),
])
def test_chi_square_fallback_matches_old_pipeline(kw, kc):
    """The single-pass fallback (every tier) draws from the identical
    distribution as the PR 2 sequential three-sort pipeline."""
    rng = np.random.default_rng(5)
    V = 24
    logits = rng.normal(0.0, 2.0, V)
    row = {"temperature": 1.0, "top_k": 0, "top_p": 1.0, "min_p": 0.0,
           "repetition_penalty": 1.0, "presence_penalty": 0.0,
           "frequency_penalty": 0.0}
    row.update(kw)
    sp = {k: jnp.full((N_DRAWS,), v,
                      jnp.int32 if k == "top_k" else jnp.float32)
          for k, v in row.items()}
    zeros = jnp.zeros((N_DRAWS, V), jnp.int32)
    toks = S.sample(jnp.broadcast_to(jnp.asarray(logits, jnp.float32),
                                     (N_DRAWS, V)), zeros, zeros, sp,
                    _keys(N_DRAWS, 17),
                    SampleFlags("xla", False, kc, False, False))
    ref = {k: row[k] for k in ("temperature", "top_k", "top_p", "min_p")}
    _chi_square(np.asarray(toks), _ref_probs(logits, **ref))


def test_chi_square_kernel_matches_old_pipeline():
    """Interpret-mode kernel draws (histogram threshold + Gumbel-max over
    the kept set) match the three-sort pipeline's distribution."""
    rng = np.random.default_rng(9)
    n, V = 2000, 24
    logits = rng.normal(0.0, 2.0, V)
    temp = 0.9
    x = jnp.broadcast_to(jnp.asarray(logits / temp, jnp.float32), (n, V))
    g = jax.vmap(lambda kk: jax.random.gumbel(kk, (V,), jnp.float32))(
        _keys(n, 23))
    out = fused_sample(x, g, jnp.full((n,), 6, jnp.int32),
                       jnp.full((n,), 0.85, jnp.float32),
                       jnp.zeros((n,), jnp.float32), interpret=True)
    _chi_square(np.asarray(out["sampled"]),
                _ref_probs(logits, temperature=temp, top_k=6, top_p=0.85))


# ---------------------------------------------------------------------------
# key derivation + flags plumbing
# ---------------------------------------------------------------------------


def test_base_keys_host_matches_prngkey():
    seeds = np.array([0, 1, 77, 2**32 - 1], np.uint32)
    ref = jax.vmap(lambda s: jax.random.PRNGKey(s))(jnp.asarray(seeds))
    np.testing.assert_array_equal(S.base_keys_host(seeds), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(S.base_keys(seeds)),
                                  np.asarray(ref))


def test_flags_for_tiers():
    f = S.flags_for([SamplingParams(temperature=0.8, top_k=40,
                                    top_p=0.95, seed=0)], 4096)
    assert (f.kc, f.pen, f.mixed, f.stops) == (64, False, False, False)
    assert S.flags_for([SamplingParams(temperature=0.8, top_p=0.9,
                                       seed=0)], 4096).kc == 0
    assert S.flags_for([SamplingParams(temperature=0.8, min_p=0.1,
                                       seed=0)], 4096).kc == -1
    # greedy riders never block the lane tier (lane 0 IS the argmax)
    assert S.flags_for([SamplingParams(),
                        SamplingParams(temperature=0.8, top_k=12,
                                       seed=0)], 4096).kc == 16
    mixed = S.flags_for([SamplingParams(),
                         SamplingParams(temperature=1.0, top_k=500,
                                        repetition_penalty=1.2, seed=0,
                                        stop=(3,))], 4096)
    assert (mixed.kc, mixed.pen, mixed.mixed, mixed.stops) == \
        (512, True, True, True)
    # a top-k larger than the vocab degenerates to the full-sort tier
    assert S.flags_for([SamplingParams(temperature=1.0, top_k=4000,
                                       seed=0)], 4096).kc == 0
