"""Property-based tests (hypothesis) on the memory subsystem invariants:
paged host store roundtrips, allocator conservation, eviction policy."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; skip when absent")
from hypothesis import given, settings, strategies as st

from repro.memory.allocator import PageAllocator
from repro.memory.paged_kv import HostKVStore

SET = settings(max_examples=25, deadline=None)


@given(length=st.integers(1, 200), page=st.integers(1, 64),
       extra=st.integers(0, 50))
@SET
def test_checkpoint_restore_roundtrip(length, page, extra):
    store = HostKVStore(page)
    arr = np.arange(3 * (length + extra) * 4, dtype=np.float32).reshape(
        3, length + extra, 4)
    store.checkpoint(7, {"k": arr[:, :length + extra]}, length)
    out = store.restore(7, max_len=length + extra + 5)
    np.testing.assert_array_equal(out["k"][:, :length], arr[:, :length])
    # positions beyond `length` are zero (not leaked from the padded page)
    assert np.all(out["k"][:, length + (-length) % page:] == 0)


@given(n_appends=st.integers(1, 20), page=st.integers(1, 16))
@SET
def test_incremental_append_equals_bulk(n_appends, page):
    """Appending token-by-token == one bulk checkpoint (host is the single
    source of truth under the §5.3 Sync phase)."""
    rng = np.random.default_rng(1)
    chunks = [rng.standard_normal((2, 1, 3)).astype(np.float32)
              for _ in range(n_appends)]
    full = np.concatenate(chunks, axis=1)
    a = HostKVStore(page)
    a.checkpoint(1, {"k": full[:, :0]}, 0)
    for i, c in enumerate(chunks):
        a.append_tokens(1, {"k": c}, i)
    b = HostKVStore(page)
    b.checkpoint(1, {"k": full}, n_appends)
    ra = a.restore(1, n_appends)
    rb = b.restore(1, n_appends)
    np.testing.assert_allclose(ra["k"], rb["k"])


@given(ops=st.lists(st.tuples(st.integers(0, 9), st.integers(1, 4)),
                    max_size=40))
@SET
def test_allocator_conservation(ops):
    """free + sum(owned) == total after any alloc/free interleaving."""
    al = PageAllocator(total_pages=32, page_size=8)
    for seq, n in ops:
        if al.pages_of(seq) and n % 2 == 0:
            al.free_seq(seq)
        else:
            al.alloc(seq, n)
        owned = sum(len(v) for v in al.owned.values())
        assert owned + len(al.free) == al.total
        assert len(set(al.free)) == len(al.free)          # no double-free
        all_pages = sorted(al.free + [p for v in al.owned.values()
                                      for p in v])
        assert all_pages == list(range(al.total))          # no lost pages


@given(lengths=st.dictionaries(st.integers(0, 15), st.integers(1, 500),
                               min_size=1, max_size=10))
@SET
def test_eviction_most_progress_first(lengths):
    al = PageAllocator(total_pages=8, page_size=8)
    for s in lengths:
        al.alloc(s, 1)
        if not al.free:
            break
    evicted = al.ensure_two_pages(lengths)
    # invariant: evicted sequences have decoded length >= any survivor that
    # was eligible (most-progress-first, §5.3)
    survivors = [s for s in lengths if s not in evicted and al.pages_of(s)]
    if evicted and survivors:
        assert max(lengths[s] for s in survivors) <= max(
            lengths[e] for e in evicted)


# ---------------------------------------------------------------------------
# deterministic eviction tie-breaks (governor regression)
# ---------------------------------------------------------------------------


def test_eviction_tiebreak_is_seq_id_not_insertion_order():
    """Equal-progress victims must evict in seq_id order regardless of the
    dict's insertion order — a chaos replay whose `active` dict was built
    in a different order must pick the same victims, or its recovery
    diverges from the fault-free run."""
    def run(insertion):
        al = PageAllocator(total_pages=6, page_size=8)
        for s in insertion:
            al.alloc(s, 2)
        return al.ensure_two_pages({s: 100 for s in insertion})
    fwd = run([0, 1, 2])
    rev = run([2, 1, 0])
    assert fwd == rev
    # with all at equal progress the highest seq_id is NOT preferred —
    # ties break ascending by id
    assert fwd == sorted(fwd)


def test_eviction_equal_progress_all_active():
    """All active at identical progress: eviction still terminates, still
    deterministic, and frees enough for two pages per survivor."""
    al = PageAllocator(total_pages=8, page_size=8)
    for s in range(4):
        al.alloc(s, 2)
    evicted = al.ensure_two_pages({s: 50 for s in range(4)})
    assert evicted == sorted(evicted)
    survivors = [s for s in range(4) if s not in evicted]
    assert len(al.free) >= 2 * len(survivors) - sum(
        len(al.pages_of(s)) for s in survivors) or not survivors


# ---------------------------------------------------------------------------
# allocator edge cases
# ---------------------------------------------------------------------------


def test_admission_with_exactly_reserve_free_pages():
    al = PageAllocator(total_pages=4, page_size=8)
    assert al.alloc(1, 2) is not None
    assert al.can_admit(2)          # exactly `reserve` pages left
    assert al.alloc(2, 2) is not None
    assert not al.can_admit(2)      # zero left
    assert al.can_admit(0)
    assert al.alloc(3, 1) is None


def test_free_seq_unknown_id_is_safe():
    al = PageAllocator(total_pages=4, page_size=8)
    assert al.free_seq(99) == 0
    assert len(al.free) == 4
    assert al.stats.frees == 0


def test_peak_used_across_evict_readmit_cycles():
    al = PageAllocator(total_pages=8, page_size=8)
    al.alloc(0, 6)
    assert al.stats.peak_used == 6
    al.free_seq(0)
    assert al.used == 0
    al.alloc(1, 4)
    assert al.stats.peak_used == 6      # peak survives the free
    al.alloc(2, 4)                      # refused (only 4 free)
    assert al.stats.peak_used == 6
    al.alloc(2, 3)
    assert al.stats.peak_used == 7      # new high-water mark


def test_watermark_queries():
    al = PageAllocator(total_pages=10, page_size=8,
                       high_watermark=0.8, low_watermark=0.5)
    al.alloc(0, 5)
    assert not al.above_high() and not al.below_low()   # dead band
    al.alloc(1, 4)
    assert al.above_high()
    al.free_seq(1)
    al.free_seq(0)
    assert al.below_low() and al.occupancy == 0.0


# ---------------------------------------------------------------------------
# host-store incremental byte accounting (O(1) nbytes counter)
# ---------------------------------------------------------------------------


def _mk_slices(rng, n_tokens):
    return {"k": rng.standard_normal((2, n_tokens, 3)).astype(np.float32)}


def _assert_counter(store):
    assert store._nbytes == store.nbytes_walk(), (
        store._nbytes, store.nbytes_walk())


@given(steps=st.lists(st.sampled_from(["ckpt", "append", "drop"]),
                      min_size=1, max_size=30),
       page=st.integers(1, 8))
@SET
def test_nbytes_counter_matches_walk(steps, page):
    """The incrementally-maintained byte counter equals a full recomputed
    walk after any interleaving of checkpoint/append/drop."""
    rng = np.random.default_rng(3)
    store = HostKVStore(page)
    lengths = {}
    for i, op in enumerate(steps):
        seq = i % 3
        if op == "ckpt":
            n = int(rng.integers(1, 12))
            store.checkpoint(seq, _mk_slices(rng, n), n)
            lengths[seq] = n
        elif op == "append" and seq in lengths:
            store.append_tokens(seq, _mk_slices(rng, 1), lengths[seq])
            lengths[seq] += 1
        elif op == "drop" and seq in lengths:
            store.drop(seq)
            del lengths[seq]
        _assert_counter(store)
    assert store.nbytes() == store._nbytes


def test_nbytes_counter_across_adopt_and_pop():
    """Migrate's pop_state + adopt keep both stores' counters exact."""
    rng = np.random.default_rng(4)
    src, dst = HostKVStore(4), HostKVStore(4)
    src.checkpoint(1, _mk_slices(rng, 10), 10)
    _assert_counter(src)
    st_ = src.pop_state(1)
    _assert_counter(src)
    assert src._nbytes == 0
    dst.adopt(1, st_)
    _assert_counter(dst)
    assert dst.nbytes() > 0


def test_nbytes_counter_with_cow_and_shared_prefix():
    """publish_prefix page swaps, clone_shared, and COW copies all keep
    the counter equal to the dedup walk (shared pages counted once)."""
    rng = np.random.default_rng(5)
    store = HostKVStore(4, enable_prefix=True)
    prompt = list(range(8))                 # two full pages
    store.checkpoint(1, _mk_slices(rng, 8), 8)
    store.publish_prefix(1, prompt)
    _assert_counter(store)
    store.clone_shared(1, 2)
    _assert_counter(store)
    # sibling 2 appends into a shared page -> COW copy
    store.append_tokens(2, _mk_slices(rng, 1), 8)
    _assert_counter(store)
    assert store.cow_copies >= 0
    store.drop(2)
    _assert_counter(store)
    store.drop(1)
    _assert_counter(store)


def test_host_budget_cascades_to_prefix_eviction():
    """Exhausting the byte budget evicts LRU prefix spans; an over-budget
    store with only live spans reports over_budget (driver throttle)."""
    rng = np.random.default_rng(6)
    store = HostKVStore(4, enable_prefix=True, budget_bytes=1)
    store.checkpoint(1, _mk_slices(rng, 8), 8)
    store.publish_prefix(1, list(range(8)))
    # span still referenced by seq 1 -> nothing evictable yet
    assert store.over_budget()
    store.drop(1)       # releases the span; stays in trie as reusable cache
    evicted = store.enforce_budget()
    assert evicted > 0
    assert store.budget_evictions == evicted
    assert store.prefix_index.cached_nbytes == 0
