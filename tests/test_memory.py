"""Property-based tests (hypothesis) on the memory subsystem invariants:
paged host store roundtrips, allocator conservation, eviction policy."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; skip when absent")
from hypothesis import given, settings, strategies as st

from repro.memory.allocator import PageAllocator
from repro.memory.paged_kv import HostKVStore

SET = settings(max_examples=25, deadline=None)


@given(length=st.integers(1, 200), page=st.integers(1, 64),
       extra=st.integers(0, 50))
@SET
def test_checkpoint_restore_roundtrip(length, page, extra):
    store = HostKVStore(page)
    arr = np.arange(3 * (length + extra) * 4, dtype=np.float32).reshape(
        3, length + extra, 4)
    store.checkpoint(7, {"k": arr[:, :length + extra]}, length)
    out = store.restore(7, max_len=length + extra + 5)
    np.testing.assert_array_equal(out["k"][:, :length], arr[:, :length])
    # positions beyond `length` are zero (not leaked from the padded page)
    assert np.all(out["k"][:, length + (-length) % page:] == 0)


@given(n_appends=st.integers(1, 20), page=st.integers(1, 16))
@SET
def test_incremental_append_equals_bulk(n_appends, page):
    """Appending token-by-token == one bulk checkpoint (host is the single
    source of truth under the §5.3 Sync phase)."""
    rng = np.random.default_rng(1)
    chunks = [rng.standard_normal((2, 1, 3)).astype(np.float32)
              for _ in range(n_appends)]
    full = np.concatenate(chunks, axis=1)
    a = HostKVStore(page)
    a.checkpoint(1, {"k": full[:, :0]}, 0)
    for i, c in enumerate(chunks):
        a.append_tokens(1, {"k": c}, i)
    b = HostKVStore(page)
    b.checkpoint(1, {"k": full}, n_appends)
    ra = a.restore(1, n_appends)
    rb = b.restore(1, n_appends)
    np.testing.assert_allclose(ra["k"], rb["k"])


@given(ops=st.lists(st.tuples(st.integers(0, 9), st.integers(1, 4)),
                    max_size=40))
@SET
def test_allocator_conservation(ops):
    """free + sum(owned) == total after any alloc/free interleaving."""
    al = PageAllocator(total_pages=32, page_size=8)
    for seq, n in ops:
        if al.pages_of(seq) and n % 2 == 0:
            al.free_seq(seq)
        else:
            al.alloc(seq, n)
        owned = sum(len(v) for v in al.owned.values())
        assert owned + len(al.free) == al.total
        assert len(set(al.free)) == len(al.free)          # no double-free
        all_pages = sorted(al.free + [p for v in al.owned.values()
                                      for p in v])
        assert all_pages == list(range(al.total))          # no lost pages


@given(lengths=st.dictionaries(st.integers(0, 15), st.integers(1, 500),
                               min_size=1, max_size=10))
@SET
def test_eviction_most_progress_first(lengths):
    al = PageAllocator(total_pages=8, page_size=8)
    for s in lengths:
        al.alloc(s, 1)
        if not al.free:
            break
    evicted = al.ensure_two_pages(lengths)
    # invariant: evicted sequences have decoded length >= any survivor that
    # was eligible (most-progress-first, §5.3)
    survivors = [s for s in lengths if s not in evicted and al.pages_of(s)]
    if evicted and survivors:
        assert max(lengths[s] for s in survivors) <= max(
            lengths[e] for e in evicted)
