"""Roofline-harness unit tests: collective-HLO parsing, ring factors,
attention-scan correction consistency, plan cost monotonicity."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.collectives import collective_stats
from repro.models import layers, flash

HLO = """
ENTRY main {
  %p = bf16[8,4096,2048]{2,1,0} parameter(0)
  %ar = bf16[8,4096,2048]{2,1,0} all-reduce(%p), replica_groups=[32,16]<=[512], to_apply=%add
  %ag = f32[256,1024]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %rs = f32[16,1024]{1,0} reduce-scatter(%y), replica_groups=[2,256]<=[512], to_apply=%add
  %a2a = bf16[64,128]{1,0} all-to-all(%z), replica_groups=[32,16]<=[512]
  %cp = s32[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
}
"""


def test_collective_parser_counts_and_factors():
    st = collective_stats(HLO, world=512)
    assert st["counts"] == {"all-reduce": 1, "all-gather": 1,
                            "reduce-scatter": 1, "all-to-all": 1,
                            "collective-permute": 1}
    ar = 8 * 4096 * 2048 * 2
    assert st["raw_bytes"]["all-reduce"] == ar
    np.testing.assert_allclose(st["wire_bytes"]["all-reduce"],
                               ar * 2 * 15 / 16)
    ag = 256 * 1024 * 4
    np.testing.assert_allclose(st["wire_bytes"]["all-gather"], ag * 3 / 4)
    rs = 16 * 1024 * 4
    np.testing.assert_allclose(st["wire_bytes"]["reduce-scatter"], rs * 255)
    assert st["wire_bytes"]["collective-permute"] == 128 * 4


def test_attention_chunk_invariance():
    """The correction model assumes chunking never changes results: the
    flash output must be identical for any chunk size (incl. nc=1)."""
    rng = np.random.default_rng(0)
    B, S, H, Hkv, dh = 1, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    full = layers.chunked_attention(q, k, v, pos, pos, chunk=64)  # nc=1
    for c in (8, 16, 32):
        out = layers.chunked_attention(q, k, v, pos, pos, chunk=c)
        np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                                   atol=2e-5)


def test_ideal_decode_bytes_orders():
    from benchmarks.roofline import ideal_decode_bytes
    from repro.configs import get_config

    full = ideal_decode_bytes(get_config("llama3_2_1b"), "decode_32k", 256)
    swa = ideal_decode_bytes(get_config("h2o_danube_1_8b"), "decode_32k", 256)
    ssm = ideal_decode_bytes(get_config("mamba2_370m"), "long_500k", 256)
    # SWA bounds cache traffic far below full attention at same class size;
    # SSM long-context state is tiny
    assert swa < full
    assert ssm < 1e9


def test_hierarchical_a2a_beats_flat():
    from repro.distributed.collectives import hierarchical_a2a_cost

    flat, hier = hierarchical_a2a_cost(1e9, pods=2, per_pod=256)
    assert hier < flat          # pod-local-first always wins cross-pod
    # single pod: degenerates to pure ICI
    flat1, hier1 = hierarchical_a2a_cost(1e9, pods=1, per_pod=256)
    assert hier1 <= flat1
