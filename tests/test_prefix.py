"""Shared-prefix KV reuse: trie index, COW pages, fork fan-out.

Covers the whole share -> diverge -> release lifecycle at three levels:

* PrefixIndex unit semantics (match/extend/graft, LRU eviction of
  zero-ref spans only, refcount underflow detection),
* HostKVStore COW + span-aware exactly-once ``drop``,
* end-to-end bitwise identity: ``submit(n=...)`` fork fan-out and
  cross-submit prefix hits must reproduce independent submissions
  token-for-token on BOTH the virtual-clock SimEngine cluster (with
  chaos) and the real jax NodeEngine (with a mid-stream MIGRATE).
"""
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core import plan as plan_lib
from repro.core import primitives as prim
from repro.core.scheduler import CoroutineScheduler, SchedulerConfig
from repro.memory.paged_kv import HostKVStore
from repro.prefix.index import PrefixIndex
from repro.runtime.cluster import Cluster, Workload
from repro.runtime.engine import NodeEngine
from repro.runtime.faults import Fault, FaultPlan
from repro.sampling import SamplingParams, derive_fork_seed

P = 4


def _tokens(n, start=0):
    return list(range(start, start + n))


# ---------------------------------------------------------------------------
# PrefixIndex unit semantics
# ---------------------------------------------------------------------------


def test_index_match_extend_longest_chain():
    idx = PrefixIndex(P)
    toks = _tokens(11)                       # 2 full pages + tail of 3
    assert idx.match(toks) == []
    chain = idx.extend([], toks)
    assert len(chain) == 2 and idx.num_pages == 2
    assert idx.stats["inserted_pages"] == 2
    # full match, partial match, diverging block
    assert idx.match(toks) == chain
    assert idx.match(toks[:P] + _tokens(P, 900)) == chain[:1]
    assert idx.match(_tokens(P, 900)) == []
    assert idx.stats["hits"] == 2 and idx.stats["hit_tokens"] == 3 * P
    # extending past an existing chain dedupes the shared nodes
    longer = idx.extend(idx.match(toks), toks + _tokens(P, 500))
    assert longer[:2] == chain and idx.num_pages == 3


def test_index_evicts_lru_zero_ref_only():
    idx = PrefixIndex(P, max_pages=2)
    a = idx.extend([], _tokens(2 * P))       # 2 pages, then pin them
    idx.acquire(a[-1])
    idx.extend([], _tokens(2 * P, 700))      # 2 more: over budget
    # only the unpinned span's leaf-first cascade is evictable
    assert idx.num_pages == 2
    assert idx.stats["evicted_pages"] == 2
    assert idx.match(_tokens(2 * P)) == a, "live span must survive eviction"
    idx.release(a[-1])
    assert idx.live_refs() == 0


def test_index_refcount_underflow_raises():
    idx = PrefixIndex(P)
    chain = idx.extend([], _tokens(P))
    idx.acquire(chain[-1])
    idx.release(chain[-1])
    with pytest.raises(AssertionError, match="refcount underflow"):
        idx.release(chain[-1])


def test_index_graft_moves_span_bytes_once():
    src = PrefixIndex(P)
    pages = [{"k": np.arange(8, dtype=np.float32).reshape(2, P)}
             for _ in range(2)]
    chain = src.extend([], _tokens(2 * P), lambda i: pages[i])
    dst = PrefixIndex(P)
    c1, b1 = dst.graft(chain[-1])
    assert b1 == sum(p["k"].nbytes for p in pages)
    assert [nd.block for nd in c1] == [nd.block for nd in chain]
    c2, b2 = dst.graft(chain[-1])            # a sibling migrates later
    assert b2 == 0 and c2 == c1, "second graft must reuse resident nodes"


# ---------------------------------------------------------------------------
# HostKVStore: COW + exactly-once drop
# ---------------------------------------------------------------------------


def _store_with_span(n_tokens=2 * P):
    store = HostKVStore(page_size=P)
    arr = np.arange(2 * n_tokens, dtype=np.float32).reshape(2, n_tokens)
    store.checkpoint(0, {"k": arr}, n_tokens)
    store.publish_prefix(0, _tokens(n_tokens))
    return store, arr


def test_cow_first_divergent_write_copies_frozen_page():
    store, arr = _store_with_span()
    st0 = store.seqs[0]
    assert all(not p.flags.writeable for p in st0.pages["k"]), \
        "published span pages must be frozen"
    store.clone_shared(0, 1)
    st1 = store.seqs[1]
    assert st1.pages["k"][0] is st0.pages["k"][0], "fork shares span pages"
    before = st0.pages["k"][0].copy()
    store.append_tokens(1, {"k": np.full((2, 2), -1.0, np.float32)}, start=2)
    assert store.cow_copies == 1
    assert st1.pages["k"][0] is not st0.pages["k"][0], "write detached page"
    np.testing.assert_array_equal(st0.pages["k"][0], before), \
        "lead's shared page must be untouched"
    assert (st1.pages["k"][0][:, 2:P] == -1.0).all()


def test_drop_releases_span_exactly_once():
    store, _ = _store_with_span()
    store.clone_shared(0, 1)
    idx = store.prefix_index
    assert idx.live_refs() == 2 * 2          # two seqs x two-page chain
    store.drop(0)
    releases = idx.stats["releases"]
    store.drop(0)                            # duplicate teardown: no-op
    assert idx.stats["releases"] == releases
    assert idx.live_refs() == 2
    store.drop(1)
    assert idx.live_refs() == 0


def test_fork_seed_derivation_stable_and_distinct():
    assert derive_fork_seed(123, 0) == 123, "fork 0 keeps the group seed"
    seeds = {derive_fork_seed(123, k) for k in range(64)}
    assert len(seeds) == 64
    assert derive_fork_seed(123, 7) == derive_fork_seed(123, 7)


# ---------------------------------------------------------------------------
# SimEngine cluster: fan-out bitwise identity + chaos refcount hygiene
# ---------------------------------------------------------------------------


def _sim_cluster(enable_prefix=True, fault_plan=None):
    return Cluster(get_config("qwen3_moe_30b"), plan_lib.Hardware(),
                   nodes=2, max_active=32, max_len=256, page_size=8,
                   fault_plan=fault_plan, enable_prefix=enable_prefix)


def _sim_tokens(cl):
    return {i: list(c.generated) for i, c in cl.sched.cos.items()}


def test_sim_fork_fanout_bitwise_and_flops():
    wl = Workload([[11 + i] * 20 for i in range(3)], [12] * 3)
    sp = SamplingParams(temperature=0.8)
    fan = _sim_cluster()
    rep = fan.run(wl, sampling=sp, n=4)
    assert rep["status"] == "completed" and rep["completed"] == 12
    base = _sim_cluster(enable_prefix=False)
    ind = Workload([p for p in wl.prompts for _ in range(4)],
                   [m for m in wl.max_out for _ in range(4)])
    assert base.run(ind, sampling=sp)["status"] == "completed"
    assert _sim_tokens(fan) == _sim_tokens(base), \
        "forked streams must be bitwise-identical to independent submits"
    computed = sum(e.prefill_tokens for e in fan.engines)
    naive = sum(e.prefill_tokens for e in base.engines)
    assert computed == 3 * 20 and naive == 12 * 20
    assert rep["prefix"]["prefill_tokens_saved"] == naive - computed
    for i in list(fan.sched.cos):
        fan.sched.retire(i)
    assert fan.sched.report()["prefix"]["live_refs"] == 0


@pytest.mark.parametrize("seed", [1, 2, 3])
def test_sim_fork_chaos_bitwise_and_refs_return_to_zero(seed):
    """Property: fork -> diverge -> NODE_FAILURE/straggler/transfer chaos
    (seeded FaultPlan) never perturbs a single token, and every span
    refcount returns to zero once the pool is retired."""
    wl = Workload([[31 + i] * 20 for i in range(3)], [16, 24, 12])
    sp = SamplingParams(temperature=0.9)
    clean = _sim_cluster()
    assert clean.run(wl, sampling=sp, n=4)["status"] == "completed"
    plan = FaultPlan.random(seed, nodes=2, horizon=12, n_faults=4)
    chaos = _sim_cluster(fault_plan=plan)
    rep = chaos.run(wl, sampling=sp, n=4)
    assert rep["status"] == "completed" and rep["completed"] == 12
    assert _sim_tokens(chaos) == _sim_tokens(clean), plan.describe()
    for i in list(chaos.sched.cos):
        chaos.sched.retire(i)
    assert chaos.sched.report()["prefix"]["live_refs"] == 0


def test_sim_node_failure_mid_fork_recovers_bitwise():
    wl = Workload([[61] * 24], [40])
    sp = SamplingParams(temperature=0.7)
    clean = _sim_cluster()
    assert clean.run(wl, sampling=sp, n=6)["status"] == "completed"
    plan = FaultPlan([Fault("node_death", node=1, at_tick=2)], seed=0)
    chaos = _sim_cluster(fault_plan=plan)
    rep = chaos.run(wl, sampling=sp, n=6)
    assert rep["status"] == "completed"
    assert 1 in rep["robustness"]["failed_nodes"]
    assert _sim_tokens(chaos) == _sim_tokens(clean)
    for i in list(chaos.sched.cos):
        chaos.sched.retire(i)
    assert chaos.sched.report()["prefix"]["live_refs"] == 0


# ---------------------------------------------------------------------------
# NodeEngine (real jax decode): fork fan-out, cross-submit hits, COW
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_cfg():
    return reduced_config("llama3_2_1b")


def _node_run(cfg, prompts, max_out, sampling=None, n=1,
              enable_prefix=True):
    eng = NodeEngine(cfg, max_active=8, max_len=64, page_size=8, seed=0,
                     enable_prefix=enable_prefix)
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8))
    ids = sched.submit(prompts, max_out, sampling=sampling, n=n)
    rep = sched.run(max_ticks=500)
    assert rep["status"] == "completed"
    return eng, sched, {i: list(sched.cos[i].generated) for i in ids}


def test_node_fork_fanout_bitwise_and_prefill_saved(tiny_cfg, rng):
    prompt = list(rng.integers(2, 100, 17))
    sp = SamplingParams(temperature=0.8, top_p=0.9)
    eng, sched, forked = _node_run(tiny_cfg, [prompt], [10],
                                   sampling=sp, n=4)
    base_eng, _, independent = _node_run(
        tiny_cfg, [prompt] * 4, [10] * 4, sampling=sp,
        enable_prefix=False)
    assert forked == independent, \
        "real-engine fork fan-out must be bitwise-identical"
    assert eng.prefill_tokens == 17 and base_eng.prefill_tokens == 4 * 17
    assert eng.prefill_tokens_saved == 3 * 17
    for i in list(sched.cos):
        sched.retire(i)
    assert eng.host_store.prefix_index.live_refs() == 0


def test_node_cross_submit_prefix_hit_bitwise(tiny_cfg, rng):
    prompt = list(rng.integers(2, 100, 17))      # 2 full pages + 1
    eng, sched, first = _node_run(tiny_cfg, [prompt], [10])
    before = eng.prefill_tokens
    ids = sched.submit([prompt], [10])
    assert sched.run(max_ticks=500)["status"] == "completed"
    assert list(sched.cos[ids[0]].generated) == list(first.values())[0], \
        "prefix-hit tail recompute must reproduce the full prefill"
    assert eng.prefill_tokens - before == 1, \
        "hit recomputes only the last position"
    assert eng.prefill_tokens_saved >= 16
    assert eng.host_store.prefix_index.stats["hits"] >= 1


def test_node_migrate_forked_sibling_cow_and_release(tiny_cfg, rng):
    """YIELD -> MIGRATE a forked sibling mid-stream: the span grafts into
    the destination index (still frozen, still shared), the stream stays
    bitwise-identical, and a divergent write into the migrated span
    copy-on-writes a private page without touching the canonical one."""
    prompt = list(rng.integers(2, 100, 17))
    sp = SamplingParams(temperature=0.8, top_p=0.9)
    _, _, baseline = _node_run(tiny_cfg, [prompt] * 2, [16] * 2,
                               sampling=sp, enable_prefix=False)
    engs = [NodeEngine(tiny_cfg, node_id=i, max_active=4, max_len=64,
                       page_size=8, seed=0) for i in range(2)]
    sched = CoroutineScheduler(
        engs, SchedulerConfig(page_size=8, migrate_imbalance=10 ** 9))
    ids = sched.submit([prompt], [16], sampling=sp, n=2)
    sched._node_tick(0, engs[0])             # prefill + first decode page
    co = sched.cos[ids[1]]
    assert 0 < len(co.generated) < 16
    prim.yield_(co, engs[0])
    prim.migrate(co, engs[0], engs[1])
    prim.combine([co], engs[1])
    dst = engs[1].host_store
    assert dst.prefix_index.stats["inserted_pages"] >= 2, \
        "migrate must graft the span into the destination index"
    st = dst.seqs[ids[1]]
    assert st.prefix_node is not None
    leaf = next(n for n in st.pages if st.pages[n])
    span_page = st.pages[leaf][0]
    assert not span_page.flags.writeable, "grafted span stays frozen"
    canonical = span_page.copy()
    # decode only appends past the span (synced_len starts at co.length
    # after COMBINE), so the span is never organically rewritten — force
    # a divergent in-span write to prove the COW guard: private copy for
    # the writer, canonical page untouched
    poke = np.full((span_page.shape[0], 1) + span_page.shape[2:], -1.0,
                   span_page.dtype)
    dst.append_tokens(ids[1], {leaf: poke}, start=0)
    assert dst.cow_copies >= 1
    assert st.pages[leaf][0] is not span_page, "writer got a private page"
    np.testing.assert_array_equal(span_page, canonical)
    for _ in range(100):
        if all(sched.cos[i].done for i in ids):
            break
        sched._node_tick(0, engs[0])
        sched._node_tick(1, engs[1])
    assert {i: list(sched.cos[i].generated) for i in ids} == baseline, \
        "mid-stream MIGRATE of a fork must not perturb the stream"
    for i in ids:
        sched.retire(i)
    assert sum(e.host_store.prefix_index.live_refs() for e in engs) == 0
