"""Fused decode megastep: parity with the seed per-token loop, the
one-transfer-per-page contract, and bounded jit caches.

The fused path (one jitted lax.scan per page, NodeEngine(fused=True),
the default) must be token-for-token identical to the per-step Python
loop it replaced — across dense and MoE configs, mid-page finishes,
eviction/yield/combine round-trips, and the module-granularity path."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import reduced_config
from repro.core.forward import ModuleRuntime
from repro.core.scheduler import CoroutineScheduler, SchedulerConfig
from repro.models import transformer as T
from repro.models.api import MeshAxes
from repro.runtime.engine import NodeEngine, _PREFILL_JIT_CAP

AXES = MeshAxes()


def _run(cfg, prompts, max_out, *, fused, page_size=8, max_active=3,
         seed=0, **kw):
    eng = NodeEngine(cfg, max_active=max_active, max_len=128,
                     page_size=page_size, seed=seed, fused=fused, **kw)
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=page_size))
    ids = sched.submit(prompts, max_out)
    rep = sched.run(max_ticks=500)
    assert rep["completed"] == len(prompts)
    return [sched.cos[i].generated for i in ids], eng


@pytest.mark.parametrize("arch", ["llama3_2_1b", "phi3_5_moe"])
def test_fused_matches_looped_end_to_end(arch, rng):
    """Dense + MoE: full scheduler runs (prefill, eviction pressure with
    more sequences than slots, yield/combine round-trips, mid-page
    finishes from ragged max_out) decode identical tokens."""
    cfg = reduced_config(arch)
    prompts = [list(rng.integers(2, cfg.vocab_size, int(n)))
               for n in rng.integers(4, 12, 7)]
    max_out = [12, 5, 9, 20, 7, 3, 16]      # finishes at every page offset
    got_f, eng_f = _run(cfg, prompts, max_out, fused=True)
    got_l, eng_l = _run(cfg, prompts, max_out, fused=False)
    assert got_f == got_l, "fused megastep diverged from per-step loop"
    # per-page decode_steps accounting preserved (simulator/roofline)
    assert eng_f.decode_steps == eng_l.decode_steps
    # fused needs strictly fewer device->host transfers
    assert eng_f.d2h_transfers < eng_l.d2h_transfers


def test_fused_module_granularity_matches_looped(rng):
    """Algorithm-1 module path: scanned forward_decode_page == the
    per-step forward_decode loop, token for token."""
    cfg = reduced_config("phi3_5_moe")
    prompts = [list(rng.integers(2, cfg.vocab_size, int(n)))
               for n in rng.integers(4, 12, 5)]
    max_out = [9, 4, 14, 6, 11]
    got_f, _ = _run(cfg, prompts, max_out, fused=True, max_active=4,
                    module_granularity=True, b_attn=2)
    got_l, _ = _run(cfg, prompts, max_out, fused=False, max_active=4,
                    module_granularity=True, b_attn=2)
    assert got_f == got_l


def test_one_transfer_per_decode_page(rng):
    """Transfer-spy: exactly ONE device->host copy per decode_page call."""
    cfg = reduced_config("llama3_2_1b")
    eng = NodeEngine(cfg, max_active=3, max_len=128, page_size=8, seed=0)
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8))
    sched.submit([[2, 3, 4, 5]] * 3, [20] * 3)

    calls = []
    in_page = [False]
    orig_decode, orig_to_host = eng.decode_page, eng._to_host

    def spy_to_host(arr):
        if in_page[0]:              # ignore prefill/sync transfers
            calls[-1] += 1
        return orig_to_host(arr)

    def spy_decode(active, P):
        calls.append(0)
        in_page[0] = True
        try:
            return orig_decode(active, P)
        finally:
            in_page[0] = False

    eng.decode_page, eng._to_host = spy_decode, spy_to_host
    rep = sched.run(max_ticks=300)
    assert rep["completed"] == 3
    assert calls and all(c == 1 for c in calls), calls


def test_megastep_direct_mid_page_mask():
    """T.decode_page with ragged `remaining`: a slot finishing mid-page
    stops advancing (lengths frozen, token frozen) while others continue;
    emitted rows match a hand-rolled decode_step loop."""
    cfg = reduced_config("qwen2_0_5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, steps = 3, 6
    cache = T.init_cache(cfg, B, 64)
    tokens = jnp.asarray([5, 9, 13], jnp.int32)
    lengths = jnp.asarray([3, 1, 0], jnp.int32)
    remaining = jnp.asarray([2, 6, 4], jnp.int32)

    block, tok_f, len_f, rem_f, _ = jax.jit(
        lambda c, t, l, r: T.decode_page(cfg, AXES, params, c, t, l, r,
                                         steps))(cache, tokens, lengths,
                                                 remaining)
    # reference: per-step loop with host-side masking
    c, t, l = T.init_cache(cfg, B, 64), tokens, lengths
    rem = np.asarray(remaining).copy()
    rows = []
    for _ in range(steps):
        nxt, c = T.decode_step(cfg, AXES, params, c, t, l)
        live = rem > 0
        t = jnp.where(jnp.asarray(live), nxt, t)
        l = l + jnp.asarray(live.astype(np.int32))
        rem -= live.astype(np.int32)
        rows.append(np.asarray(t))
    np.testing.assert_array_equal(np.asarray(block), np.stack(rows))
    np.testing.assert_array_equal(np.asarray(len_f),
                                  np.asarray(lengths) + [2, 6, 4])
    np.testing.assert_array_equal(np.asarray(rem_f), [0, 0, 0])
    np.testing.assert_array_equal(np.asarray(tok_f), np.asarray(t))


def test_module_subbatch_covers_ragged_batch(rng):
    """B % n_sub != 0 (B=5, b_attn=2): the sub-batch split used to drop
    the tail rows (looped: head IndexError; fused: scan carry shape
    mismatch).  Both paths must now cover every row; caches match the
    monolithic step up to the documented bf16 sub-batch rounding (exact
    token equality with monolithic is NOT guaranteed — sub-batched
    einsums can flip an argmax by 1-2 ulp)."""
    cfg = reduced_config("phi3_5_moe")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rt = ModuleRuntime(cfg, AXES, params)
    B = 5
    from repro.core.forward import _sub_slices
    for b, n in [(5, 2), (7, 3), (8, 4), (3, 1)]:
        sls = _sub_slices(b, n)
        assert sls[0].start == 0 and sls[-1].stop == b
        assert all(a.stop == c.start for a, c in zip(sls, sls[1:]))
    cache = T.init_cache(cfg, B, 64)
    toks = jnp.asarray(rng.integers(2, cfg.vocab_size, B), jnp.int32)
    lens = jnp.asarray(rng.integers(1, 16, B), jnp.int32)
    n_loop, c_loop = rt.forward_decode(toks, cache, lens, b_attn=2)
    _, c_mono = T.decode_step(cfg, AXES, params, cache, toks, lens)
    assert n_loop.shape == (B,)
    assert int(jnp.max(n_loop)) < T.padded_vocab(cfg)
    for name in c_loop:
        np.testing.assert_allclose(np.asarray(c_loop[name], np.float32),
                                   np.asarray(c_mono[name], np.float32),
                                   atol=6e-2)
    rem = jnp.full((B,), 4, jnp.int32)
    block, tok_p, len_p, rem_p, _ = rt.forward_decode_page(
        toks, cache, lens, rem, 2, 3)
    assert block.shape == (3, B)
    np.testing.assert_array_equal(np.asarray(len_p), np.asarray(lens) + 3)
    np.testing.assert_array_equal(np.asarray(block[2]), np.asarray(tok_p))


def test_prefill_jit_cache_bucketed_and_bounded(rng):
    """Prefill compilations bucket (B, S) to pow2 and evict beyond the
    LRU cap on long mixed workloads."""
    cfg = reduced_config("llama3_2_1b")
    # prefix reuse off: identical prompts would otherwise dedupe to one
    # fresh lead and this test is about the (B, S) bucketing of fresh work
    eng = NodeEngine(cfg, max_active=8, max_len=512, page_size=8,
                     enable_prefix=False)
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8))

    def prefill_batch(n_cos, plen):
        ids = sched.submit([[2] * plen] * n_cos, [1] * n_cos)
        eng.prefill([sched.cos[i] for i in ids])

    prefill_batch(3, 5)      # -> key (4, 8)
    prefill_batch(4, 7)      # same bucket, no new compile
    assert list(eng._prefill_cache) == [(4, 8)]
    for i, plen in enumerate([9, 17, 33, 65, 129, 250, 255, 31, 63]):
        prefill_batch(1 + i % 3, plen)
    assert len(eng._prefill_cache) <= _PREFILL_JIT_CAP
    assert all(b == 1 << (b - 1).bit_length() and s == 1 << (s - 1).bit_length()
               for b, s in eng._prefill_cache)


def test_host_store_consistent_after_fused_pages(rng):
    """The batched per-page sync must leave the host store byte-identical
    to what per-sequence slicing produced (restore == device cache)."""
    cfg = reduced_config("llama3_2_1b")
    eng = NodeEngine(cfg, max_active=2, max_len=64, page_size=8, seed=0)
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8))
    ids = sched.submit([[2, 3, 4], [5, 6, 7, 8]], [10, 13])
    cos = [sched.cos[i] for i in ids]
    for _ in range(2):
        sched._node_tick(0, eng)
    for co in cos:
        if co.slot is None or co.done:
            continue
        restored = eng.host_store.restore(co.seq_id, eng.max_len)
        for name, leaf in eng.cache.items():
            dev = np.asarray(leaf[:, co.slot, : co.length])
            np.testing.assert_array_equal(
                restored[name][:, : co.length].astype(dev.dtype), dev)
