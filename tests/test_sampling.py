"""On-device sampling subsystem: distribution correctness (chi-square vs a
NumPy reference), exact greedy parity with the PR 1 argmax megastep, the
one-transfer-per-page contract under sampling, and per-sequence seed
reproducibility across batch composition and mid-stream migration."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from scipy import stats as sp_stats

from repro import sampling as S
from repro.configs import default_sampling, reduced_config
from repro.core import primitives as prim
from repro.core.scheduler import CoroutineScheduler, SchedulerConfig
from repro.runtime.engine import NodeEngine
from repro.sampling import SamplingParams


# ---------------------------------------------------------------------------
# NumPy reference sampler (distribution ground truth)
# ---------------------------------------------------------------------------


def ref_probs(logits, *, temperature=1.0, top_k=0, top_p=1.0, min_p=0.0):
    """Reference distribution: temperature -> top-k -> top-p -> min-p,
    same processor order as repro.sampling.processors."""
    l = np.asarray(logits, np.float64) / temperature
    if top_k > 0:
        kth = np.sort(l)[::-1][min(top_k, len(l)) - 1]
        l = np.where(l >= kth, l, -np.inf)
    if top_p < 1.0:
        order = np.argsort(l)[::-1]
        p = np.exp(l[order] - np.max(l))
        p /= p.sum()
        cum_excl = np.cumsum(p) - p
        kept_logit = l[order][cum_excl < top_p]
        l = np.where(l >= kept_logit.min(), l, -np.inf)
    if min_p > 0.0:
        p = np.exp(l - np.nanmax(np.where(np.isfinite(l), l, np.nan)))
        p = p / np.nansum(np.where(np.isfinite(l), p, 0.0))
        pm = np.where(np.isfinite(l), p, 0.0)
        l = np.where(pm >= min_p * pm.max(), l, -np.inf)
    p = np.exp(l - np.max(l[np.isfinite(l)]))
    p[~np.isfinite(l)] = 0.0
    return p / p.sum()


def draw_many(logits, sp_kwargs, n, seed=0):
    """Draw n tokens from sample(): one slot per draw, key =
    fold_in(PRNGKey(seed), i) — exactly the megastep's key discipline."""
    V = len(logits)
    row = {"temperature": 1.0, "top_k": 0, "top_p": 1.0, "min_p": 0.0,
           "repetition_penalty": 1.0, "presence_penalty": 0.0,
           "frequency_penalty": 0.0}
    row.update(sp_kwargs)
    sp = {k: jnp.full((n,), v, jnp.int32 if k == "top_k" else jnp.float32)
          for k, v in row.items()}
    keys = S.step_keys(S.base_keys(np.full((n,), seed, np.uint32)),
                       jnp.arange(n, dtype=jnp.int32))
    zeros = jnp.zeros((n, V), jnp.int32)
    toks = S.sample(jnp.broadcast_to(jnp.asarray(logits, jnp.float32),
                                     (n, V)),
                    zeros, zeros, sp, keys)
    return np.asarray(toks)


def chi_square_check(tokens, probs, alpha=1e-3):
    """Pearson chi-square of observed token counts vs expected; also
    asserts no draw landed on a zero-probability token."""
    n = len(tokens)
    obs = np.bincount(tokens, minlength=len(probs)).astype(np.float64)
    assert obs[probs == 0].sum() == 0, "drew a filtered (p=0) token"
    exp = n * probs
    live = exp > 0
    chi2 = float(((obs[live] - exp[live]) ** 2 / exp[live]).sum())
    df = int(live.sum()) - 1
    crit = float(sp_stats.chi2.ppf(1 - alpha, df))
    assert chi2 < crit, f"chi2={chi2:.1f} >= crit={crit:.1f} (df={df})"


@pytest.fixture(scope="module")
def fixed_logits():
    return np.random.default_rng(7).normal(0.0, 2.0, 24)


N_DRAWS = 4000


def test_chi_square_temperature(fixed_logits):
    # seed is an arbitrary fixture: 1 lands on a p~2e-4 draw for the
    # token-addressed noise stream (verified unbiased: mean chi2 == df
    # over 36 seeds); 11 is an unremarkable one
    for temp in (0.5, 1.0, 1.7):
        toks = draw_many(fixed_logits, {"temperature": temp}, N_DRAWS,
                         seed=11)
        chi_square_check(toks, ref_probs(fixed_logits, temperature=temp))


def test_chi_square_top_k(fixed_logits):
    toks = draw_many(fixed_logits, {"temperature": 1.0, "top_k": 5},
                     N_DRAWS, seed=2)
    chi_square_check(toks, ref_probs(fixed_logits, top_k=5))


def test_chi_square_top_p(fixed_logits):
    toks = draw_many(fixed_logits, {"temperature": 0.9, "top_p": 0.7},
                     N_DRAWS, seed=3)
    chi_square_check(toks, ref_probs(fixed_logits, temperature=0.9,
                                     top_p=0.7))


def test_chi_square_combined(fixed_logits):
    kw = {"temperature": 0.8, "top_k": 10, "top_p": 0.9, "min_p": 0.02}
    toks = draw_many(fixed_logits, kw, N_DRAWS, seed=4)
    chi_square_check(toks, ref_probs(fixed_logits, **kw))


# ---------------------------------------------------------------------------
# processor unit properties
# ---------------------------------------------------------------------------


def test_default_pipeline_is_exact_identity(fixed_logits):
    """SamplingParams() processors must be a BITWISE identity — this is
    what makes temperature=0 reproduce PR 1's argmax megastep."""
    row = {k: jnp.asarray(v)[0] for k, v in S.pack_params(
        [SamplingParams()], [0]).items() if k not in ("stop", "seed")}
    l = jnp.asarray(fixed_logits, jnp.float32)
    zeros = jnp.zeros((len(fixed_logits),), jnp.int32)
    out = S.process_logits(l, zeros, zeros, row)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(l))


def test_top_k_top_p_masks(fixed_logits):
    l = jnp.asarray(fixed_logits, jnp.float32)
    kept = np.isfinite(np.where(np.asarray(
        S.apply_top_k(l, jnp.asarray(5))) <= -1e29, -np.inf,
        np.asarray(S.apply_top_k(l, jnp.asarray(5)))))
    assert kept.sum() == 5
    assert set(np.flatnonzero(kept)) == set(np.argsort(fixed_logits)[-5:])
    out_p = np.asarray(S.apply_top_p(l, jnp.asarray(0.6)))
    ref = ref_probs(fixed_logits, top_p=0.6)
    assert set(np.flatnonzero(out_p > -1e29)) == set(np.flatnonzero(ref))


def test_penalties_shift_distribution():
    logits = jnp.zeros((8,), jnp.float32) + 1.0
    counts = jnp.asarray([3, 1, 0, 0, 0, 0, 0, 0], jnp.int32)
    out = np.asarray(S.apply_penalties(logits, counts, counts,
                                       jnp.asarray(2.0), jnp.asarray(0.5),
                                       jnp.asarray(0.25)))
    # token 0: 1/2 - 0.25*3 - 0.5 = -0.75; token 1: 1/2 - 0.25 - 0.5
    np.testing.assert_allclose(out[0], -0.75, atol=1e-6)
    np.testing.assert_allclose(out[1], -0.25, atol=1e-6)
    np.testing.assert_allclose(out[2:], 1.0, atol=1e-6)
    # prompt-only occurrences: repetition applies, presence/frequency
    # must NOT (OpenAI/vLLM semantics penalize generated tokens only)
    zeros = jnp.zeros((8,), jnp.int32)
    out2 = np.asarray(S.apply_penalties(logits, counts, zeros,
                                        jnp.asarray(2.0), jnp.asarray(0.5),
                                        jnp.asarray(0.25)))
    np.testing.assert_allclose(out2[:2], 0.5, atol=1e-6)
    np.testing.assert_allclose(out2[2:], 1.0, atol=1e-6)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


def _run(cfg, prompts, max_out, sampling, *, page_size=8, max_active=3,
         fused=True, **kw):
    eng = NodeEngine(cfg, max_active=max_active, max_len=128,
                     page_size=page_size, seed=0, fused=fused, **kw)
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=page_size))
    ids = sched.submit(prompts, max_out, sampling=sampling)
    rep = sched.run(max_ticks=500)
    assert rep["completed"] == len(prompts)
    return [sched.cos[i] for i in ids], eng


@pytest.fixture(scope="module")
def dense_cfg():
    return reduced_config("llama3_2_1b")


@pytest.fixture(scope="module")
def prompts(dense_cfg):
    rng = np.random.default_rng(3)
    return [list(rng.integers(2, dense_cfg.vocab_size, int(n)))
            for n in rng.integers(4, 12, 4)]


def test_greedy_parity_temperature_zero(dense_cfg, prompts):
    """temperature=0 through the SAMPLED megastep (seed set, so the
    sampling pipeline runs) reproduces the greedy argmax megastep
    token-for-token — including the prefill-sampled first token."""
    max_out = [14, 6, 11, 9]
    greedy, _ = _run(dense_cfg, prompts, max_out, None)
    t0, _ = _run(dense_cfg, prompts, max_out,
                 SamplingParams(temperature=0.0, seed=99))
    assert [c.generated for c in t0] == [c.generated for c in greedy]


def test_greedy_parity_module_granularity():
    cfg = reduced_config("phi3_5_moe")
    rng = np.random.default_rng(5)
    prompts = [list(rng.integers(2, cfg.vocab_size, int(n)))
               for n in rng.integers(4, 10, 3)]
    greedy, _ = _run(cfg, prompts, [8, 5, 10], None, max_active=3,
                     module_granularity=True, b_attn=2)
    t0, _ = _run(cfg, prompts, [8, 5, 10],
                 SamplingParams(temperature=0.0, seed=13), max_active=3,
                 module_granularity=True, b_attn=2)
    assert [c.generated for c in t0] == [c.generated for c in greedy]


def test_sampled_fused_matches_looped(dense_cfg, prompts):
    """The per-token looped path and the fused megastep consume the SAME
    fold_in key stream -> identical sampled tokens."""
    sp = SamplingParams(temperature=0.9, top_k=40, top_p=0.95)
    f, ef = _run(dense_cfg, prompts, [12, 7, 9, 5], sp, fused=True)
    l, el = _run(dense_cfg, prompts, [12, 7, 9, 5], sp, fused=False)
    assert [c.generated for c in f] == [c.generated for c in l]
    assert ef.d2h_transfers < el.d2h_transfers


def test_sampled_one_transfer_per_decode_page(dense_cfg):
    """Transfer-spy: sampled decode (temperature>0, top-k/top-p active)
    still performs exactly ONE device->host copy per decode_page."""
    eng = NodeEngine(dense_cfg, max_active=3, max_len=128, page_size=8,
                     seed=0)
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8))
    sched.submit([[2, 3, 4, 5]] * 3, [20] * 3,
                 sampling=SamplingParams(temperature=0.8, top_k=30,
                                         top_p=0.9, seed=5))

    calls = []
    in_page = [False]
    orig_decode, orig_to_host = eng.decode_page, eng._to_host

    def spy_to_host(arr):
        if in_page[0]:
            calls[-1] += 1
        return orig_to_host(arr)

    def spy_decode(active, P):
        calls.append(0)
        in_page[0] = True
        try:
            return orig_decode(active, P)
        finally:
            in_page[0] = False

    eng.decode_page, eng._to_host = spy_decode, spy_to_host
    rep = sched.run(max_ticks=300)
    assert rep["completed"] == 3
    assert calls and all(c == 1 for c in calls), calls


def test_seed_reproducible_across_batch_composition(dense_cfg):
    """A fixed per-sequence seed yields the identical token stream whether
    the sequence decodes alone, with co-resident neighbours, or in a
    larger slot array — keys are fold_in(seed, t), never a function of
    batch shape or slot."""
    rng = np.random.default_rng(11)
    target = list(rng.integers(2, dense_cfg.vocab_size, 7))
    sp = SamplingParams(temperature=0.8, top_k=30, seed=123)

    def stream(extra, max_active):
        prompts = [target] + extra
        sps = [sp] + [SamplingParams(temperature=1.1, seed=50 + i)
                      for i in range(len(extra))]
        cos, _ = _run(dense_cfg, prompts, [16] * len(prompts), sps,
                      max_active=max_active)
        return cos[0].generated

    alone = stream([], 3)
    crowded = stream([list(rng.integers(2, dense_cfg.vocab_size, 5)),
                      list(rng.integers(2, dense_cfg.vocab_size, 9))], 3)
    wider = stream([list(rng.integers(2, dense_cfg.vocab_size, 6))], 4)
    assert alone == crowded == wider


def test_seed_reproducible_across_migration(dense_cfg):
    """YIELD -> MIGRATE -> COMBINE onto another node mid-stream: the
    sampled continuation is identical to the uninterrupted run (state is
    re-derived from seed + token count + token list at install)."""
    rng = np.random.default_rng(13)
    prompt = list(rng.integers(2, dense_cfg.vocab_size, 6))
    sp = SamplingParams(temperature=0.9, top_p=0.9, seed=77)

    baseline, _ = _run(dense_cfg, [prompt], [20], sp)
    baseline = baseline[0].generated

    engs = [NodeEngine(dense_cfg, node_id=i, max_active=3, max_len=128,
                       page_size=8, seed=0) for i in range(2)]
    sched = CoroutineScheduler(
        engs, SchedulerConfig(page_size=8, migrate_imbalance=10 ** 9))
    ids = sched.submit([prompt], [20], sampling=sp)
    co = sched.cos[ids[0]]
    sched._node_tick(0, engs[0])            # prefill + first page on node 0
    assert 0 < len(co.generated) < 20
    prim.yield_(co, engs[0])
    prim.migrate(co, engs[0], engs[1])
    prim.combine([co], engs[1])
    for _ in range(100):
        if co.done:
            break
        sched._node_tick(1, engs[1])        # finish on node 1
    assert co.done
    assert co.generated == baseline


def test_stop_tokens_truncate_and_finish(dense_cfg, prompts):
    sp = SamplingParams(temperature=0.9, top_k=50)
    base, _ = _run(dense_cfg, prompts, [16] * 4, sp)
    target = base[0].generated[4]
    sps = [SamplingParams(temperature=0.9, top_k=50, stop=(target,))] + \
        [sp] * 3
    cos, _ = _run(dense_cfg, prompts, [16] * 4, sps)
    idx = base[0].generated.index(target)
    assert cos[0].generated == base[0].generated[: idx + 1]
    assert cos[0].stopped and cos[0].finish_reason == "stop"
    assert cos[0].done
    # neighbours' streams unperturbed
    assert [c.generated for c in cos[1:]] == \
        [c.generated for c in base[1:]]


def test_per_sequence_mixed_configs(dense_cfg, prompts):
    """Greedy riders + different sampled configs share one megastep; the
    greedy rider matches the all-greedy run exactly."""
    greedy, _ = _run(dense_cfg, prompts, [10] * 4, None)
    sps = [SamplingParams(),                      # greedy rider
           default_sampling("llama3_2_1b", seed=1),
           SamplingParams(temperature=1.3, min_p=0.1, seed=2),
           SamplingParams(temperature=0.7, repetition_penalty=1.3, seed=3)]
    mixed, _ = _run(dense_cfg, prompts, [10] * 4, sps)
    assert mixed[0].generated == greedy[0].generated
    assert mixed[1].generated != greedy[1].generated


def test_logprob_plane_is_pre_filter_under_sampling(dense_cfg, prompts):
    """Regression for the (P, B, 2+2K) plane contract: top-K alternatives
    must be log-softmax of the RAW model logits, not of the filtered
    logits.  Under top_k=2 the third/fourth alternatives are outside the
    kept set — a post-filter plane would surface ~-1e30 for them."""
    sp = SamplingParams(temperature=0.9, top_k=2, seed=4)

    def run(fused):
        eng = NodeEngine(dense_cfg, max_active=4, max_len=128, page_size=8,
                         seed=0, fused=fused)
        sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8))
        ids = sched.submit(prompts, [8] * 4, sampling=sp, logprobs=True,
                           top_logprobs=4)
        assert sched.run(max_ticks=500)["completed"] == 4
        return [sched.cos[i] for i in ids]

    fused = run(True)
    for co in fused:
        for alts in co.top_token_logprobs:
            assert len(alts) == 4
            vals = [lp for _, lp in alts]
            assert all(v > -1e29 for v in vals), vals   # pre-filter values
            assert vals == sorted(vals, reverse=True)
    # and the fused on-device plane matches the looped host-side math
    looped = run(False)
    for f, l in zip(fused, looped):
        assert f.generated == l.generated
        np.testing.assert_allclose(f.token_logprobs, l.token_logprobs,
                                   rtol=1e-5, atol=1e-5)
        assert [[t for t, _ in alts] for alts in f.top_token_logprobs] == \
            [[t for t, _ in alts] for alts in l.top_token_logprobs]


def test_prefill_batched_gather_two_transfers(dense_cfg):
    """The prefill host-checkpoint is ONE batched blob transfer (plus the
    logits/sampled-token transfer) — not n_seqs * n_leaves slices."""
    eng = NodeEngine(dense_cfg, max_active=4, max_len=128, page_size=8,
                     seed=0)
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8))
    ids = sched.submit([[2, 3, 4], [5, 6, 7, 8], [9, 10]], [4] * 3)
    before = eng.d2h_transfers
    eng.prefill([sched.cos[i] for i in ids])
    assert eng.d2h_transfers - before == 2
    # host store holds the full prompt KV for every sequence
    for i in ids:
        co = sched.cos[i]
        assert eng.host_store.has(co.seq_id)
        rest = eng.host_store.restore(co.seq_id, eng.max_len)
        assert all(v.shape[1] == eng.max_len for v in rest.values())
