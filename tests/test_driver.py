"""Streaming job driver tests: bounded window, elastic replicas,
auto-drain on dead-letter, kill-and-resume byte-identity, and the
long-tail request stream that feeds it."""
import json
import os
import signal
import subprocess
import sys

import pytest

from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.core.scheduler import SchedulerConfig
from repro.data.pipeline import LongTailRequestStream
from repro.driver import (DriverConfig, JsonlRequestSource,
                          StreamingJobDriver, iter_custom_ids)
from repro.runtime.cluster import sim_node_group
from repro.runtime.faults import Fault, FaultPlan
from repro.runtime.ledger import SegmentedJobLedger

N = 400
WINDOW = 48


@pytest.fixture(scope="module")
def sim_parts():
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    plan = plan_lib.search_plan(cfg, hw, ctx=2048, new_tokens=1,
                                max_active=16)
    return cfg, hw, plan


def _factory(sim_parts):
    cfg, hw, plan = sim_parts

    def factory(rid):
        return sim_node_group(cfg, hw, nodes=2, first_node_id=rid * 100,
                              max_active=16, max_len=4096, page_size=64,
                              plan=plan)
    return factory


def _input(tmp_path, n=N, seed=11):
    p = str(tmp_path / "in.jsonl")
    LongTailRequestStream(n, seed=seed, mean_in=24,
                          mean_out=10).write_jsonl(p)
    return p


def _driver(tmp_path, inp, sim_parts, *, name="out", window=WINDOW,
            rotate_records=64, fault_plan_factory=None, max_rounds=10 ** 7):
    return StreamingJobDriver(
        inp, str(tmp_path / f"{name}.jsonl"), str(tmp_path / f"led_{name}"),
        _factory(sim_parts),
        cfg=DriverConfig(window=window, rotate_records=rotate_records,
                         max_rounds=max_rounds),
        sched_cfg=SchedulerConfig(page_size=64),
        fault_plan_factory=fault_plan_factory)


# ---------------------------------------------------------------------------
# long-tail request stream (data/pipeline.py)
# ---------------------------------------------------------------------------


def test_longtail_stream_deterministic_and_long_tailed(tmp_path):
    a = list(LongTailRequestStream(200, seed=3))
    b = list(LongTailRequestStream(200, seed=3))
    assert a == b, "same seed => identical requests"
    s = LongTailRequestStream(200, seed=3)
    assert s.request(17) == a[17], "request(i) is a pure function"
    assert [r["custom_id"] for r in a] == \
        [f"req-{i:08d}" for i in range(200)]
    outs = sorted(r["body"]["max_tokens"] for r in a)
    assert outs[-1] >= 4 * outs[len(outs) // 2], \
        "lognormal budgets must have a heavy tail (max >> median)"
    p = str(tmp_path / "in.jsonl")
    assert LongTailRequestStream(50, seed=1).write_jsonl(p) == 50
    assert list(iter_custom_ids(p)) == [f"req-{i:08d}" for i in range(50)]


def test_jsonl_source_bounded_take_and_skip(tmp_path):
    inp = _input(tmp_path, n=30)
    seen = {f"req-{i:08d}" for i in range(0, 30, 2)}   # pretend even done
    src = JsonlRequestSource(inp, skip=seen.__contains__).open()
    got = src.take(5)
    assert len(got) == 5 and not src.exhausted
    got += src.take(100)
    assert src.exhausted and src.skipped == 15
    assert [r.custom_id for r in got] == \
        sorted({f"req-{i:08d}" for i in range(1, 30, 2)})
    src.close()


# ---------------------------------------------------------------------------
# driver end-to-end
# ---------------------------------------------------------------------------


def test_driver_elastic_end_to_end(tmp_path, sim_parts):
    """Mid-job scale_up + requeue-drain: all requests complete exactly
    once, merged output is in input order, and the resident window never
    exceeds its bound."""
    inp = _input(tmp_path)
    drv = _driver(tmp_path, inp, sim_parts)
    acts = {}

    def hook(d, rnd):
        if rnd == 3 and "up" not in acts:
            acts["up"] = d.scale_up()
        if rnd == 6 and "drain" not in acts and len(d._open_replicas()) > 1:
            acts["drain"] = d.drain(d.replicas[0].rid, requeue=True)

    res = drv.run(on_round=hook)
    assert res.status == "completed"
    assert res.merged_records == N, "drain must lose zero requests"
    assert res.scale_ups == 1 and "drain" in acts
    assert res.peak_resident <= WINDOW
    with open(res.merged_path) as f:
        cids = [json.loads(l)["custom_id"] for l in f]
    assert cids == [f"req-{i:08d}" for i in range(N)], "input order"
    rep = res.report
    assert rep["completed"] == N
    assert set(rep["scheduler_reports"]) == {r.rid for r in drv.replicas}
    # merged robustness counters: sums + per-replica node lists
    assert rep["robustness"]["transfer"]["dead_letters"] == 0
    assert rep["ledger"]["sealed_segments"] >= 2, "rotation exercised"


def test_driver_auto_drains_dead_lettered_replica(tmp_path, sim_parts):
    """A replica whose scheduler dead-letters a node is drained
    automatically; its unfinished requests requeue and the job still
    completes exactly once (first-wins ledger absorbs any race)."""
    inp = _input(tmp_path, n=120)

    def fpf(rid):
        if rid == 0:    # poison only the first replica
            return FaultPlan([Fault("transfer_fail", node=0, at_tick=2,
                                    count=99, transfer_kind="install")],
                             seed=0)
        return None

    drv = _driver(tmp_path, inp, sim_parts, name="auto",
                  fault_plan_factory=fpf)
    res = drv.run()
    assert res.status == "completed"
    assert res.merged_records == 120
    assert res.auto_drained >= 1, "dead-letter must trigger auto-drain"
    assert res.report["robustness"]["dead_letter_failovers"] >= 1
    # the poisoned replica is gone; a respawned/remaining one finished
    assert any(r.closed for r in drv.replicas)


def test_driver_graceful_drain_finishes_in_flight(tmp_path, sim_parts):
    inp = _input(tmp_path, n=80)
    drv = _driver(tmp_path, inp, sim_parts, name="grace")

    def hook(d, rnd):
        if rnd == 2 and d.scale_ups == 0:
            d.scale_up()
            d.drain(d.replicas[0].rid, requeue=False)

    res = drv.run(on_round=hook)
    assert res.status == "completed" and res.merged_records == 80
    assert res.requeued == 0, "graceful drain never requeues"
    assert drv.replicas[0].closed


def _scan_partials(ledger_root):
    """All committed partial records across every segment, per custom_id."""
    per = {}
    for f in sorted(os.listdir(ledger_root)):
        if not f.startswith("seg-"):
            continue
        for line in open(os.path.join(ledger_root, f), "rb").read() \
                .splitlines():
            try:
                rec = json.loads(line)
            except ValueError:
                continue            # torn tail line
            if rec.get("kind") == "partial":
                per.setdefault(rec["custom_id"], []).append(
                    (rec["off"], len(rec["tokens"])))
    return per


def _assert_no_overlap(per):
    for cid, blocks in per.items():
        covered = set()
        for off, n in blocks:
            span = set(range(off, off + n))
            assert not (covered & span), \
                f"duplicate partial coverage for {cid} at offset {off}"
            covered |= span


def test_segmented_ledger_partial_journal_exactly_once(tmp_path):
    """record_partial is exactly-once per token offset, survives rotation
    (seal snapshots) and reopen (tail replay), and a finished row
    supersedes the partial stream."""
    root = str(tmp_path / "led")
    led = SegmentedJobLedger(root, rotate_records=4).open()
    assert led.record_partial("a", 0, [1, 2, 3])
    assert not led.record_partial("a", 0, [1, 2, 3]), "replayed prefix"
    assert not led.record_partial("a", 2, [9]), "offset inside committed"
    assert led.partial_duplicates_refused == 2
    assert led.record_partial("a", 3, [4, 5])
    assert led.record_output("a", {"custom_id": "a", "ok": True})
    assert not led.record_partial("a", 5, [6]), "finished row supersedes"
    # rotation carries partial progress through the seal snapshot
    assert led.record_partial("b", 0, [7] * 3)
    for i in range(4):
        led.record_output(f"fill-{i}", {"custom_id": f"fill-{i}"})
    assert led.sealed_segments >= 1
    led.close()
    led2 = SegmentedJobLedger(root, rotate_records=4).open()
    assert led2.replayed_segments <= 1, "reopen parses only the tail"
    assert not led2.record_partial("a", 0, [1]), "finished survives reopen"
    assert not led2.record_partial("b", 0, [7] * 3), \
        "a resumed recompute's replayed prefix must be refused"
    assert led2.record_partial("b", 3, [8])
    led2.close()
    _assert_no_overlap(_scan_partials(root))


def test_driver_kill_resume_no_duplicate_partials(tmp_path, sim_parts):
    """SIGKILL mid-job, resume in a fresh process: requeued recomputes
    replay their token streams from offset 0, but the durable journal
    must contain every token's partial record at most once."""
    inp = str(tmp_path / "in.jsonl")
    LongTailRequestStream(150, seed=3, mean_in=24,
                          mean_out=120).write_jsonl(inp)
    out = str(tmp_path / "killed.jsonl")
    led = str(tmp_path / "led_killed")
    worker = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                          "streaming_driver.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    args = [sys.executable, worker, "--worker", inp, out, led]
    p = subprocess.run(args + ["40"], capture_output=True, env=env)
    assert p.returncode == -signal.SIGKILL, p.stderr.decode()[-2000:]
    assert _scan_partials(led), "killed run must have journaled partials"
    p = subprocess.run(args + ["-1"], capture_output=True, env=env)
    assert p.returncode == 0, p.stderr.decode()[-2000:]
    info = json.loads(p.stdout.decode().strip().splitlines()[-1])
    assert info["status"] == "completed" and info["merged"] == 150
    per = _scan_partials(led)
    _assert_no_overlap(per)


def test_driver_kill_resume_byte_identical(tmp_path, sim_parts):
    """SIGKILL mid-job, then resume with a fresh process: merged output
    is byte-identical to the uninterrupted run and only the ledger's
    tail segment is replayed."""
    inp = _input(tmp_path, n=300, seed=9)
    clean = _driver(tmp_path, inp, sim_parts, name="clean").run()
    assert clean.status == "completed"
    clean_bytes = open(clean.merged_path, "rb").read()

    out = str(tmp_path / "killed.jsonl")
    led = str(tmp_path / "led_killed")
    worker = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                          "streaming_driver.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    args = [sys.executable, worker, "--worker", inp, out, led]
    p = subprocess.run(args + ["100"], capture_output=True, env=env)
    assert p.returncode == -signal.SIGKILL, p.stderr.decode()[-2000:]
    assert not os.path.exists(out), "killed run must not publish output"
    p = subprocess.run(args + ["-1"], capture_output=True, env=env)
    assert p.returncode == 0, p.stderr.decode()[-2000:]
    info = json.loads(p.stdout.decode().strip().splitlines()[-1])
    assert info["status"] == "completed" and info["merged"] == 300
    assert info["skipped"] > 0, "resume must skip journaled rows"
    assert info["replayed"] <= 1, "resume replays only the tail segment"
    assert open(out, "rb").read() == clean_bytes
