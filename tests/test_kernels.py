"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (deliverable c)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import ref_attention
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import ref_paged_attention
from repro.kernels.moe_gemm.ops import moe_ffn
from repro.kernels.moe_gemm.moe_gemm import (grouped_gemm_tpu,
                                             sort_tokens_by_expert)
from repro.kernels.moe_gemm.ref import ref_grouped_gemm, ref_moe_ffn
from repro.kernels.ssd_scan.ops import ssd_state_scan
from repro.kernels.ssd_scan.ref import ref_state_scan

TOL = {jnp.float32: 3e-5, jnp.bfloat16: 3e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,H,Hkv,dh,causal,window", [
    (2, 128, 4, 2, 64, True, 0),
    (1, 96, 2, 1, 32, True, 0),
    (2, 64, 4, 4, 16, False, 0),
    (1, 256, 2, 2, 32, True, 64),
    (1, 64, 8, 2, 128, True, 0),
])
def test_flash_attention_kernel(rng, dtype, B, Sq, H, Hkv, dh, causal,
                                window):
    q = jnp.asarray(rng.standard_normal((B, Sq, H, dh)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Sq, Hkv, dh)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Sq, Hkv, dh)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = ref_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,dh,page,maxp", [
    (4, 8, 2, 64, 16, 8),
    (2, 4, 4, 32, 8, 4),
    (3, 16, 8, 128, 32, 5),
    (1, 2, 1, 16, 8, 2),
])
def test_paged_attention_kernel(rng, dtype, B, H, Hkv, dh, page, maxp):
    npool = B * maxp + 3
    q = jnp.asarray(rng.standard_normal((B, H, dh)), dtype)
    kp = jnp.asarray(rng.standard_normal((npool, page, Hkv, dh)), dtype)
    vp = jnp.asarray(rng.standard_normal((npool, page, Hkv, dh)), dtype)
    table = jnp.asarray(rng.permutation(npool)[: B * maxp].reshape(B, maxp),
                        jnp.int32)
    lengths = jnp.asarray(rng.integers(1, page * maxp, B), jnp.int32)
    out = paged_attention(q, kp, vp, table, lengths, interpret=True)
    ref = ref_paged_attention(q.astype(jnp.float32),
                              kp.astype(jnp.float32),
                              vp.astype(jnp.float32), table, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,D,F,E", [(256, 64, 128, 4), (512, 32, 256, 8),
                                     (128, 128, 64, 2)])
def test_grouped_gemm_kernel(rng, dtype, T, D, F, E):
    x = jnp.asarray(rng.standard_normal((T, D)), dtype)
    w = jnp.asarray(rng.standard_normal((E, D, F)) * 0.1, dtype)
    be = jnp.asarray(rng.integers(0, E, T // 128), jnp.int32)
    out = grouped_gemm_tpu(x, w, be, block_t=128, interpret=True)
    ref = ref_grouped_gemm(x.astype(jnp.float32), w.astype(jnp.float32), be)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=max(TOL[dtype] * D, 1e-3), rtol=5e-2)


@pytest.mark.parametrize("T,D,F,E,k", [(64, 32, 64, 4, 2),
                                       (96, 64, 256, 16, 4)])
def test_moe_ffn_kernel(rng, T, D, F, E, k):
    x = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    ids = jnp.asarray(np.stack([rng.permutation(E)[:k] for _ in range(T)]),
                      jnp.int32)
    vals = jnp.asarray(rng.random((T, k)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((E, D, F)) * 0.1, jnp.float32)
    w3 = jnp.asarray(rng.standard_normal((E, D, F)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((E, F, D)) * 0.1, jnp.float32)
    out = moe_ffn(x, ids, vals, w1, w3, w2, num_experts=E, interpret=True)
    ref = ref_moe_ffn(x, ids, vals, w1, w3, w2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-3)


def test_sort_tokens_roundtrip(rng):
    T, D, E = 50, 8, 4
    x = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, E, T), jnp.int32)
    xs, be, slot_of, order, valid = sort_tokens_by_expert(x, ids, E,
                                                          block_t=16)
    # every original token recoverable from its slot
    np.testing.assert_allclose(np.asarray(xs[slot_of]), np.asarray(x))
    # block expert map consistent with occupied slots
    occ = np.asarray(valid).reshape(-1, 16)
    be_np = np.asarray(be)
    ids_np = np.asarray(ids)
    slot_np = np.asarray(slot_of)
    for t in range(T):
        assert be_np[slot_np[t] // 16] == ids_np[t]


@pytest.mark.parametrize("B,H,nc,N,P", [(2, 4, 8, 16, 8), (1, 2, 16, 32, 16)])
def test_ssd_scan_kernel(rng, B, H, nc, N, P):
    s = jnp.asarray(rng.standard_normal((B, H, nc, N, P)), jnp.float32)
    d = jnp.asarray(rng.random((B, H, nc)) * 0.9, jnp.float32)
    prev, fin = ssd_state_scan(s, d, interpret=True)
    rp, rf = ref_state_scan(s, d)
    np.testing.assert_allclose(np.asarray(prev), np.asarray(rp), atol=1e-5)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(rf), atol=1e-5)
