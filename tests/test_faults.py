"""Chaos tests (§5.6): deterministic fault injection, health-driven
recovery, transfer retry/dead-letter, and bitwise token parity between
fault-free and fault-injected runs on both engine families."""
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core import plan as plan_lib
from repro.core.scheduler import CoroutineScheduler, SchedulerConfig
from repro.runtime.cluster import Cluster, SimEngine, fixed_workload
from repro.runtime.engine import NodeEngine
from repro.runtime.faults import (Fault, FaultPlan, NodeFaults, RetryPolicy,
                                  TransferDeadLetter, TransferError,
                                  TransferTimeout, guarded_transfer)
from repro.sampling import SamplingParams


# ---------------------------------------------------------------------------
# guarded_transfer unit tests
# ---------------------------------------------------------------------------


class _Eng:
    """Minimal engine satisfying guarded_transfer's contract."""
    node_id = 0

    def __init__(self, faults=None, max_attempts=3):
        self.retry_policy = RetryPolicy(max_attempts=max_attempts,
                                        base_backoff_s=0.0, max_backoff_s=0.0)
        self.transfer_stats = {"retries": 0, "timeouts": 0, "dead_letters": 0}
        self.dead_lettered = False
        self.faults = faults


def _armed(*faults):
    nf = NodeFaults(faults)
    nf.advance(0)
    return nf


def test_guarded_transfer_retries_then_succeeds():
    eng = _Eng(_armed(Fault("transfer_fail", 0, 0, count=2)))
    assert guarded_transfer(eng, "stage", lambda: "ok") == "ok"
    assert eng.transfer_stats["retries"] == 2
    assert eng.transfer_stats["dead_letters"] == 0
    assert not eng.dead_lettered


def test_guarded_transfer_timeout_counted():
    eng = _Eng(_armed(Fault("transfer_timeout", 0, 0, count=1)))
    assert guarded_transfer(eng, "drain", lambda: 7) == 7
    assert eng.transfer_stats["timeouts"] == 1
    assert eng.transfer_stats["retries"] == 1


def test_guarded_transfer_dead_letters_after_budget():
    eng = _Eng(_armed(Fault("transfer_fail", 0, 0, count=10)),
               max_attempts=3)
    calls = []
    with pytest.raises(TransferDeadLetter) as ei:
        guarded_transfer(eng, "install", lambda: calls.append(1))
    assert ei.value.node == 0 and ei.value.kind == "install"
    assert eng.dead_lettered
    assert eng.transfer_stats["dead_letters"] == 1
    assert eng.transfer_stats["retries"] == 3
    # injected faults are raised BEFORE fn runs: a donated-buffer copy is
    # never re-invoked on an attempt the injector already failed
    assert calls == []


def test_guarded_transfer_real_failure_retries():
    eng = _Eng(faults=None)
    state = {"n": 0}

    def flaky():
        state["n"] += 1
        if state["n"] < 3:
            raise TransferError("nic hiccup")
        return "done"

    assert guarded_transfer(eng, "migrate", flaky) == "done"
    assert eng.transfer_stats["retries"] == 2


def test_guarded_transfer_backoff_is_bounded_and_callback_driven():
    pol = RetryPolicy(max_attempts=6, base_backoff_s=1e-3,
                      max_backoff_s=4e-3)
    assert [pol.backoff(a) for a in range(5)] == \
        [1e-3, 2e-3, 4e-3, 4e-3, 4e-3]
    eng = _Eng(_armed(Fault("transfer_fail", 0, 0, count=2)))
    eng.retry_policy = pol
    waits = []
    guarded_transfer(eng, "stage", lambda: None, on_backoff=waits.append)
    assert waits == [1e-3, 2e-3]


# ---------------------------------------------------------------------------
# FaultPlan determinism
# ---------------------------------------------------------------------------


def test_fault_plan_random_is_seed_deterministic():
    a = FaultPlan.random(17, nodes=4, horizon=10, n_faults=6)
    b = FaultPlan.random(17, nodes=4, horizon=10, n_faults=6)
    assert a.describe() == b.describe() and len(a) == 6
    c = FaultPlan.random(18, nodes=4, horizon=10, n_faults=6)
    assert a.describe() != c.describe()


def test_fault_plan_random_keeps_a_survivor():
    for seed in range(8):
        plan = FaultPlan.random(seed, nodes=3, horizon=8, n_faults=12,
                                kinds=("node_death",))
        dead = {f.node for f in plan.faults if f.kind == "node_death"}
        assert len(dead) <= 2, "every chaos run must keep a survivor"


def test_node_faults_arm_by_tick_and_window():
    nf = NodeFaults([Fault("straggler", 0, at_tick=2, duration=2,
                           factor=8.0),
                     Fault("oom", 0, at_tick=3, duration=1),
                     Fault("stale_heartbeat", 0, at_tick=1, duration=2)])
    nf.advance(0)
    assert nf.straggler_factor() == 1.0 and not nf.heartbeat_suppressed()
    nf.advance(1)
    assert nf.heartbeat_suppressed()
    nf.advance(2)
    assert nf.straggler_factor() == 8.0 and nf.heartbeat_suppressed()
    nf.advance(3)
    assert nf.oom_active() and not nf.heartbeat_suppressed()
    nf.advance(5)
    assert nf.straggler_factor() == 1.0 and not nf.oom_active()


# ---------------------------------------------------------------------------
# SimEngine chaos: scheduler-level recovery + parity
# ---------------------------------------------------------------------------


def _sim_run(fault_plan, n=24, out_len=256):
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    cl = Cluster(cfg, hw, nodes=3, max_active=16, max_len=4096,
                 fault_plan=fault_plan)
    wl = fixed_workload(n, 128, out_len)
    ids = cl.sched.submit(wl.prompts, wl.max_out)
    rep = cl.sched.run(max_ticks=50000)
    toks = {i: list(cl.sched.cos[i].generated) for i in ids}
    return cl, rep, toks


def test_sim_chaos_parity_bitwise():
    """Node death + transfer timeouts + a straggler: the run completes and
    every sequence's tokens are bitwise identical to the fault-free run."""
    plan = FaultPlan([
        Fault("node_death", node=2, at_tick=2),
        Fault("transfer_timeout", node=0, at_tick=1, count=2,
              transfer_kind="drain"),
        Fault("straggler", node=1, at_tick=1, duration=3, factor=4.0),
    ], seed=0)
    _, rep0, toks0 = _sim_run(None)
    cl, rep1, toks1 = _sim_run(plan)
    assert rep0["completed"] == rep1["completed"] == 24
    assert toks1 == toks0, "chaos must not change a single token"
    rb = rep1["robustness"]
    assert 2 in rb["failed_nodes"]
    assert rb["health_failovers"] >= 1
    assert rb["transfer"]["retries"] >= 2
    assert rb["transfer"]["timeouts"] >= 2
    assert cl.engines[1].straggler_steps > 0


def test_sim_chaos_replay_from_seed():
    """The same seeded chaos matrix replays to the identical outcome."""
    mk = lambda: FaultPlan.random(23, nodes=3, horizon=8, n_faults=5)
    _, rep_a, toks_a = _sim_run(mk())
    _, rep_b, toks_b = _sim_run(mk())
    assert toks_a == toks_b
    assert rep_a["robustness"] == rep_b["robustness"]
    assert rep_a["completed"] == rep_b["completed"] == 24


def test_sim_stale_heartbeat_triggers_health_failover():
    plan = FaultPlan([Fault("stale_heartbeat", node=1, at_tick=1,
                            duration=8)])
    cl, rep, _ = _sim_run(plan)
    rb = rep["robustness"]
    assert rep["completed"] == 24
    assert rb["health_failovers"] >= 1 and 1 in rb["failed_nodes"]


def test_sim_dead_letter_escalates_to_node_failure():
    """A transfer that exhausts its retry budget dead-letters and the
    scheduler escalates the node through the SAME NODE_FAILURE path."""
    plan = FaultPlan([Fault("transfer_fail", node=0, at_tick=1, count=64,
                            transfer_kind="any")])
    cl, rep, _ = _sim_run(plan)
    rb = rep["robustness"]
    assert rep["completed"] == 24
    assert rb["dead_letter_failovers"] >= 1 and 0 in rb["failed_nodes"]
    assert rb["transfer"]["dead_letters"] >= 1


def test_sim_straggler_alone_never_escalates_to_failure():
    """Slow is not dead: a straggler-only fault plan must never trip the
    HealthMonitor into NODE_FAILURE — its heartbeats still arrive.  Only
    stale_heartbeat / node_death / dead-letters may escalate."""
    plan = FaultPlan.straggler(1, factor=8.0)       # slow forever
    cl, rep, _ = _sim_run(plan)
    rb = rep["robustness"]
    assert rep["completed"] == 24
    assert rb["health_failovers"] == 0 and rb["dead_letter_failovers"] == 0
    assert rb["failed_nodes"] == [], \
        "a slow node must stay in rotation, never be declared dead"
    assert cl.engines[1].straggler_steps > 0, "fault actually armed"


def test_sim_oom_fault_counts_rejections():
    # oversubscribe (64 seqs > 48 slots) so refill admissions land inside
    # the allocator-pressure window and get refused
    plan = FaultPlan([Fault("oom", node=0, at_tick=1, duration=10)])
    cl, rep, _ = _sim_run(plan, n=64)
    assert rep["completed"] == 64
    assert cl.engines[0].oom_rejections > 0


# ---------------------------------------------------------------------------
# NodeEngine chaos: real-engine parity (greedy + seeded sampling)
# ---------------------------------------------------------------------------


def _real_run(fault_plan):
    cfg = reduced_config("llama3_2_1b")
    rng = np.random.default_rng(5)
    engines = [NodeEngine(cfg, node_id=i, max_active=3, max_len=64,
                          page_size=8, seed=0) for i in range(2)]
    sched = CoroutineScheduler(engines, SchedulerConfig(page_size=8),
                               fault_plan=fault_plan)
    prompts = [list(rng.integers(2, 100, 5)) for _ in range(6)]
    sps = [SamplingParams() if i % 2 == 0
           else SamplingParams(temperature=0.8, top_k=20, seed=40 + i)
           for i in range(6)]
    ids = sched.submit(prompts, [24] * 6, sampling=sps)
    rep = sched.run(max_ticks=2000)
    return sched, rep, {i: list(sched.cos[i].generated) for i in ids}


def test_node_engine_chaos_parity_bitwise():
    """Real engines under injected node death + stage-transfer retries +
    a straggler window still produce bitwise-identical tokens (greedy AND
    seeded-sampled rows) and finish every sequence."""
    plan = FaultPlan([
        Fault("node_death", node=1, at_tick=1),
        Fault("transfer_fail", node=0, at_tick=1, count=2,
              transfer_kind="stage"),
        Fault("straggler", node=0, at_tick=1, duration=2, factor=2.0),
    ], seed=1)
    _, rep0, toks0 = _real_run(None)
    sched, rep1, toks1 = _real_run(plan)
    assert rep0["completed"] == rep1["completed"] == 6
    assert toks1 == toks0, \
        "failover recompute/migrate must reproduce the exact token streams"
    rb = rep1["robustness"]
    assert 1 in rb["failed_nodes"] and rb["health_failovers"] >= 1
    assert rb["transfer"]["retries"] >= 2
