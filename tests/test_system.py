"""End-to-end system behaviour tests (replaces the scaffold placeholder):
every assigned architecture instantiates, trains one step, prefann
serves — on its reduced config (deliverable f smoke tests)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.configs import ARCH_IDS, ASSIGNED, reduced_config
from repro.models import transformer as T
from repro.models.api import MeshAxes, SHAPES, shape_applicable

AXES = MeshAxes()


def _batch(cfg, rng, B=2, S=32):
    tokens = jnp.asarray(rng.integers(2, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((B, cfg.num_patches, cfg.d_model),
                                    jnp.bfloat16)
        batch["labels"] = jnp.concatenate(
            [jnp.full((B, cfg.num_patches), -1, jnp.int32), tokens], 1)
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model),
                                   jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_prefill_decode(arch, rng):
    """One forward/train loss + prefill + 3 decode steps; shapes + no NaNs."""
    cfg = reduced_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    loss = T.forward_loss(cfg, AXES, params, batch, remat=False)
    assert np.isfinite(float(loss))
    logits, cache = T.prefill(cfg, AXES, params, batch)
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    assert bool(jnp.all(jnp.isfinite(logits)))
    dc = T.init_cache(cfg, 2, 64)
    toks = jnp.zeros((2,), jnp.int32)
    lens = jnp.zeros((2,), jnp.int32)
    for _ in range(3):
        toks, dc = T.decode_step(cfg, AXES, params, dc, toks, lens)
        lens = lens + 1
        assert toks.shape == (2,)
        assert int(toks.max()) < T.padded_vocab(cfg)


@pytest.mark.parametrize("arch", ["llama3_2_1b", "qwen3_moe_30b",
                                  "mamba2_370m", "recurrentgemma_2b",
                                  "whisper_base"])
def test_train_step_reduces_loss(arch, rng):
    """A few AdamW steps on a fixed batch must reduce the loss."""
    cfg = reduced_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng, B=2, S=16)
    ocfg = optim.AdamWConfig(lr=3e-3, zero1=False, weight_decay=0.0)
    opt = optim.init_opt_state(params, n_dev=1)

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(
            lambda p: T.forward_loss(cfg, AXES, p, batch, remat=False))(params)
        params, opt, _ = optim.apply_updates(ocfg, params, grads, opt, 1)
        return params, opt, loss

    losses = []
    for _ in range(8):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses


def test_shape_applicability_matrix():
    """40 assigned cells; long_500k only for sub-quadratic archs."""
    cells = [(a, s) for a in ASSIGNED for s in SHAPES]
    assert len(cells) == 40
    runnable = [c for c in cells
                if shape_applicable(reduced_config(c[0]).__class__ and
                                    __import__("repro.configs",
                                               fromlist=["get_config"])
                                    .get_config(c[0]), SHAPES[c[1]])[0]]
    assert len(runnable) == 33
    long_ok = {a for a, s in runnable if s == "long_500k"}
    assert long_ok == {"h2o_danube_1_8b", "mamba2_370m", "recurrentgemma_2b"}
