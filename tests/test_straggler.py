"""Straggler mitigation tests: ProgressTracker detection (EWMA +
hysteresis + cooldown), NODE_SLOW shedding, hedged tail re-execution,
per-request deadlines, and the driver-tier slow-replica auto-drain — all
validated for bitwise token parity against fault-free runs."""
import json

import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core import plan as plan_lib
from repro.core.scheduler import (CoroutineScheduler, SchedulerConfig)
from repro.data.pipeline import LongTailRequestStream
from repro.driver import DriverConfig, StreamingJobDriver
from repro.runtime.api import BatchMaster, BatchRequest
from repro.runtime.cluster import Cluster, fixed_workload, sim_node_group
from repro.runtime.engine import NodeEngine
from repro.runtime.failure import Heartbeat, ProgressTracker
from repro.runtime.faults import Fault, FaultPlan
from repro.sampling import SamplingParams

from test_driver import _assert_no_overlap, _scan_partials  # noqa: F401


# ---------------------------------------------------------------------------
# ProgressTracker unit tests (synthetic heartbeats)
# ---------------------------------------------------------------------------


def _beat(tr, rnd, cumulative):
    """Feed one round of cumulative token counters, return newly flagged."""
    for node, tot in cumulative.items():
        tr.observe(Heartbeat(node=node, t=float(rnd), devices=[],
                             tokens=float(tot)))
    return tr.evaluate(rnd, list(cumulative))


def test_progress_tracker_flags_then_recovers():
    tr = ProgressTracker(slow_fraction=0.5, slow_rounds=3, cooldown=0,
                         recover_fraction=0.8, ewma_alpha=1.0)
    tot = {0: 0.0, 1: 0.0, 2: 0.0}
    newly = []
    for rnd in range(1, 6):
        tot = {0: tot[0] + 2, 1: tot[1] + 10, 2: tot[2] + 10}
        newly.append(_beat(tr, rnd, tot))
    # first beat is baseline-only; streak builds over rounds 2-4
    assert newly == [[], [], [], [0], []], "flag exactly once, on round 4"
    assert tr.is_flagged(0) and tr.flags_raised == 1
    assert tr.deficit(0) == pytest.approx(0.8)
    # hysteresis: node 0 speeds back up, clears above recover_fraction
    for rnd in range(6, 9):
        tot = {0: tot[0] + 10, 1: tot[1] + 10, 2: tot[2] + 10}
        assert _beat(tr, rnd, tot) == []
    assert not tr.is_flagged(0) and tr.flags_cleared == 1


def test_progress_tracker_cooldown_blocks_reflag():
    tr = ProgressTracker(slow_fraction=0.5, slow_rounds=1, cooldown=6,
                         recover_fraction=0.8, ewma_alpha=1.0)
    tot = {0: 0.0, 1: 0.0}
    tot = {0: 2.0, 1: 20.0}
    _beat(tr, 1, tot)
    tot = {0: 4.0, 1: 40.0}
    assert _beat(tr, 2, tot) == [0]
    tr.start_cooldown(0, 2)                     # as the shed handler does
    tr.flagged[0] = False                       # node recovered post-shed
    flagged_at = None
    for rnd in range(3, 12):
        tot = {0: tot[0] + 2, 1: tot[1] + 20}
        if _beat(tr, rnd, tot) == [0]:
            flagged_at = rnd
            break
    assert flagged_at == 8, "cooldown must hold re-flag until round 2+6"


def test_progress_tracker_idle_is_not_slow():
    tr = ProgressTracker(slow_fraction=0.5, slow_rounds=3, cooldown=0,
                         ewma_alpha=1.0)
    tot = {0: 0.0, 1: 0.0}
    for rnd in range(1, 4):                     # 2 deficient deltas
        tot = {0: tot[0] + 2, 1: tot[1] + 20}
        assert _beat(tr, rnd, tot) == []
    assert _beat(tr, 4, dict(tot)) == [], \
        "an idle round (no new tokens) is not evidence of slowness"
    tot = {0: tot[0] + 2, 1: tot[1] + 20}
    assert _beat(tr, 5, tot) == [0], "streak must survive the idle round"


def test_progress_tracker_single_node_never_flags():
    tr = ProgressTracker(slow_rounds=1, ewma_alpha=1.0)
    tot = {0: 0.0}
    for rnd in range(1, 8):
        tot = {0: tot[0] + 1}
        assert _beat(tr, rnd, tot) == []
    assert tr.median_rate() is None, "a fleet of one has no peers to lag"


# ---------------------------------------------------------------------------
# SimEngine: detect -> shed, A/B vs no mitigation, bitwise parity
# ---------------------------------------------------------------------------

N_SIM, OUT_SIM = 24, 2048


def _sim_run(fault_plan, sched_cfg=None, place=None):
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    cl = Cluster(cfg, hw, nodes=3, max_active=16, max_len=4096,
                 fault_plan=fault_plan, sched_cfg=sched_cfg)
    wl = fixed_workload(N_SIM, 128, OUT_SIM)
    ids = cl.sched.submit(wl.prompts, wl.max_out)
    if place is not None:       # explicit placement policy (skewed load)
        for i, sid in enumerate(ids):
            cl.sched.cos[sid].node = place[i]
    rep = cl.sched.run(max_ticks=50000)
    toks = {i: list(cl.sched.cos[i].generated) for i in ids}
    return cl, rep, toks


@pytest.fixture(scope="module")
def sim_ab():
    """Fault-free baseline, mitigated straggler run, unmitigated run."""
    strag = lambda: FaultPlan.straggler(0, factor=4.0)
    free = _sim_run(None)
    on = _sim_run(strag())
    off = _sim_run(strag(), SchedulerConfig(page_size=64,
                                            mitigate_stragglers=False))
    return {"free": free, "on": on, "off": off}


def test_sim_straggler_detected_and_shed(sim_ab):
    _, rep, toks = sim_ab["on"]
    _, rep0, toks0 = sim_ab["free"]
    assert rep["completed"] == rep0["completed"] == N_SIM
    rb = rep["robustness"]
    assert rb["slow_flags"] >= 1, "4x straggler must be flagged"
    assert rb["sheds"] >= 1 and rb["shed_migrations"] >= 1
    assert toks == toks0, "shedding must not change a single token"


def test_sim_straggler_is_slow_not_dead(sim_ab):
    """The slow-vs-dead split: a straggler raises NODE_SLOW, never trips
    the HealthMonitor into NODE_FAILURE (its heartbeats still arrive)."""
    _, rep, _ = sim_ab["on"]
    rb = rep["robustness"]
    assert rb["health_failovers"] == 0 and rb["dead_letter_failovers"] == 0
    assert rb["failed_nodes"] == []


def test_sim_mitigation_beats_no_mitigation(sim_ab):
    """The acceptance gate: with a persistent 4x straggler, mitigation
    must cut batch completion time >= 1.3x (paper's tail-latency claim)
    while staying bitwise identical to the unmitigated run."""
    _, rep_on, toks_on = sim_ab["on"]
    _, rep_off, toks_off = sim_ab["off"]
    assert rep_off["robustness"]["slow_flags"] == 0, "kill switch works"
    assert toks_on == toks_off
    speedup = rep_off["bct_s"] / rep_on["bct_s"]
    assert speedup >= 1.3, \
        f"mitigation speedup {speedup:.2f}x under a 4x straggler"


# ---------------------------------------------------------------------------
# SimEngine: hedged tail re-execution (clone wins, loser retired clean)
# ---------------------------------------------------------------------------


def test_sim_hedge_winner_bitwise_and_loser_clean(sim_ab):
    """Pile 20 of 24 sequences on the slow node with shedding disabled:
    queued sequences get speculative clones on the fast nodes, clones
    finish first (the originals are still waiting for slots), and every
    surfaced result is bitwise identical to the fault-free run.  Losing
    racers must leave zero residue (slots, pages, host blobs, maps)."""
    place = [0] * 20 + [1, 2, 1, 2]
    cfg = SchedulerConfig(page_size=64, max_shed_fraction=0.0,
                          hedge_deadline_s=0.0)
    cl, rep, toks = _sim_run(FaultPlan.straggler(0, factor=4.0), cfg,
                             place=place)
    _, _, toks0 = sim_ab["free"]
    rb = rep["robustness"]
    assert rep["completed"] == N_SIM and rep["total"] == N_SIM
    assert rb["sheds"] == 0, "max_shed_fraction=0 must disable shedding"
    assert rb["hedges"]["launched"] >= 1
    assert rb["hedges"]["won"] >= 1, "a queued original must lose the race"
    assert rb["hedges"]["won"] + rb["hedges"]["lost"] == \
        rb["hedges"]["launched"]
    assert toks == toks0, \
        "hedged winners must reproduce the original token streams"
    # loser cleanup: no live clones, no orphaned residency anywhere
    assert cl.sched.hedged == {} and cl.sched.hedge_origin == {}
    for e in cl.engines:
        assert dict(e.allocator.owned) == {}
        assert not e.host_store.seqs
        assert all(s is None for s in e.slot_owner)


# ---------------------------------------------------------------------------
# graceful deadline degradation (both engine families)
# ---------------------------------------------------------------------------


def test_sim_deadline_truncates_gracefully():
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    cl = Cluster(cfg, hw, nodes=3, max_active=16, max_len=4096)
    wl = fixed_workload(12, 128, 256)
    ids = cl.sched.submit(wl.prompts, wl.max_out,
                          sampling=SamplingParams(deadline_s=0.0))
    rep = cl.sched.run(max_ticks=5000)
    assert rep["status"] == "completed" and rep["completed"] == 12
    for i in ids:
        co = cl.sched.cos[i]
        assert co.finish_reason == "deadline"
        assert 1 <= len(co.generated) < 256, \
            "a deadline truncates output; it never returns empty"


def test_real_deadline_truncates_gracefully(rng):
    cfg = reduced_config("llama3_2_1b")
    engines = [NodeEngine(cfg, node_id=i, max_active=3, max_len=64,
                          page_size=8, seed=0) for i in range(2)]
    sched = CoroutineScheduler(engines, SchedulerConfig(page_size=8))
    prompts = [list(rng.integers(2, 100, 5)) for _ in range(4)]
    ids = sched.submit(prompts, [24] * 4,
                       sampling=SamplingParams(deadline_s=0.0))
    rep = sched.run(max_ticks=500)
    assert rep["status"] == "completed" and rep["completed"] == 4
    for i in ids:
        co = sched.cos[i]
        assert co.finish_reason == "deadline"
        assert 1 <= len(co.generated) < 24


def test_batch_api_deadline_row():
    req = BatchRequest.from_dict({
        "custom_id": "slo", "body": {"prompt": [5, 6, 7], "max_tokens": 16,
                                     "deadline_s": 0.0}})
    assert req.sampling.deadline_s == 0.0
    assert BatchRequest.from_dict(
        {"custom_id": "x", "body": {"prompt": [1]}}).sampling.deadline_s \
        is None
    cfg = reduced_config("llama3_2_1b")
    eng = NodeEngine(cfg, max_active=3, max_len=64, page_size=8)
    master = BatchMaster([eng], SchedulerConfig(page_size=8))
    bo = master.run(master.submit([req]))
    assert bo.status == "completed"
    row = bo.results[0]["response"]
    assert row["finish_reason"] == "deadline"
    assert 1 <= len(row["tokens"]) < 16


# ---------------------------------------------------------------------------
# NodeEngine: real-engine straggler detection + parity
# ---------------------------------------------------------------------------


def _real_run(fault_plan):
    cfg = reduced_config("llama3_2_1b")
    rng = np.random.default_rng(5)
    engines = [NodeEngine(cfg, node_id=i, max_active=3, max_len=96,
                          page_size=8, seed=0) for i in range(2)]
    sched = CoroutineScheduler(engines, SchedulerConfig(page_size=8),
                               fault_plan=fault_plan)
    prompts = [list(rng.integers(2, 100, 5)) for _ in range(6)]
    sps = [SamplingParams() if i % 2 == 0
           else SamplingParams(temperature=0.8, top_k=20, seed=40 + i)
           for i in range(6)]
    ids = sched.submit(prompts, [64] * 6, sampling=sps)
    rep = sched.run(max_ticks=2000)
    return sched, rep, {i: list(sched.cos[i].generated) for i in ids}


def test_real_straggler_flagged_never_dead_bitwise():
    """A permanently 4x-slow real engine is flagged slow (its heartbeat
    token credit drops), never declared dead, and the run's tokens stay
    bitwise identical to the fault-free run."""
    _, rep0, toks0 = _real_run(None)
    sched, rep1, toks1 = _real_run(FaultPlan.straggler(0, factor=4.0))
    assert rep0["completed"] == rep1["completed"] == 6
    rb = rep1["robustness"]
    assert rb["slow_flags"] >= 1
    assert rb["health_failovers"] == 0 and rb["failed_nodes"] == []
    assert toks1 == toks0, "mitigation must not change a single token"


# ---------------------------------------------------------------------------
# driver tier: slow replicas are rebalanced away from, then auto-drained
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def drv_parts():
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    plan = plan_lib.search_plan(cfg, hw, ctx=2048, new_tokens=1,
                                max_active=16)
    return cfg, hw, plan


def _drv_factory(drv_parts):
    cfg, hw, plan = drv_parts

    def factory(rid):
        return sim_node_group(cfg, hw, nodes=2, first_node_id=rid * 100,
                              max_active=16, max_len=4096, page_size=64,
                              plan=plan)
    return factory


def _uniform_input(tmp_path, n, max_tokens=256, seed=7):
    rng = np.random.default_rng(seed)
    p = str(tmp_path / "uniform.jsonl")
    with open(p, "w") as f:
        for i in range(n):
            f.write(json.dumps({
                "custom_id": f"req-{i:08d}",
                "body": {"prompt": [int(x) for x in
                                    rng.integers(2, 100, 16)],
                         "max_tokens": max_tokens}}) + "\n")
    return p


def _slow_replica_fpf(rid):
    if rid != 0:
        return None
    return FaultPlan([Fault("straggler", node=n, at_tick=1, factor=4.0,
                            duration=10 ** 9) for n in (0, 1)])


def test_driver_auto_drains_slow_replica(tmp_path, drv_parts):
    """Replica 0 runs 4x slow on both its nodes (so its own scheduler
    sees no intra-replica laggard): the driver's throughput EWMA drains
    it, its work requeues, and the job completes exactly once."""
    inp = _uniform_input(tmp_path, 200)
    drv = StreamingJobDriver(
        inp, str(tmp_path / "out.jsonl"), str(tmp_path / "led"),
        _drv_factory(drv_parts),
        cfg=DriverConfig(window=96, replicas=2, oversubscribe=1.0,
                         rotate_records=64, slow_replica_rounds=5),
        sched_cfg=SchedulerConfig(page_size=64),
        fault_plan_factory=_slow_replica_fpf)
    res = drv.run()
    assert res.status == "completed" and res.merged_records == 200
    assert res.slow_drained >= 1, "throughput trigger must drain replica 0"
    assert res.requeued > 0, "slow drain recycles in-flight work"
    assert res.report["slow_drained"] == res.slow_drained
    with open(res.merged_path) as f:
        cids = [json.loads(l)["custom_id"] for l in f]
    assert cids == [f"req-{i:08d}" for i in range(200)], "input order"


def test_driver_straggler_interrupt_resume_first_wins(tmp_path, drv_parts):
    """Interrupt a mitigated straggler run mid-job, resume in a fresh
    driver: the ledger's first-wins journal suppresses every duplicate
    (hedge re-execution AND resume recompute) and the merged output is
    byte-identical to an uninterrupted fault-free run."""
    inp = _uniform_input(tmp_path, 120)
    clean = StreamingJobDriver(
        inp, str(tmp_path / "clean.jsonl"), str(tmp_path / "led_clean"),
        _drv_factory(drv_parts),
        cfg=DriverConfig(window=96, replicas=2, oversubscribe=1.0,
                         rotate_records=64),
        sched_cfg=SchedulerConfig(page_size=64)).run()
    assert clean.status == "completed"
    clean_bytes = open(clean.merged_path, "rb").read()

    out, led = str(tmp_path / "faulty.jsonl"), str(tmp_path / "led_faulty")

    def mk(max_rounds):
        return StreamingJobDriver(
            inp, out, led, _drv_factory(drv_parts),
            cfg=DriverConfig(window=96, replicas=2, oversubscribe=1.0,
                             rotate_records=64, max_rounds=max_rounds),
            sched_cfg=SchedulerConfig(page_size=64),
            fault_plan_factory=_slow_replica_fpf)

    first = mk(6).run()
    assert first.status != "completed", "run must stop mid-job"
    assert first.merged_records < 120, "interrupted run is partial"
    second = mk(10 ** 7).run()
    assert second.status == "completed"
    assert second.skipped_resume + second.completed >= 120
    assert open(out, "rb").read() == clean_bytes
    _assert_no_overlap(_scan_partials(led))
