"""Memory-pressure governor tests: watermark-driven preempt/re-admit,
mid-flight OOM fault recovery with bitwise token parity, staged h2d
restores, the ``preempt_choice`` policy hook, and the driver-tier
host-spill budget throttle — on both engine families."""
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core import plan as plan_lib
from repro.core.events import EventKind
from repro.core.scheduler import (CoroutineScheduler, SchedulerConfig,
                                  SchedulerPolicy, _admit_budget)
from repro.runtime.cluster import Cluster, SimEngine, fixed_workload
from repro.runtime.engine import NodeEngine
from repro.runtime.faults import Fault, FaultPlan
from repro.sampling import SamplingParams


# ---------------------------------------------------------------------------
# event ordering + admission budget units
# ---------------------------------------------------------------------------


def test_seq_preempt_priority_ordering():
    """SEQ_PREEMPT must rank after SEQ_DONE (finished sequences free pages
    for free — never preempt what is about to evict) and before
    PAGE_BOUNDARY / MODULE_READY (pressure resolves before the node tries
    to extend pages or decode again)."""
    assert EventKind.SEQ_DONE < EventKind.SEQ_PREEMPT
    assert EventKind.SEQ_PREEMPT < EventKind.PAGE_BOUNDARY
    assert EventKind.SEQ_PREEMPT < EventKind.MODULE_READY
    assert EventKind.SEQ_PREEMPT < EventKind.REFILL
    # the policy table dispatches it
    assert EventKind.SEQ_PREEMPT in SchedulerPolicy().table()


def test_admit_budget_tracks_watermark_headroom():
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    eng = SimEngine(cfg, hw, node_id=0, max_active=8, max_len=1024,
                    page_size=64, device_pages=20)
    sched = CoroutineScheduler([eng], SchedulerConfig(
        page_size=64, high_watermark=0.8, low_watermark=0.5))
    # headroom under the high watermark, two pages per admission
    assert _admit_budget(sched, eng) == (int(0.8 * 20) - 0) // 2
    eng.allocator.alloc(1, 10)
    assert _admit_budget(sched, eng) == (16 - 10) // 2
    eng.allocator.alloc(2, 8)       # used=18, over the watermark
    assert _admit_budget(sched, eng) == 0
    sched.cfg.govern_memory = False
    assert _admit_budget(sched, eng) == eng.max_active


# ---------------------------------------------------------------------------
# SimEngine family: oversubscribed decode + parity
# ---------------------------------------------------------------------------


def _sim_run(device_pages, fault_plan=None, *, nodes=2, n=16, out_len=192,
             max_ticks=100000):
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    cl = Cluster(cfg, hw, nodes=nodes, max_active=8, max_len=2048,
                 page_size=64, device_pages=device_pages,
                 fault_plan=fault_plan)
    wl = fixed_workload(n, 128, out_len)
    ids = cl.sched.submit(wl.prompts, wl.max_out)
    rep = cl.sched.run(max_ticks=max_ticks)
    toks = {i: list(cl.sched.cos[i].generated) for i in ids}
    return cl, rep, toks


def test_sim_oversubscribed_decode_completes_with_parity():
    """Device pools ~4x smaller than the concurrent working set (8 active
    x 5 pages vs 10 device pages): the governor preempts into the
    watermark gap and re-admits under the low watermark — every sequence
    still finishes with tokens identical to the unconstrained run."""
    _, rep0, toks0 = _sim_run(256)          # unconstrained baseline
    cl, rep1, toks1 = _sim_run(10)
    assert rep0["completed"] == rep1["completed"] == 16
    assert toks1 == toks0, "oversubscription must not change any token"
    gov0 = rep0["robustness"]["governor"]
    gov1 = rep1["robustness"]["governor"]
    assert gov0["preempts"] == 0, "unconstrained run must never preempt"
    assert gov1["preempts"] > 0
    assert gov1["restores"] > 0
    assert gov1["host_spill_bytes"] > 0
    assert not cl.sched._preempted, "every preempted seq re-admitted"
    # occupancy ended under the high watermark on every node
    assert all(not e.allocator.above_high() for e in cl.engines)


def test_sim_oversubscribed_stages_h2d_restores():
    """Re-admissions under pressure prefetch their host→device restore
    through the ring buffer; decodes between stage and take hide the
    transfer, which the hidden-seconds counter records."""
    cl, rep, _ = _sim_run(10)
    gov = rep["robustness"]["governor"]
    assert gov["restore_stages"] > 0
    assert gov["restore_stage_hidden_s"] > 0
    assert gov["restore_wait_s"] >= gov["restore_stage_hidden_s"]
    assert sum(e.restore_staged_bytes for e in cl.engines) > 0


def test_sim_mid_flight_oom_parity():
    """FaultPlan.oom now fails the page-extension alloc DURING decode (not
    just admission): affected sequences preempt through the one event-loop
    path and re-admit when the fault clears, tokens bitwise-unchanged."""
    plan = FaultPlan([Fault("oom", node=0, at_tick=1, duration=4)])
    _, rep0, toks0 = _sim_run(256)
    cl, rep1, toks1 = _sim_run(256, plan)
    assert rep0["completed"] == rep1["completed"] == 16
    assert toks1 == toks0, "oom recovery must not change a single token"
    assert cl.engines[0].oom_rejections > 0, "extension allocs failed"
    assert rep1["robustness"]["governor"]["preempts"] > 0
    assert any("yield(oom)" in line for line in cl.sched.log)


def test_sim_oom_with_concurrent_node_failure():
    """Mid-flight oom on one node while another dies outright: recovery
    composes — NODE_FAILURE reschedules the dead node's sequences, the
    governor cycles the oom node's, and the merged run stays bitwise
    identical to fault-free."""
    plan = FaultPlan([
        Fault("oom", node=0, at_tick=1, duration=3),
        Fault("node_death", node=2, at_tick=2),
    ], seed=0)
    _, rep0, toks0 = _sim_run(256, nodes=3, n=24, out_len=256)
    cl, rep1, toks1 = _sim_run(256, plan, nodes=3, n=24, out_len=256)
    assert rep0["completed"] == rep1["completed"] == 24
    assert toks1 == toks0
    rb = rep1["robustness"]
    assert 2 in rb["failed_nodes"] and rb["health_failovers"] >= 1
    assert cl.engines[0].oom_rejections > 0
    assert rb["governor"]["preempts"] > 0


def test_preempt_choice_hook_can_veto_victims():
    """A ``preempt_choice`` policy returning "keep" vetoes watermark
    preemptions (pressure then resolves through page-exhaustion eviction
    as before the governor) — and the hook actually gets consulted."""
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    calls = []

    def veto(sched, co, eng):
        calls.append(co.seq_id)
        return "keep"

    engines = [SimEngine(cfg, hw, node_id=0, max_active=8, max_len=2048,
                         page_size=64, device_pages=10)]
    sched = CoroutineScheduler(engines, SchedulerConfig(page_size=64),
                               policy=SchedulerPolicy(preempt_choice=veto))
    wl = fixed_workload(8, 128, 192)
    sched.submit(wl.prompts, wl.max_out)
    rep = sched.run(max_ticks=100000)
    assert rep["completed"] == 8
    assert calls, "watermark pressure must consult the hook"
    assert not any("yield(preempt)" in line for line in sched.log), \
        "every watermark preemption was vetoed"


def test_governor_disabled_restores_legacy_admission():
    """govern_memory=False: no SEQ_PREEMPT, no watermark admission caps —
    pressure resolves through page-exhaustion eviction and the mid-flight
    exhaustion preempt (the one recovery path stays armed)."""
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    engines = [SimEngine(cfg, hw, node_id=0, max_active=8, max_len=2048,
                         page_size=64, device_pages=10)]
    sched = CoroutineScheduler(
        engines, SchedulerConfig(page_size=64, govern_memory=False))
    wl = fixed_workload(8, 128, 192)
    sched.submit(wl.prompts, wl.max_out)
    rep = sched.run(max_ticks=100000)
    assert rep["completed"] == 8
    assert not any("yield(preempt)" in line for line in sched.log), \
        "watermark preemption must stay off when the governor is disabled"


# ---------------------------------------------------------------------------
# NodeEngine family: real-engine oversubscription + oom chaos
# ---------------------------------------------------------------------------


def _real_run(device_pages=None, fault_plan=None):
    cfg = reduced_config("llama3_2_1b")
    rng = np.random.default_rng(5)
    engines = [NodeEngine(cfg, node_id=i, max_active=3, max_len=64,
                          page_size=8, seed=0, device_pages=device_pages)
               for i in range(2)]
    sched = CoroutineScheduler(engines, SchedulerConfig(page_size=8),
                               fault_plan=fault_plan)
    prompts = [list(rng.integers(2, 100, 5)) for _ in range(6)]
    sps = [SamplingParams() if i % 2 == 0
           else SamplingParams(temperature=0.8, top_k=20, seed=40 + i)
           for i in range(6)]
    ids = sched.submit(prompts, [24] * 6, sampling=sps)
    rep = sched.run(max_ticks=4000)
    return sched, rep, {i: list(sched.cos[i].generated) for i in ids}


def test_real_engine_oversubscribed_parity_bitwise():
    """Real jax engines with the device pool shrunk ~4x under the working
    set (3 active x 4 pages vs 8 device pages): preempt → host spill →
    staged h2d restore → re-admit reproduces the exact token streams,
    greedy AND seeded-sampled rows."""
    _, rep0, toks0 = _real_run()
    sched, rep1, toks1 = _real_run(device_pages=8)
    assert rep0["completed"] == rep1["completed"] == 6
    assert toks1 == toks0, \
        "preempt/restore cycling must be pure rescheduling"
    gov = rep1["robustness"]["governor"]
    assert gov["preempts"] > 0
    assert gov["restores"] > 0
    assert gov["host_spill_bytes"] > 0
    assert not sched._preempted


def test_real_engine_mid_flight_oom_with_node_death_parity():
    """Real engines under a mid-flight oom window on node 0 plus node 1
    dying: both recovery paths compose and the tokens stay bitwise
    identical to the fault-free run."""
    plan = FaultPlan([
        Fault("oom", node=0, at_tick=1, duration=3),
        Fault("node_death", node=1, at_tick=2),
    ], seed=2)
    _, rep0, toks0 = _real_run()
    sched, rep1, toks1 = _real_run(fault_plan=plan)
    assert rep0["completed"] == rep1["completed"] == 6
    assert toks1 == toks0
    rb = rep1["robustness"]
    assert 1 in rb["failed_nodes"] and rb["health_failovers"] >= 1
    assert sched.engines and \
        any(getattr(e, "oom_rejections", 0) > 0
            for e in sched._all_engines)


# ---------------------------------------------------------------------------
# driver tier: host-spill budget throttle
# ---------------------------------------------------------------------------


def test_replica_host_over_budget_flag():
    from repro.driver.replica import ReplicaHandle
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    plan = plan_lib.search_plan(cfg, hw, ctx=1024, new_tokens=1,
                                max_active=8)
    engines = [SimEngine(cfg, hw, node_id=0, max_active=8, max_len=2048,
                         page_size=64, plan=plan)]
    r = ReplicaHandle.spawn(0, engines, sched_cfg=SchedulerConfig(
        page_size=64))
    assert not r.host_over_budget()         # unbounded budget
    store = engines[0].host_store
    store.budget_bytes = 10
    assert not r.host_over_budget()         # empty store fits
    store._nbytes = 100                     # model a spilled working set
    assert r.host_over_budget()
    store._nbytes = 0
    r.cancel()
    assert not r.host_over_budget()         # closed replica never throttles


def test_driver_throttles_over_budget_replica(tmp_path):
    """A replica whose host store is pinned over its byte budget stops
    receiving admissions (throttle, not death); the other replica absorbs
    the stream and the merged report carries the governor counters.  Sim
    stores checkpoint metadata (zero bytes), so a negative budget models
    a store the prefix-LRU cascade cannot drain."""
    from repro.data.pipeline import LongTailRequestStream
    from repro.driver import DriverConfig, StreamingJobDriver
    from repro.runtime.cluster import sim_node_group
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    plan = plan_lib.search_plan(cfg, hw, ctx=2048, new_tokens=1,
                                max_active=16)

    def factory(rid):
        group = sim_node_group(cfg, hw, nodes=1, first_node_id=rid * 100,
                               max_active=16, max_len=4096, page_size=64,
                               plan=plan)
        if rid == 0:
            for e in group:
                e.host_store.budget_bytes = -1      # permanently over
        return group

    inp = str(tmp_path / "in.jsonl")
    LongTailRequestStream(40, seed=11, mean_in=24,
                          mean_out=10).write_jsonl(inp)
    drv = StreamingJobDriver(
        inp, str(tmp_path / "out.jsonl"), str(tmp_path / "led"),
        factory, cfg=DriverConfig(window=16, replicas=2),
        sched_cfg=SchedulerConfig(page_size=64))
    res = drv.run()
    assert res.status == "completed" and res.completed == 40
    assert res.report["budget_throttled"] > 0
    assert drv.replicas[0].admitted == 0, \
        "the over-budget replica must never receive work"
    assert "governor" in res.report["robustness"]
