"""Integration tests of the coroutine runtime: exact decode preservation,
lifecycle invariants, migration, module-granularity Algorithm 1."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import reduced_config
from repro.core.coroutine import Status
from repro.core.scheduler import CoroutineScheduler, SchedulerConfig
from repro.core.forward import ModuleRuntime
from repro.runtime.engine import NodeEngine
from repro.models import transformer as T
from repro.models.api import MeshAxes

AXES = MeshAxes()


def _reference_decode(cfg, params, prompt, n_steps, max_len=128):
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    logits, cache_p = T.prefill(cfg, AXES, params, batch)
    toks = [int(jnp.argmax(logits[0, 0]))]
    dc = T.init_cache(cfg, 1, max_len)
    for name in dc:
        dc[name] = dc[name].at[:, :, : len(prompt)].set(
            cache_p[name][:, :, : len(prompt)])
    lens = jnp.array([len(prompt)], jnp.int32)
    t = jnp.array([toks[0]], jnp.int32)
    for _ in range(n_steps - 1):
        t, dc = T.decode_step(cfg, AXES, params, dc, t, lens)
        lens = lens + 1
        toks.append(int(t[0]))
    return toks


def test_coroutine_decode_exactness(rng):
    """Full lifecycle (prefill->host ckpt->combine->decode across page
    boundaries with eviction pressure) must reproduce monolithic greedy."""
    cfg = reduced_config("llama3_2_1b")
    eng = NodeEngine(cfg, max_active=3, max_len=128, page_size=8, seed=0)
    prompts = [list(rng.integers(2, cfg.vocab_size, int(n)))
               for n in rng.integers(4, 12, 7)]
    max_out = [12, 5, 9, 20, 7, 3, 16]
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8))
    ids = sched.submit(prompts, max_out)
    rep = sched.run(max_ticks=300)
    assert rep["completed"] == len(prompts)
    for i in (0, 3, 5):
        ref = _reference_decode(cfg, eng.params, prompts[i], max_out[i])
        assert sched.cos[ids[i]].generated == ref, f"seq {i} diverged"


def test_eviction_under_slot_pressure(rng):
    """More sequences than slots: eviction/refill must still finish all."""
    cfg = reduced_config("qwen2_0_5b")
    eng = NodeEngine(cfg, max_active=2, max_len=64, page_size=8, seed=1)
    prompts = [[2, 3, 4, 5]] * 6
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=8))
    sched.submit(prompts, [10] * 6)
    rep = sched.run(max_ticks=300)
    assert rep["completed"] == 6
    assert eng.stats.counts["combine"] >= 3   # multiple admission waves


def test_migration_balances_nodes(rng):
    cfg = reduced_config("llama3_2_1b")
    e0 = NodeEngine(cfg, node_id=0, max_active=2, max_len=64, page_size=8)
    e1 = NodeEngine(cfg, node_id=1, max_active=2, max_len=64, page_size=8)
    sched = CoroutineScheduler([e0, e1], SchedulerConfig(page_size=8))
    ids = sched.submit([[2, 3, 4]] * 8, [6] * 8)
    # force initial skew: all on node 0
    for i in ids:
        sched.cos[i].node = 0
    rep = sched.run(max_ticks=300)
    assert rep["completed"] == 8
    moved = sum(sched.cos[i].migrations for i in ids)
    assert moved >= 1, "expected MIGRATE to rebalance the skewed pool"


def test_module_granularity_matches_monolithic(rng):
    """Algorithm 1 (B_attn sub-batches + COMBINE) == monolithic decode."""
    cfg = reduced_config("phi3_5_moe")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rt = ModuleRuntime(cfg, AXES, params)
    B, S = 4, 24
    cache = T.init_cache(cfg, B, 64)
    toks = jnp.asarray(rng.integers(2, cfg.vocab_size, B), jnp.int32)
    lens = jnp.asarray(rng.integers(1, 16, B), jnp.int32)
    yields = []
    n1, c1 = rt.forward_decode(toks, cache, lens, b_attn=2,
                               on_yield=lambda *a: yields.append(a))
    n2, c2 = T.decode_step(cfg, AXES, params, cache, toks, lens)
    np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))
    for name in c1:
        # bf16 caches: sub-batched einsums round differently at the single
        # written position (~1-2 bf16 ulp of unit-scale activations)
        np.testing.assert_allclose(np.asarray(c1[name], np.float32),
                                   np.asarray(c2[name], np.float32),
                                   atol=6e-2)
    # 2 attention sub-batch yields per layer + 1 ffn yield per layer
    assert len(yields) == cfg.num_layers * 3
    # COMBINE inflated the expert batch 2x vs attention sub-batch
    assert rt.expert_load(B)["per_expert"] == 2 * rt.expert_load(B // 2)["per_expert"]


def test_longtail_partition_trigger(rng):
    cfg = reduced_config("llama3_2_1b")
    eng = NodeEngine(cfg, max_active=4, max_len=256, page_size=8)
    sched = CoroutineScheduler(
        [eng], SchedulerConfig(page_size=8, longtail_active=2,
                               longtail_min_remaining=16))
    sched.submit([[2, 3]] * 4, [4, 4, 4, 120])
    rep = sched.run(max_ticks=500)
    assert rep["completed"] == 4
    assert eng.stats.counts["partition"] >= 1
    assert any(c.partition_group for c in sched.cos.values())
