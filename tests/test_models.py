"""Unit tests for model layers: attention equivalences, RoPE, SSD, RG-LRU,
MoE capacity behaviour, prefill->decode consistency per family."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, reduced_config
from repro.models import layers, flash, moe as moe_lib, ssm as ssm_lib, rglru
from repro.models import transformer as T
from repro.models.api import MeshAxes

AXES = MeshAxes()


def _batch(cfg, rng, B=2, S=32):
    tokens = jnp.asarray(rng.integers(2, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((B, cfg.num_patches, cfg.d_model),
                                    jnp.bfloat16)
        batch["labels"] = jnp.concatenate(
            [jnp.full((B, cfg.num_patches), -1, jnp.int32), tokens], 1)
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model),
                                   jnp.bfloat16)
    return batch


# ---------------------------------------------------------------- attention


@pytest.mark.parametrize("H,Hkv,causal,window",
                         [(4, 4, True, 0), (4, 2, True, 0), (6, 2, False, 0),
                          (4, 1, True, 16), (8, 4, True, 7)])
def test_flash_matches_naive(rng, H, Hkv, causal, window):
    B, Sq, dh = 2, 64, 16
    q = jnp.asarray(rng.standard_normal((B, Sq, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Sq, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Sq, Hkv, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None], (B, Sq))
    out = layers.chunked_attention(q, k, v, pos, pos, causal=causal,
                                   window=window, chunk=16)
    ref = flash.naive_attention(q, k, v, pos, pos, causal=causal,
                                window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_flash_vjp_matches_autodiff(rng):
    B, S, H, Hkv, dh = 1, 32, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def f_flash(q, k, v):
        return jnp.sum(layers.chunked_attention(q, k, v, pos, pos,
                                                chunk=8) ** 2)

    def f_naive(q, k, v):
        return jnp.sum(flash.naive_attention(q, k, v, pos, pos) ** 2)

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=5e-4, rtol=1e-3)


def test_decode_matches_prefix_attention(rng):
    """One-token decode vs full-sequence attention on the same prefix."""
    B, S, H, Hkv, dh = 2, 16, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((B, 1, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, dh)), jnp.float32)
    lengths = jnp.array([S, S - 5], jnp.int32)
    out = layers.decode_attention(q, k, v, lengths)
    # oracle: mask positions >= length
    qpos = (lengths - 1)[:, None]
    kpos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    ref = flash.naive_attention(q, k, v, qpos, kpos, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_rope_rotation_invariance(rng):
    """RoPE preserves norms and relative-position dot products."""
    x = jnp.asarray(rng.standard_normal((1, 8, 2, 16)), jnp.float32)
    pos = jnp.arange(8, dtype=jnp.int32)[None]
    r = layers.rope(x, pos, 10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(r, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # <rope(q,i), rope(k,j)> depends only on i-j
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 16)), jnp.float32)
    def dot(i, j):
        qi = layers.rope(q, jnp.array([[i]], jnp.int32), 10000.0)
        kj = layers.rope(k, jnp.array([[j]], jnp.int32), 10000.0)
        return float(jnp.sum(qi * kj))
    assert abs(dot(3, 1) - dot(7, 5)) < 1e-4


# ---------------------------------------------------------------- SSM / LRU


def test_ssd_chunked_matches_stepwise(rng):
    cfg = reduced_config("mamba2_370m")
    p = ssm_lib.init_ssm(cfg, jax.random.PRNGKey(1))
    B, S = 2, 32
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)) * 0.1,
                    jnp.float32)
    full = ssm_lib.ssm_fwd(cfg, p, x, chunk=8)
    # step-by-step recurrence must agree
    cache = ssm_lib.init_ssm_cache(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = ssm_lib.ssm_decode(cfg, p, x[:, t : t + 1], cache)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               atol=2e-3, rtol=1e-2)


def test_rglru_scan_matches_stepwise(rng):
    cfg = reduced_config("recurrentgemma_2b")
    p = rglru.init_rglru(cfg, jax.random.PRNGKey(2))
    B, S = 2, 16
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)) * 0.1,
                    jnp.float32)
    full = rglru.rglru_fwd(cfg, p, x)
    cache = rglru.init_rglru_cache(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = rglru.rglru_decode(cfg, p, x[:, t : t + 1], cache)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate(outs, 1)),
                               atol=2e-3, rtol=1e-2)


# ---------------------------------------------------------------- MoE


def test_moe_capacity_matches_ref_when_uncapped(rng):
    cfg = reduced_config("qwen3_moe_30b")
    p = moe_lib.init_moe(cfg, jax.random.PRNGKey(3))
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)) * 0.1,
                    jnp.float32)
    y, aux = moe_lib.moe_fwd(cfg, AXES, p, x)
    ref = moe_lib.moe_ref(cfg, p, x)
    # cf=2.0 in reduced config -> no drops for near-uniform routing
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=5e-3,
                               rtol=5e-2)
    assert float(aux) > 0.5     # aux loss ~1 for near-uniform routing


# ------------------------------------------------- prefill/decode agreement


@pytest.mark.parametrize("arch", ["llama3_2_1b", "qwen2_0_5b", "mamba2_370m",
                                  "recurrentgemma_2b", "phi3_5_moe",
                                  "whisper_base", "h2o_danube_1_8b"])
def test_prefill_then_decode_matches_forward(arch, rng):
    """Greedy decode continuing from the prefill cache must equal argmax of
    teacher-forced forward logits (one step)."""
    cfg = reduced_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, rng, B, S)
    logits_p, cache_p = T.prefill(cfg, AXES, params, batch)
    # init a decode cache of larger size and install the prefill cache
    dc = T.init_cache(cfg, B, 64)

    def install(d, c):
        for key in d:
            if isinstance(d[key], dict):
                install(d[key], c[key])
            elif d[key].ndim >= 3 and c[key].shape[2] <= d[key].shape[2] \
                    and d[key].shape[2] >= S:
                d[key] = d[key].at[:, :, : c[key].shape[2]].set(c[key])
            else:
                d[key] = c[key] if c[key].shape == d[key].shape else d[key]

    if cfg.family in ("dense", "moe") and cfg.sliding_window == 0:
        install(dc, cache_p)
        tok = jnp.argmax(logits_p[:, 0], -1).astype(jnp.int32)
        lens = jnp.full((B,), S, jnp.int32)
        nxt, _ = T.decode_step(cfg, AXES, params, dc, tok, lens)
        # teacher-forced forward with the predicted token appended
        toks2 = jnp.concatenate([batch["tokens"], tok[:, None]], 1)
        b2 = dict(batch)
        b2["tokens"] = toks2
        b2["labels"] = toks2
        h, _, _ = T._backbone(cfg, AXES, params, b2, None, False, False)
        ref = jnp.argmax(T.logits_fn(cfg, params, h[:, -1:])[:, 0], -1)
        np.testing.assert_array_equal(np.asarray(nxt), np.asarray(ref))
    else:
        # structural check: shapes + finiteness for the exotic families
        assert bool(jnp.all(jnp.isfinite(logits_p)))
