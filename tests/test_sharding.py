"""Sharding-rule tests: every spec divides its dim on the production mesh;
spec pytrees match param/cache structures; data pipeline shards align."""
import math

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, ARCH_IDS, get_config
from repro.data.pipeline import DataConfig, SyntheticLMStream
from repro.distributed import sharding as shd
from repro.models import transformer as T
from repro.models.api import MeshAxes

AXES = MeshAxes(batch=("data",), model="model")
TP = 16


def _check_divisible(shapes, specs, tp):
    def chk(path, leaf, spec):
        assert len(spec) == leaf.ndim, (path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, spec):
            if ax == "model":
                assert dim % tp == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(chk, shapes, specs)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("regime", ["tp", "decode"])
def test_param_specs_divide(arch, regime):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    specs = shd.param_specs(cfg, AXES, TP, regime)
    assert jax.tree.structure(
        shapes, is_leaf=lambda x: hasattr(x, "shape")) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, P))
    _check_divisible(shapes, specs, TP)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_cache_specs_divide(arch):
    cfg = get_config(arch)
    B, S = 128, 32768
    shapes = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
    specs = shd.cache_specs(cfg, AXES, TP, B, 16)
    _check_divisible(shapes, specs, TP)


@pytest.mark.parametrize("arch,expected", [
    ("llama3_2_1b", "heads"), ("qwen2_0_5b", "seq"), ("smollm_360m", "seq"),
    ("whisper_base", "seq"), ("recurrentgemma_2b", "seq"),
    ("pixtral_12b", "heads"), ("qwen3_moe_30b", "heads"),
])
def test_attention_mode(arch, expected):
    assert shd.attention_mode(get_config(arch), TP) == expected


def test_data_shards_disjoint_and_deterministic():
    full = SyntheticLMStream(DataConfig(8, 16, 128, num_hosts=1, host_id=0))
    parts = [SyntheticLMStream(DataConfig(8, 16, 128, num_hosts=2, host_id=h))
             for h in range(2)]
    b = full.batch_at(3)
    p0, p1 = parts[0].batch_at(3), parts[1].batch_at(3)
    assert p0["tokens"].shape == (4, 16)
    # deterministic across re-instantiation
    again = SyntheticLMStream(DataConfig(8, 16, 128, num_hosts=2, host_id=0))
    assert (again.batch_at(3)["tokens"] == p0["tokens"]).all()
    assert (p0["tokens"] != p1["tokens"]).any()
