"""Streaming job driver at scale: bounded-window feed, elastic replicas.

Full mode drives a 100k-request long-tail jsonl job through
``StreamingJobDriver`` on SimEngine replicas twice — a static 1-replica
run and an identical run that ``scale_up()``s a second replica mid-job —
and reports sustained req/s for both (the elastic run must be faster)
plus the peak resident window (must stay under the configured bound).

``--smoke`` is the CI variant: 10k requests with one mid-job
``scale_up()`` AND one ``drain()``, then a subprocess SIGKILL mid-job
followed by a resume whose merged output must be byte-identical to the
uninterrupted run's (only the ledger's tail segment may be replayed).
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, write_json
from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.core.scheduler import SchedulerConfig
from repro.data.pipeline import LongTailRequestStream
from repro.driver import DriverConfig, StreamingJobDriver
from repro.runtime.cluster import sim_node_group

CFG_NAME = "qwen3_moe_30b"
MAX_ACTIVE = 256
WINDOW = 4096


def _factory(cfg, hw, plan):
    def factory(rid):
        return sim_node_group(cfg, hw, nodes=2, first_node_id=rid * 100,
                              max_active=MAX_ACTIVE, max_len=8192,
                              page_size=64, plan=plan)
    return factory


def _driver(inp, out, ledger, factory, rotate_records=50_000):
    return StreamingJobDriver(
        inp, out, ledger, factory,
        cfg=DriverConfig(window=WINDOW, replicas=1,
                         rotate_records=rotate_records),
        sched_cfg=SchedulerConfig(page_size=64))


def _rates(timeline, split_t):
    """Sustained req/s before/after a driver-timeline instant."""
    pre = [p for p in timeline if p["t"] <= split_t]
    post = [p for p in timeline if p["t"] > split_t]
    def rate(pts):
        if len(pts) < 2:
            return 0.0
        dt = pts[-1]["t"] - pts[0]["t"]
        return (pts[-1]["completed"] - pts[0]["completed"]) / max(dt, 1e-9)
    return rate(pre), rate(post)


def run_job(n, root, *, scale_at_frac=None, drain_at_frac=None,
            rotate_records=50_000, seed=7):
    """One full driver job over a fresh n-request input; returns
    (DriverResult, scale_time, wall_s, output_path)."""
    cfg = get_config(CFG_NAME)
    hw = plan_lib.Hardware()
    plan = plan_lib.search_plan(cfg, hw, ctx=4096, new_tokens=1,
                                max_active=MAX_ACTIVE)
    inp = os.path.join(root, "in.jsonl")
    if not os.path.exists(inp):
        LongTailRequestStream(n, seed=seed, mean_in=48,
                              mean_out=24).write_jsonl(inp)
    out = os.path.join(root, "out.jsonl")
    drv = _driver(inp, out, os.path.join(root, "ledger"),
                  _factory(cfg, hw, plan), rotate_records=rotate_records)
    marks = {"scale_t": None}

    def hook(d, rnd):
        if scale_at_frac is not None and marks["scale_t"] is None \
                and d.completed >= n * scale_at_frac:
            d.scale_up()
            marks["scale_t"] = d.sim_now()
        if drain_at_frac is not None and not marks.get("drained") \
                and d.completed >= n * drain_at_frac \
                and len(d._open_replicas()) > 1:
            d.drain(d.replicas[0].rid, requeue=True)
            marks["drained"] = True

    t0 = time.perf_counter()
    res = drv.run(on_round=hook)
    wall = time.perf_counter() - t0
    return res, drv.timeline, marks["scale_t"], wall, out


def _full(n):
    payload = {"n": n, "window": WINDOW, "max_active": MAX_ACTIVE}
    with tempfile.TemporaryDirectory() as r1:
        base, _, _, wall1, _ = run_job(n, r1)
        assert base.status == "completed" and base.merged_records == n
        assert base.peak_resident <= WINDOW
        base_rps = n / base.makespan_s
        emit("driver.static_1_replica", base.makespan_s * 1e6,
             f"rps={base_rps:.0f} peak_resident={base.peak_resident} "
             f"wall={wall1:.0f}s")
        payload["static"] = {
            "makespan_s": base.makespan_s, "sustained_rps": base_rps,
            "peak_resident": base.peak_resident, "wall_s": wall1,
            "sealed_segments": base.report["ledger"]["sealed_segments"]}
    with tempfile.TemporaryDirectory() as r2:
        el, timeline, scale_t, wall2, _ = run_job(n, r2, scale_at_frac=0.3)
        assert el.status == "completed" and el.merged_records == n
        assert el.scale_ups == 1 and el.peak_resident <= WINDOW
        el_rps = n / el.makespan_s
        pre, post = _rates(timeline, scale_t)
        emit("driver.scale_up_mid_job", el.makespan_s * 1e6,
             f"rps={el_rps:.0f} pre={pre:.0f} post={post:.0f} "
             f"speedup={base_rps and el_rps / base_rps:.2f}x")
        payload["elastic"] = {
            "makespan_s": el.makespan_s, "sustained_rps": el_rps,
            "scale_up_at_s": scale_t, "pre_scale_rps": pre,
            "post_scale_rps": post, "peak_resident": el.peak_resident,
            "wall_s": wall2}
        payload["speedup"] = el_rps / base_rps
        assert el_rps > base_rps, "scale_up must raise sustained req/s"
        assert post > pre, "post-scale-up rate must exceed pre"
    write_json("streaming_driver", payload)


def _worker(argv):
    """Subprocess body for the SIGKILL leg: run the job, kill -9 self
    after ``kill_after`` rows are journaled."""
    inp, out, ledger, kill_after = argv[0], argv[1], argv[2], int(argv[3])
    cfg = get_config(CFG_NAME)
    hw = plan_lib.Hardware()
    plan = plan_lib.search_plan(cfg, hw, ctx=4096, new_tokens=1,
                                max_active=MAX_ACTIVE)
    drv = _driver(inp, out, ledger, _factory(cfg, hw, plan),
                  rotate_records=1000)

    def hook(d, rnd):
        if kill_after >= 0 and d.completed >= kill_after:
            os.kill(os.getpid(), signal.SIGKILL)

    res = drv.run(on_round=hook)
    print(json.dumps({"status": res.status, "completed": res.completed,
                      "skipped": res.skipped_resume,
                      "replayed": res.report["ledger"]["replayed_segments"],
                      "merged": res.merged_records}))


def _smoke(n=10_000):
    payload = {"n": n, "window": WINDOW, "mode": "smoke"}
    # leg 1: elasticity — one scale_up AND one requeue-drain mid-job
    with tempfile.TemporaryDirectory() as root:
        res, _, _, wall, out = run_job(n, root, scale_at_frac=0.2,
                                       drain_at_frac=0.5,
                                       rotate_records=1000)
        assert res.status == "completed" and res.merged_records == n
        assert res.scale_ups == 1 and res.peak_resident <= WINDOW
        emit("driver.smoke_elastic", res.makespan_s * 1e6,
             f"rps={n / res.makespan_s:.0f} requeued={res.requeued} "
             f"wall={wall:.0f}s")
        payload["elastic"] = {
            "makespan_s": res.makespan_s, "requeued": res.requeued,
            "peak_resident": res.peak_resident, "wall_s": wall}
        clean = open(out, "rb").read()
    # leg 2: SIGKILL mid-job + resume == byte-identical merged output
    with tempfile.TemporaryDirectory() as root:
        inp = os.path.join(root, "in.jsonl")
        LongTailRequestStream(n, seed=7, mean_in=48,
                              mean_out=24).write_jsonl(inp)
        out = os.path.join(root, "out.jsonl")
        led = os.path.join(root, "ledger")
        args = [sys.executable, os.path.abspath(__file__), "--worker",
                inp, out, led]
        p = subprocess.run(args + [str(n // 3)], capture_output=True)
        assert p.returncode == -signal.SIGKILL, p.stderr.decode()[-2000:]
        p = subprocess.run(args + ["-1"], capture_output=True)
        assert p.returncode == 0, p.stderr.decode()[-2000:]
        info = json.loads(p.stdout.decode().strip().splitlines()[-1])
        assert info["status"] == "completed" and info["merged"] == n
        assert info["skipped"] > 0, "resume must skip journaled rows"
        assert info["replayed"] <= 1, "resume must replay only the tail"
        resumed = open(out, "rb").read()
        assert resumed == clean, "kill+resume output != clean output"
        emit("driver.smoke_kill_resume", 0.0,
             f"skipped={info['skipped']} replayed={info['replayed']} "
             f"bytes={len(clean)}")
        payload["kill_resume"] = {"skipped": info["skipped"],
                                  "replayed_segments": info["replayed"],
                                  "merged_bytes": len(clean),
                                  "byte_identical": True}
    write_json("streaming_driver_smoke", payload)


def run():
    _full(100_000)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("-n", type=int, default=None)
    ap.add_argument("--worker", nargs=4, metavar="ARG")
    a = ap.parse_args()
    if a.worker:
        _worker(a.worker)
    elif a.smoke:
        _smoke(a.n or 10_000)
    else:
        _full(a.n or 100_000)
