"""Shared-prefix KV reuse: fork fan-out FLOPs and overlap-sweep throughput.

Two legs, both on the virtual-clock SimEngine cluster so the numbers come
from the §5.4 performance model rather than CPU wall time:

* **fan-out** — every prompt fanned into 16 forked siblings via
  ``submit(n=16)``; with the prefix index on, the group prefills ONCE, so
  prefill FLOPs (∝ prompt tokens computed) must drop ≥3x vs the identical
  run with ``enable_prefix=False``.
* **overlap sweep** — workloads whose prompts share a leading fraction
  f ∈ {0, 0.25, 0.5, 0.75} of their tokens; cross-submit hits skip the
  shared pages' prefill, and effective tokens/s (prompt+decoded tokens
  over model makespan) must beat the baseline at every f ≥ 0.5.

``--smoke`` shrinks both legs for CI; assertions are identical.
Results land in ``BENCH_prefix_reuse.json``.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, write_json
from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.runtime.cluster import Cluster, Workload

CFG_NAME = "qwen3_moe_30b"


def _cluster(enable_prefix: bool, *, max_active: int, max_len: int) -> Cluster:
    return Cluster(get_config(CFG_NAME), plan_lib.Hardware(), nodes=2,
                   max_active=max_active, max_len=max_len, page_size=64,
                   enable_prefix=enable_prefix)


def _prefill_stats(cl: Cluster):
    comp = sum(e.prefill_tokens for e in cl.engines)
    saved = sum(e.prefill_tokens_saved for e in cl.engines)
    secs = sum(e.prefill_s for e in cl.engines)
    return comp, saved, secs


def _fanout(n_prompts: int, prompt_len: int, fan: int, out_len: int):
    """Same workload, prefix on vs off; ratio of prompt tokens computed."""
    wl = Workload([[11 + i] * prompt_len for i in range(n_prompts)],
                  [out_len] * n_prompts)
    legs = {}
    for on in (True, False):
        cl = _cluster(on, max_active=n_prompts * fan,
                      max_len=prompt_len + out_len + 64)
        rep = cl.run(wl, n=fan)
        assert rep["status"] == "completed", rep["status"]
        comp, saved, secs = _prefill_stats(cl)
        legs[on] = {"prefill_tokens": comp, "prefill_tokens_saved": saved,
                    "prefill_model_s": secs, "bct_s": rep["bct_s"],
                    "prefix": rep["prefix"]}
    ratio = legs[False]["prefill_tokens"] / max(legs[True]["prefill_tokens"], 1)
    flops_ratio = legs[False]["prefill_model_s"] \
        / max(legs[True]["prefill_model_s"], 1e-12)
    emit(f"prefix.fanout_{fan}x", legs[True]["prefill_model_s"] * 1e6,
         f"tokens {legs[False]['prefill_tokens']}->"
         f"{legs[True]['prefill_tokens']} ({ratio:.1f}x) "
         f"model_s_ratio={flops_ratio:.1f}x")
    assert ratio >= 3.0, \
        f"{fan}-way fan-out must cut prefill FLOPs >=3x, got {ratio:.2f}x"
    return {"fan": fan, "n_prompts": n_prompts, "prompt_len": prompt_len,
            "tokens_ratio": ratio, "model_s_ratio": flops_ratio,
            "on": legs[True], "off": legs[False]}


def _overlap_workload(n: int, prompt_len: int, frac: float,
                      out_len: int) -> Workload:
    shared = [7] * int(prompt_len * frac)
    prompts = [shared + [1000 + i] * (prompt_len - len(shared))
               for i in range(n)]
    return Workload(prompts, [out_len] * n)


def _overlap_sweep(n: int, prompt_len: int, out_len: int, max_active: int):
    rows = []
    for frac in (0.0, 0.25, 0.5, 0.75):
        wl = _overlap_workload(n, prompt_len, frac, out_len)
        total_tokens = sum(len(p) for p in wl.prompts) + sum(wl.max_out)
        leg = {}
        for on in (True, False):
            cl = _cluster(on, max_active=max_active,
                          max_len=prompt_len + out_len + 64)
            rep = cl.run(wl)
            assert rep["status"] == "completed", rep["status"]
            comp, saved, _ = _prefill_stats(cl)
            leg[on] = {"eff_tok_s": total_tokens / max(rep["bct_s"], 1e-12),
                       "bct_s": rep["bct_s"], "prefill_tokens": comp,
                       "prefill_tokens_saved": saved,
                       "hits": rep["prefix"]["hits"]}
        gain = leg[True]["eff_tok_s"] / max(leg[False]["eff_tok_s"], 1e-12)
        emit(f"prefix.overlap_{int(frac * 100)}pct",
             leg[True]["bct_s"] * 1e6,
             f"eff_tok_s {leg[False]['eff_tok_s']:.0f}->"
             f"{leg[True]['eff_tok_s']:.0f} ({gain:.2f}x) "
             f"saved={leg[True]['prefill_tokens_saved']}")
        if frac >= 0.5:
            assert gain > 1.0, \
                f"{frac:.0%} overlap must raise eff tokens/s, got {gain:.3f}x"
        rows.append({"overlap_frac": frac, "gain": gain,
                     "on": leg[True], "off": leg[False]})
    return rows


def run(smoke: bool = False):
    if smoke:
        fan = _fanout(n_prompts=4, prompt_len=512, fan=16, out_len=32)
        sweep = _overlap_sweep(n=48, prompt_len=512, out_len=32,
                               max_active=16)
    else:
        fan = _fanout(n_prompts=16, prompt_len=2048, fan=16, out_len=64)
        sweep = _overlap_sweep(n=256, prompt_len=2048, out_len=64,
                               max_active=64)
    write_json("prefix_reuse", {"fanout": fan, "overlap_sweep": sweep,
                                "mode": "smoke" if smoke else "full"})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    run(smoke=ap.parse_args().smoke)
