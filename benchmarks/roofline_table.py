"""Render experiments/roofline/*.json into the §Roofline markdown table.

    PYTHONPATH=src python -m benchmarks.roofline_table
"""
import glob
import json
import os

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main(dirpath="experiments/roofline", out="experiments/roofline/TABLE.md"):
    rows = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        r = json.load(open(f))
        if r.get("status") == "skipped":
            rows.append((r["cell"], None, r["why"]))
        elif r.get("status") == "ok":
            rows.append((r["cell"], r, ""))
    rows.sort(key=lambda x: (x[0].split(".")[0],
                             ORDER.index(x[0].split(".")[1])
                             if x[0].split(".")[1] in ORDER else 9))
    lines = [
        "# Roofline baseline table (single-pod 16x16, per device per step)",
        "",
        "| cell | compute s | memory s | collective s | dominant | MFU* | "
        "useful | bw_eff | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for cell, r, why in rows:
        if r is None:
            lines.append(f"| {cell} | — | — | — | skipped | — | — | — | {why} |")
            continue
        t = r["terms_s"]
        lines.append(
            f"| {cell} | {t['compute']:.4f} | {t['memory']:.4f} | "
            f"{t['collective']:.4f} | **{r['dominant']}** | "
            f"{r['roofline_fraction']:.3f} | {r['useful_flops_ratio']:.2f} | "
            f"{r.get('bw_efficiency') or '—'} | {r.get('attention_mode','')} |")
    lines += ["", "MFU* = model-flops-at-peak / dominant term; bw_eff = ideal"
              " decode bytes / achieved (decode cells).", ""]
    os.makedirs(dirpath, exist_ok=True)
    with open(out, "w") as f:
        f.write("\n".join(lines))
    print("\n".join(lines))


if __name__ == "__main__":
    main()
