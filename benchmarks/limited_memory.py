"""Memory-constrained serving, two layers.

Analytical (Table 7 reproduction): memory-constrained accelerator
(A5000, 24 GB), Mixtral-class MoE with full expert offloading vs
keep-experts-resident baselines (FlexGen/MoE-Lightning style).

Measured (governor A/B): an oversubscribed decode on the virtual-clock
SimEngine cluster with the device KV pool shrunk to ~25% of the working
set (10 pages vs the ~40-page per-node demand).  The memory-pressure
governor preempts least-progress sequences to the host store when
occupancy crosses the high watermark, re-admits them under the low
watermark, and stages their host→device restores through the h2d ring
so the PCIe copy rides behind live decode pages.  Asserts:

* tokens are bitwise identical to the unconstrained run (preempt/spill/
  restore is pure rescheduling),
* the governor actually cycled (preempts, restores, spilled bytes > 0),
* staging hides >= 50% of the restore wait (the acceptance gate), and
* a chaos leg — mid-flight ``FaultPlan.oom`` plus a concurrent
  NODE_FAILURE on the oversubscribed cluster — still completes with
  bitwise-identical tokens.

A NodeEngine parity leg re-checks preempt → host spill → staged restore
→ re-admit on real engines (skipped under ``--smoke``: it needs a model
build).  Results land in ``BENCH_limited_memory.json``.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, write_json
from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.models.api import ModelConfig
from repro.runtime.cluster import Cluster, fixed_workload
from repro.runtime.faults import Fault, FaultPlan

MIXTRAL_8X7B = ModelConfig(
    name="mixtral-8x7b", family="moe", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=0, vocab_size=32000, head_dim=128,
    num_experts=8, experts_per_token=2, moe_d_ff=14336)

GSM8K = (8500, 512, 256)          # samples, in, out
CHATBOT = (36000, 256, 512)

CFG_NAME = "qwen3_moe_30b"
# 8 slots x ~5 pages (128 prompt + 192 out at page 64) ~= 40 pages of
# per-node working set; 10 device pages is the ~25% oversubscription
CONSTRAINED_PAGES = 10
UNCONSTRAINED_PAGES = 256


def bct_hours(cfg, hw, *, batch, in_len, out_len, n, offload_experts,
              ep_degree=1):
    plan = plan_lib.search_plan(cfg, hw, ctx=in_len + out_len // 2,
                                new_tokens=1, max_active=batch,
                                offload_params=offload_experts,
                                offload_kv=offload_experts)
    t_pre = plan_lib.step_time(cfg, hw, dataclasses.replace(
        plan, offload_params=offload_experts), batch, in_len, in_len)
    t_dec = plan_lib.step_time(cfg, hw, plan, batch, in_len + out_len // 2, 1)
    waves = -(-n // batch)
    return waves * (t_pre + t_dec * out_len) / 3600


def _table7():
    hw = plan_lib.A5000
    rows = {}
    for ds_name, (n, i, o) in {"gsm8k": GSM8K, "chatbot": CHATBOT}.items():
        # baseline: experts resident -> GPU memory caps batch at ~16
        base = bct_hours(MIXTRAL_8X7B, hw, batch=16, in_len=i, out_len=o,
                         n=n, offload_experts=False)
        # +weights don't fit 24GB with KV: model the paged thrash as 3x
        base *= 3.0
        # BatchGen: full expert offload frees HBM -> batch 6000 (paper §7)
        bg = bct_hours(MIXTRAL_8X7B, hw, batch=6000, in_len=i, out_len=o,
                       n=n, offload_experts=True)
        emit(f"t7.moelightning_like.{ds_name}", base * 3600e6,
             f"{base:.1f}h (paper MoE-Lightning 7.3h/58.5h)")
        emit(f"t7.batchgen.{ds_name}", bg * 3600e6,
             f"{bg:.1f}h (paper BatchGen 1.7h/10.0h) "
             f"speedup={base/bg:.1f}x (paper up to 9.6x)")
        rows[ds_name] = {"baseline_h": base, "batchgen_h": bg}
    # PCIe-bound convergence claim (§6.1): per-token time roughly model-
    # size-independent once offloading dominates
    big = dataclasses.replace(MIXTRAL_8X7B, num_layers=56, moe_d_ff=16384)
    t_small = bct_hours(MIXTRAL_8X7B, hw, batch=2048, in_len=512,
                        out_len=256, n=2048, offload_experts=True)
    t_big = bct_hours(big, hw, batch=2048, in_len=512, out_len=256, n=2048,
                      offload_experts=True)
    emit("t7.pcie_bound_ratio", 0.0,
         f"big/small={t_big/t_small:.2f} (paper: ~1.0 — PCIe-bandwidth-"
         f"bound, not compute-bound)")
    rows["pcie_bound_ratio"] = t_big / t_small
    return rows


def _run(device_pages, n, out_len, nodes=2, fault_plan=None):
    cl = Cluster(get_config(CFG_NAME), plan_lib.Hardware(), nodes=nodes,
                 max_active=8, max_len=2048, page_size=64,
                 device_pages=device_pages, fault_plan=fault_plan)
    wl = fixed_workload(n, 128, out_len)
    ids = cl.sched.submit(wl.prompts, wl.max_out)
    rep = cl.sched.run(max_ticks=200000)
    assert rep["status"] == "completed", rep["status"]
    toks = {i: list(cl.sched.cos[i].generated) for i in ids}
    return cl, rep, toks


def _gov(rep):
    g = dict(rep["robustness"]["governor"])
    g["hidden_frac"] = (g["restore_stage_hidden_s"]
                        / max(g["restore_wait_s"], 1e-12))
    return g


def _sim_ab(n=16, out_len=192):
    _, rep0, toks0 = _run(UNCONSTRAINED_PAGES, n, out_len)
    cl, rep1, toks1 = _run(CONSTRAINED_PAGES, n, out_len)
    assert toks1 == toks0, \
        "preempt/spill/restore cycling must not change a single token"
    g = _gov(rep1)
    assert g["preempts"] > 0 and g["restores"] > 0
    assert g["host_spill_bytes"] > 0 and g["restore_stages"] > 0
    assert not cl.sched._preempted, "every preempted sequence re-admitted"
    assert g["hidden_frac"] >= 0.5, \
        f"h2d staging must hide >= 50% of the restore wait, " \
        f"got {g['hidden_frac']:.0%}"
    slowdown = rep1["bct_s"] / rep0["bct_s"]
    emit("limited_memory.oversubscribed", rep1["bct_s"] * 1e6,
         f"bct {rep0['bct_s']:.2f}s->{rep1['bct_s']:.2f}s "
         f"({slowdown:.1f}x at 25% pool) preempts={g['preempts']} "
         f"spill={g['host_spill_bytes'] >> 20}MiB "
         f"hidden={g['hidden_frac']:.0%}")
    return {"n": n, "out_len": out_len,
            "device_pages": CONSTRAINED_PAGES,
            "bct_unconstrained_s": rep0["bct_s"],
            "bct_constrained_s": rep1["bct_s"],
            "slowdown": slowdown, "governor": g}


def _sim_chaos(n=24, out_len=256):
    """Oversubscribed AND faulted: a mid-flight oom window (page-extension
    allocs fail during decode) plus a node death, recovering through the
    one event-loop path with bitwise token parity."""
    plan = FaultPlan([Fault("oom", node=0, at_tick=1, duration=3),
                      Fault("node_death", node=2, at_tick=2)], seed=7)
    _, rep0, toks0 = _run(CONSTRAINED_PAGES, n, out_len, nodes=3)
    cl, rep1, toks1 = _run(CONSTRAINED_PAGES, n, out_len, nodes=3,
                           fault_plan=plan)
    assert toks1 == toks0, "chaos recovery must reproduce exact tokens"
    rb = rep1["robustness"]
    oom_rej = sum(getattr(e, "oom_rejections", 0)
                  for e in cl.sched._all_engines)
    assert 2 in rb["failed_nodes"] and oom_rej > 0
    g = _gov(rep1)
    assert g["preempts"] > 0
    emit("limited_memory.chaos", rep1["bct_s"] * 1e6,
         f"oom+node_death on 25% pool: bct {rep1['bct_s']:.2f}s "
         f"oom_rejections={oom_rej} preempts={g['preempts']} "
         f"hidden={g['hidden_frac']:.0%}")
    return {"n": n, "out_len": out_len, "oom_rejections": oom_rej,
            "failed_nodes": rb["failed_nodes"], "bct_s": rep1["bct_s"],
            "governor": g}


def _real_parity():
    """NodeEngine leg: preempt -> host spill -> staged h2d restore ->
    re-admit on real jax engines, bitwise vs the unconstrained run."""
    import numpy as np

    from repro.configs import reduced_config
    from repro.core.scheduler import CoroutineScheduler, SchedulerConfig
    from repro.runtime.engine import NodeEngine
    from repro.sampling import SamplingParams

    def run(device_pages):
        cfg = reduced_config("llama3_2_1b")
        rng = np.random.default_rng(5)
        engines = [NodeEngine(cfg, node_id=i, max_active=3, max_len=64,
                              page_size=8, seed=0,
                              device_pages=device_pages)
                   for i in range(2)]
        sched = CoroutineScheduler(engines, SchedulerConfig(page_size=8))
        prompts = [list(rng.integers(2, 100, 5)) for _ in range(6)]
        ids = sched.submit(prompts, [24] * 6,
                           sampling=[SamplingParams()] * 6)
        rep = sched.run(max_ticks=4000)
        return rep, {i: list(sched.cos[i].generated) for i in ids}

    rep0, toks0 = run(None)
    rep1, toks1 = run(8)        # ~4x under the 3-slot working set
    assert toks1 == toks0 and rep1["completed"] == rep0["completed"] == 6
    g = _gov(rep1)
    assert g["preempts"] > 0 and g["host_spill_bytes"] > 0
    emit("limited_memory.real_parity", rep1["bct_s"] * 1e6,
         f"preempts={g['preempts']} spill={g['host_spill_bytes']}B "
         f"stages={g['restore_stages']}")
    return {"preempts": g["preempts"],
            "host_spill_bytes": g["host_spill_bytes"],
            "restore_stages": g["restore_stages"]}


def run(smoke: bool = False):
    payload = {"table7": _table7(), "mode": "smoke" if smoke else "full",
               "sim_ab": _sim_ab(), "chaos": _sim_chaos()}
    if not smoke:
        payload["real_parity"] = _real_parity()
    write_json("limited_memory", payload)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    run(smoke=ap.parse_args().smoke)
