"""Table 7 reproduction: memory-constrained accelerator (A5000, 24 GB),
Mixtral-class MoE with full expert offloading vs keep-experts-resident
baselines (FlexGen/MoE-Lightning style)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.models.api import ModelConfig

MIXTRAL_8X7B = ModelConfig(
    name="mixtral-8x7b", family="moe", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=0, vocab_size=32000, head_dim=128,
    num_experts=8, experts_per_token=2, moe_d_ff=14336)

GSM8K = (8500, 512, 256)          # samples, in, out
CHATBOT = (36000, 256, 512)


def bct_hours(cfg, hw, *, batch, in_len, out_len, n, offload_experts,
              ep_degree=1):
    plan = plan_lib.search_plan(cfg, hw, ctx=in_len + out_len // 2,
                                new_tokens=1, max_active=batch,
                                offload_params=offload_experts,
                                offload_kv=offload_experts)
    t_pre = plan_lib.step_time(cfg, hw, dataclasses.replace(
        plan, offload_params=offload_experts), batch, in_len, in_len)
    t_dec = plan_lib.step_time(cfg, hw, plan, batch, in_len + out_len // 2, 1)
    waves = -(-n // batch)
    return waves * (t_pre + t_dec * out_len) / 3600


def run():
    hw = plan_lib.A5000
    for ds_name, (n, i, o) in {"gsm8k": GSM8K, "chatbot": CHATBOT}.items():
        # baseline: experts resident -> GPU memory caps batch at ~16
        base = bct_hours(MIXTRAL_8X7B, hw, batch=16, in_len=i, out_len=o,
                         n=n, offload_experts=False)
        # +weights don't fit 24GB with KV: model the paged thrash as 3x
        base *= 3.0
        # BatchGen: full expert offload frees HBM -> batch 6000 (paper §7)
        bg = bct_hours(MIXTRAL_8X7B, hw, batch=6000, in_len=i, out_len=o,
                       n=n, offload_experts=True)
        emit(f"t7.moelightning_like.{ds_name}", base * 3600e6,
             f"{base:.1f}h (paper MoE-Lightning 7.3h/58.5h)")
        emit(f"t7.batchgen.{ds_name}", bg * 3600e6,
             f"{bg:.1f}h (paper BatchGen 1.7h/10.0h) "
             f"speedup={base/bg:.1f}x (paper up to 9.6x)")
    # PCIe-bound convergence claim (§6.1): per-token time roughly model-
    # size-independent once offloading dominates
    big = dataclasses.replace(MIXTRAL_8X7B, num_layers=56, moe_d_ff=16384)
    t_small = bct_hours(MIXTRAL_8X7B, hw, batch=2048, in_len=512,
                        out_len=256, n=2048, offload_experts=True)
    t_big = bct_hours(big, hw, batch=2048, in_len=512, out_len=256, n=2048,
                      offload_experts=True)
    emit("t7.pcie_bound_ratio", 0.0,
         f"big/small={t_big/t_small:.2f} (paper: ~1.0 — PCIe-bandwidth-"
         f"bound, not compute-bound)")


if __name__ == "__main__":
    run()
