"""Figure 2b reproduction: GPU utilization vs expert-level batch size, and
the COMBINE primitive's effect on per-expert batches (measured on the real
module runtime + modelled on TPU v5e)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config, reduced_config
from repro.core import plan as plan_lib
from repro.core.forward import ModuleRuntime
from repro.models import transformer as T
from repro.models.api import MeshAxes


def run():
    # --- modelled: per-expert GEMM efficiency vs tokens at the gate -------
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    sat = plan_lib.saturation_tokens(cfg, hw)
    emit("f2b.saturation_tokens", 0.0,
         f"{sat} tokens to compute-saturate all {cfg.num_experts} experts "
         f"(paper: 16384 for its flagship)")
    for toks in (128, 1024, 4096, 16384, 65536):
        c = plan_lib.moe_cost(cfg, toks, ep_degree=16)
        t = c.time(hw)
        mfu = c.flops / hw.peak_flops / t
        emit(f"f2b.moe_mfu.{toks}tok", t * 1e6,
             f"mfu={mfu:.3f} per_expert={toks*cfg.experts_per_token/cfg.num_experts:.0f}")

    # --- measured: COMBINE inflates per-expert batch in the real runtime --
    rcfg = reduced_config("qwen3_moe_30b")
    params = T.init_params(rcfg, __import__("jax").random.PRNGKey(0))
    rt = ModuleRuntime(rcfg, MeshAxes(), params)
    for b_attn in (1, 2, 4, 8):
        load = rt.expert_load(8)
        emit(f"f2b.combine.b_attn{b_attn}", 0.0,
             f"B_moe=8 per_expert={load['per_expert']:.1f} "
             f"(vs {b_attn*rcfg.experts_per_token/rcfg.num_experts:.1f} "
             f"without COMBINE)")


if __name__ == "__main__":
    run()
