"""Table 6 reproduction: BatchGen (no ratio tuning) vs prefill-decode
disaggregation across P:D splits on a 128-GPU pool (8K-2K workload)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.runtime.cluster import Cluster, fixed_workload

N = 320          # 10K requests scaled 1/32
SCALE = 32
UNITS = 8        # 8 units x 16 GPUs


def pd_disagg_bct(cfg, hw, wl, p_units: int, d_units: int) -> float:
    """Static PD disaggregation model: prefill pool feeds a decode pool;
    BCT = max(pipeline stages) + drain."""
    plan = plan_lib.Plan(256, 256, False, False, 0, 0.0)
    n = wl.n
    in_len = len(wl.prompts[0])
    out_len = wl.max_out[0]
    # prefill pool throughput (seqs/s): per 16-GPU unit
    t_pre = plan_lib.step_time(cfg, hw, plan, 16, in_len, in_len) / 16
    pre_rate = p_units / t_pre
    # decode pool: decode step time at its max batch
    max_active = 256
    t_dec = plan_lib.step_time(cfg, hw, plan, max_active, in_len + out_len // 2, 1)
    dec_rate = d_units * max_active / (t_dec * out_len)   # seqs/s
    rate = min(pre_rate, dec_rate)
    return n / rate


def run():
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    wl = fixed_workload(N, 8192, 2048)
    best = None
    for p in range(1, UNITS):
        d = UNITS - p
        bct = pd_disagg_bct(cfg, hw, wl, p, d)
        emit(f"t6.pd_disagg.{p}:{d}", bct * 1e6, f"{bct*SCALE/60:.1f}min")
        best = min(best, bct) if best else bct
    # BatchGen: unified pool, no ratio tuning
    cl = Cluster(cfg, hw, nodes=UNITS * 2, max_active=512, max_len=10304)
    rep = cl.run(wl)
    emit("t6.batchgen.unified", rep["bct_s"] * 1e6,
         f"{rep['bct_s']*SCALE/60:.1f}min "
         f"vs best PD {best*SCALE/60:.1f}min "
         f"speedup={best/rep['bct_s']:.2f}x (paper 2.2x, no tuning)")


if __name__ == "__main__":
    run()
