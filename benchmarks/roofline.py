import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

"""Roofline analysis (deliverable g) — single-pod mesh, per (arch x shape).

Derives the three roofline terms from the compiled dry-run artifact:

    compute    = HLO_FLOPs / (chips * peak)     [per-partition HLO => /chip]
    memory     = HLO_bytes / (chips * hbm_bw)
    collective = wire_bytes / (chips * ici_bw)

Layer scans are UNROLLED for this run so per-layer cost is counted exactly
(probe-validated: matches analytic FLOPs within ~6%).  The one remaining
rolled loop — the chunked-attention kv scan — is corrected analytically
(`_attn_scan_correction`); the correction path is validated by
tests/test_roofline.py (chunk == Skv makes nc == 1, eliminating the scan).

Usage:
    PYTHONPATH=src python -m benchmarks.roofline [--arch X] [--shape Y]
        [--outdir experiments/roofline]
"""

import argparse
import json
import time

import jax

from repro.configs import ASSIGNED, get_config
from repro.distributed.collectives import collective_stats
from repro.distributed.sharding import attention_mode
from repro.launch import steps
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.api import SHAPES, shape_applicable

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}
ATTN_CHUNK = 512
CE_CHUNK = 512


def _attn_scan_correction(cfg, shape, mesh_batch, tp):
    """Analytic (flops, bytes) missing because the chunked-attention kv scan
    body is counted once by cost_analysis.  Per-device values."""
    kind = SHAPES[shape].kind
    if kind == "decode" or cfg.family == "ssm":
        return 0.0, 0.0
    B, S = SHAPES[shape].global_batch, SHAPES[shape].seq_len
    H, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    def stack_terms(n_layers, Sq, Skv):
        nc = max(Skv // ATTN_CHUNK, 1)
        if nc <= 1:
            return 0.0, 0.0
        unit = 4.0 * B * Sq * Skv * H * dh          # qk + pv einsums, 1 pass
        # fwd body counted once; train adds remat-fwd + flash-bwd loops
        mult = (4.5 if kind == "train" else 1.0)    # (4+4+10)/4 = 4.5
        flops = unit * mult * (nc - 1) / nc * n_layers
        kv = 2.0 * B * Skv * Hkv * dh * 2           # kv re-read per chunk
        stats = 3.0 * B * Sq * H * (dh + 2) * 4     # m/l/acc rw per chunk
        byts = ((nc - 1) / nc * kv + (nc - 1) * stats) * n_layers
        if kind == "train":
            byts *= 3.0                              # fwd + remat + bwd loops
        return flops, byts

    n_attn = cfg.num_layers
    fl = by = 0.0
    if cfg.family == "hybrid":
        n_attn = sum(1 for k in cfg.block_pattern) and \
            cfg.num_layers // len(cfg.block_pattern)  # attn per unit
        f, b = stack_terms(n_attn, S, S)
        fl, by = f, b
    elif cfg.family == "audio":
        f1, b1 = stack_terms(cfg.encoder_layers, cfg.encoder_seq,
                             cfg.encoder_seq)
        f2, b2 = stack_terms(cfg.num_layers, S, S)          # self
        f3, b3 = stack_terms(cfg.num_layers, S, cfg.encoder_seq)  # cross
        fl, by = f1 + f2 + f3, b1 + b2 + b3
    else:
        fl, by = stack_terms(cfg.num_layers, S, S)
    # per-device: batch over mesh_batch; heads or Sq over tp
    div = mesh_batch * tp
    return fl / div, by / div


def model_flops_per_device(cfg, shape, chips):
    """MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (infer)."""
    n = cfg.active_param_count()
    sp = SHAPES[shape]
    if sp.kind == "train":
        toks, mult = sp.global_batch * sp.seq_len, 6.0
    elif sp.kind == "prefill":
        toks, mult = sp.global_batch * sp.seq_len, 2.0
    else:
        toks, mult = sp.global_batch * 1, 2.0
    return mult * n * toks / chips


def ideal_decode_bytes(cfg, shape, chips):
    """Ideal per-device HBM traffic for one decode step: every weight read
    once, the KV/state cache read once + one-token write."""
    sp = SHAPES[shape]
    params = cfg.param_count() * 2.0
    B, S = sp.global_batch, sp.seq_len
    if cfg.family == "ssm":
        cache = B * cfg.num_layers * (cfg.ssm_heads * cfg.ssm_state
                                      * cfg.ssm_head_dim * 4)
    elif cfg.family == "hybrid":
        n_units = cfg.num_layers // len(cfg.block_pattern)
        cache = B * (n_units * min(cfg.local_window, S) * cfg.num_kv_heads
                     * cfg.head_dim * 4
                     + cfg.num_layers * cfg.lru_width * 4)
    elif cfg.use_mla:
        cache = B * cfg.num_layers * S * (cfg.kv_lora_rank
                                          + cfg.rope_head_dim) * 2
    else:
        eff = min(cfg.sliding_window, S) if cfg.sliding_window else S
        cache = B * cfg.num_layers * eff * cfg.num_kv_heads * cfg.head_dim * 4
    return (params + cache) / chips


BOTTLENECK_FIXES = {
    "compute": "raise MXU occupancy: larger per-device microbatch or fewer "
               "redundant (replicated-attention/router) FLOPs",
    "memory": "cut HBM traffic: fuse attention (Pallas flash/paged kernel "
              "keeps softmax state in VMEM), int8 weights for decode, "
              "bigger COMBINE batches to amortize expert weight reads",
    "collective": "reshard: hierarchical (pod-local-first) all-to-all, "
                  "overlap psum with expert GEMM, bf16 grad reduction",
}


def _measure(arch, shape, mesh, num_layers, microbatches,
             train_regime="tp"):
    """Compile one variant and return (flops, bytes, wire, colls, cell).

    The microbatch count is PINNED to the full config's choice so that
    reduced-layer probe variants share the exact same step structure."""
    import dataclasses

    from repro.launch.mesh import batch_extent
    from repro.launch.steps import _auto_microbatches

    cfg = get_config(arch)
    sp = SHAPES[shape]
    n_mb = _auto_microbatches(cfg, sp.global_batch, sp.seq_len,
                              batch_extent(mesh), microbatches)         if sp.kind == "train" else None
    if num_layers is not None:
        kw = {"num_layers": num_layers}
        if cfg.family == "audio":
            kw["encoder_layers"] = num_layers   # scale both stacks together
        cfg2 = dataclasses.replace(cfg, **kw)
        import repro.launch.steps as S

        orig = S.get_config
        S.get_config = lambda a: cfg2
        try:
            cell = steps.build_cell(arch, shape, mesh, unroll=True,
                                    exact_microbatches=n_mb,
                                    train_regime=train_regime)
        finally:
            S.get_config = orig
    else:
        cell = steps.build_cell(arch, shape, mesh, unroll=True,
                                exact_microbatches=n_mb,
                                train_regime=train_regime)
    compiled = cell.lower().compile()
    ca = compiled.cost_analysis() or {}
    colls = collective_stats(compiled.as_text(), world=mesh.size)
    return (ca.get("flops", 0.0), ca.get("bytes accessed", 0.0),
            colls["total_wire_bytes"], colls, cell)


def _layer_unit(cfg):
    """Smallest homogeneous repeat unit for layer-count extrapolation."""
    if cfg.family == "hybrid":
        return len(cfg.block_pattern)
    return 1


def run_cell(arch, shape, outdir, microbatches=4, mode="extrapolate",
             train_regime="tp"):
    mesh = make_production_mesh()
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, SHAPES[shape])
    tag = f"{arch}.{shape}"
    if not ok:
        rec = {"cell": tag, "status": "skipped", "why": why}
        _save(outdir, tag, rec)
        return rec
    t0 = time.time()
    if SHAPES[shape].kind in ("decode", "prefill"):
        mode = "exact"     # fast compiles; avoids fusion-dependent 'bytes
                           # accessed' drift seen in small-L extrapolation
    if mode == "exact":
        flops, byts, wire, colls, cell = _measure(arch, shape, mesh, None,
                                                  microbatches, train_regime)
    else:
        # Paper §5.4: layers are structurally homogeneous — profile one
        # repeat unit and extrapolate: total = outside + n_units * body.
        u = _layer_unit(cfg)
        f1, b1, w1, c1, cell = _measure(arch, shape, mesh, u, microbatches)
        f2, b2, w2, colls, cell = _measure(arch, shape, mesh, 2 * u,
                                           microbatches)
        n_units = cfg.num_layers / u
        flops = f1 + (f2 - f1) * (n_units - 1)
        byts = b1 + (b2 - b1) * (n_units - 1)
        wire = w1 + (w2 - w1) * (n_units - 1)
        colls = {"wire_bytes": {k: v + (colls["wire_bytes"].get(k, 0) - v)
                                * (n_units - 1)
                 for k, v in c1["wire_bytes"].items()},
                 "total_wire_bytes": wire}
    t_compile = time.time() - t0
    tp = mesh.shape["model"]
    mesh_batch = mesh.shape["data"]
    fl_corr, by_corr = _attn_scan_correction(cfg, shape, mesh_batch, tp)
    flops = flops + fl_corr
    byts = byts + by_corr
    t_comp = flops / HW["peak_flops"]
    t_mem = byts / HW["hbm_bw"]
    t_coll = wire / HW["ici_bw"]
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops_per_device(cfg, shape, mesh.size)
    step_t = max(terms.values())
    # decode cells sit on the HBM roofline: report how close the achieved
    # byte traffic is to the ideal (weights once per step + cache r/w once)
    bw_eff = None
    if cell.kind == "decode":
        ideal = ideal_decode_bytes(cfg, shape, mesh.size)
        bw_eff = round(ideal / max(byts, 1.0), 4)
    rec = {
        "cell": tag, "status": "ok", "kind": cell.kind, "mode": mode,
        "attention_mode": attention_mode(cfg, tp), "note": cell.note,
        "compile_s": round(t_compile, 1),
        "per_device_flops": flops, "per_device_bytes": byts,
        "per_device_wire_bytes": wire,
        "hlo_flops_raw": flops - fl_corr,
        "attn_scan_correction": {"flops": fl_corr, "bytes": by_corr},
        "terms_s": {k: round(v, 6) for k, v in terms.items()},
        "dominant": dom,
        "model_flops_per_device": mf,
        "useful_flops_ratio": round(mf / max(flops, 1.0), 4),
        "bw_efficiency": bw_eff,
        "roofline_fraction": round((mf / HW["peak_flops"]) / max(step_t, 1e-12), 4),
        "collective_detail": colls["wire_bytes"],
        "fix_hint": BOTTLENECK_FIXES[dom],
    }
    _save(outdir, tag, rec)
    print(f"{tag:38s} comp={t_comp*1e3:9.2f}ms mem={t_mem*1e3:9.2f}ms "
          f"coll={t_coll*1e3:9.2f}ms dom={dom:10s} "
          f"MFU*={rec['roofline_fraction']:.3f} useful={rec['useful_flops_ratio']:.2f}")
    return rec


def _save(outdir, tag, rec):
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mode", default="extrapolate",
                    choices=["extrapolate", "exact"])
    ap.add_argument("--outdir", default="experiments/roofline")
    args = ap.parse_args()
    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    t0 = time.time()
    for a in archs:
        for s in shapes:
            try:
                run_cell(a, s, args.outdir, mode=args.mode)
            except Exception as e:  # noqa: BLE001
                print(f"{a}.{s} FAIL {type(e).__name__}: {str(e)[:150]}")
                _save(args.outdir, f"{a}.{s}",
                      {"cell": f"{a}.{s}", "status": "fail", "error": str(e)})
    print(f"done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
