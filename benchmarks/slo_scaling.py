"""Table 4 reproduction: sequences served under SLO for RSA test-time
scaling (T rounds, N candidates, select K; prefill:decode = K:1)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.runtime.cluster import Cluster, Workload, run_static_baseline


def rsa_workload(n_queries: int, T: int, N: int, K: int, dec: int = 8192):
    """Each query: T rounds x N candidates; round r>0 prefills K*dec."""
    prompts, outs = [], []
    for _ in range(n_queries):
        for r in range(T):
            pre = 1024 if r == 0 else K * dec
            prompts += [[1] * pre] * N
            outs += [dec] * N
    return Workload(prompts, outs)


def run():
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    for (T, N, K) in [(4, 8, 4), (2, 16, 4), (3, 8, 2)]:
        for slo_min in (30, 60):
            # find max queries finishing under SLO, coroutine vs static
            served = {"batchgen": 0, "static": 0}
            for q in (1, 4, 16):
                wl = rsa_workload(q, T, N, K)
                cl = Cluster(cfg, hw, nodes=2, max_active=256,
                             max_len=K * 8192 + 8300)
                rep = cl.run(wl)
                if rep["bct_s"] / 60 <= slo_min:
                    served["batchgen"] = q * N * T
                base = run_static_baseline(cfg, hw, wl, nodes=2,
                                           max_len=K * 8192 + 8300)
                if base["bct_s"] / 60 <= slo_min:
                    served["static"] = q * N * T
            sp = served["batchgen"] / max(served["static"], 1)
            emit(f"t4.rsa.T{T}N{N}K{K}.{slo_min}min", 0.0,
                 f"batchgen={served['batchgen']} static={served['static']} "
                 f"speedup={sp:.2f}x (paper 1.25-1.75x)")


if __name__ == "__main__":
    run()
