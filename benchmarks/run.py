"""Benchmark harness entry point (deliverable d): one module per paper
table/figure.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [table ...]
"""
from __future__ import annotations

import sys
import time

from benchmarks import (cluster_scaling, expert_batching, limited_memory,
                        offline_bct, pd_disagg, primitives, slo_scaling)
from benchmarks.common import ROWS

TABLES = {
    "t2_primitives": primitives.run,
    "t3_offline_bct": offline_bct.run,
    "t4_slo_scaling": slo_scaling.run,
    "t5_cluster_scaling": cluster_scaling.run,
    "t6_pd_disagg": pd_disagg.run,
    "t7_limited_memory": limited_memory.run,
    "f2b_expert_batching": expert_batching.run,
}


def main() -> None:
    wanted = sys.argv[1:] or list(TABLES)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in wanted:
        print(f"# --- {name} ---")
        TABLES[name]()
    print(f"# {len(ROWS)} rows in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
