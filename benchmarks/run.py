"""Benchmark harness entry point (deliverable d): one module per paper
table/figure.  Prints ``name,us_per_call,derived`` CSV rows and writes one
machine-readable ``BENCH_<table>.json`` per table (rows + whatever payload
the table's ``run()`` returns), so every benchmark is diffable across PRs.

    PYTHONPATH=src python -m benchmarks.run [table ...]
"""
from __future__ import annotations

import sys
import time

from benchmarks import (cluster_scaling, decode_throughput, expert_batching,
                        limited_memory, offline_bct, pd_disagg, prefix_reuse,
                        primitives, slo_scaling, straggler_tail,
                        streaming_driver)
from benchmarks.common import ROWS, WRITTEN, rows_as_dicts, write_json

TABLES = {
    "t2_primitives": primitives.run,
    "t3_offline_bct": offline_bct.run,
    "t4_slo_scaling": slo_scaling.run,
    "t5_cluster_scaling": cluster_scaling.run,
    "t6_pd_disagg": pd_disagg.run,
    "t7_limited_memory": limited_memory.run,
    "f2b_expert_batching": expert_batching.run,
    "decode_throughput": decode_throughput.run,
    "streaming_driver": streaming_driver.run,
    "prefix_reuse": prefix_reuse.run,
    "straggler": straggler_tail.run,
}


def main() -> None:
    wanted = sys.argv[1:] or list(TABLES)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in wanted:
        print(f"# --- {name} ---")
        n_rows, n_written = len(ROWS), len(WRITTEN)
        payload = TABLES[name]()
        if f"BENCH_{name}.json" in WRITTEN[n_written:]:
            continue        # table wrote its own (richer) schema; keep it
        doc = {"rows": rows_as_dicts(ROWS[n_rows:])}
        if isinstance(payload, dict):
            doc["derived"] = payload
        write_json(name, doc)
    print(f"# {len(ROWS)} rows in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
