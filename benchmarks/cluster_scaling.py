"""Table 5 reproduction: end-to-end time scaling 32 -> 128 GPUs
(12K-4K and 6.5K-2.8K trace workloads, 10K requests scaled 1/8)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.runtime.cluster import Cluster, longtail_workload, \
    run_static_baseline

N = 320          # 10K requests scaled 1/32 for sim tractability
SCALE = 32


def run():
    cfg = get_config("qwen3_moe_30b")
    hw = plan_lib.Hardware()
    for name, (i, o) in {"12k_4k": (12288, 4096),
                         "6.5k_2.8k": (6656, 2867)}.items():
        for gpus in (32, 64, 128):
            wl = longtail_workload(N, mean_in=i, mean_out=o, sigma=0.6,
                                   seed=5)
            # paper: beyond 64 GPUs deploy independent 64-GPU instances
            inst = max(gpus // 64, 1)
            nodes = min(gpus, 64) // 8
            per = [0.0] * inst
            for k in range(inst):
                sub = longtail_workload(N // inst, mean_in=i, mean_out=o,
                                        sigma=0.6, seed=5 + k)
                cl = Cluster(cfg, hw, nodes=nodes, max_active=512,
                             max_len=i + o + 2048)
                rep = cl.run(sub)
                per[k] = rep["bct_s"]
            bct = max(per)
            base = run_static_baseline(cfg, hw, wl, nodes=gpus // 8,
                                       max_active=64, max_len=i + o + 2048)
            emit(f"t5.batchgen.{name}.{gpus}gpu", bct * 1e6,
                 f"{bct*SCALE/60:.1f}min")
            emit(f"t5.static.{name}.{gpus}gpu", base["bct_s"] * 1e6,
                 f"{base['bct_s']*SCALE/60:.1f}min "
                 f"speedup={base['bct_s']/bct:.2f}x (paper 1.7-2.3x)")


if __name__ == "__main__":
    run()
