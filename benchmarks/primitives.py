"""Table 2 reproduction: coroutine primitive overheads.

Measures the real mini-engine's primitive costs on CPU (wall time) AND
reports the TPU-modelled costs at DeepSeek-R1 scale (the paper's setting:
8 devices, 10K context, batch 512) from the performance model — both
columns, clearly labelled, since the container has no accelerator."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config, reduced_config
from repro.core import plan as plan_lib
from repro.core import primitives as prim
from repro.core.coroutine import SequenceCoroutine, Status
from repro.core.scheduler import CoroutineScheduler, SchedulerConfig
from repro.runtime.cluster import kv_bytes_per_token
from repro.runtime.engine import NodeEngine


def run():
    # --- measured on the real CPU mini-engine ---------------------------
    cfg = reduced_config("llama3_2_1b")
    eng = NodeEngine(cfg, max_active=4, max_len=128, page_size=16)
    rng = np.random.default_rng(0)
    sched = CoroutineScheduler([eng], SchedulerConfig(page_size=16))
    ids = sched.submit([[2, 3, 4, 5]] * 4, [32] * 4)
    cos = [sched.cos[i] for i in ids]
    eng.prefill(cos)
    prim.combine(cos, eng)
    eng.decode_page(cos, 16)
    eng.sync_appends(cos)

    t0 = time.perf_counter()
    prim.yield_(cos[0], eng)
    emit("t2.yield_checkpoint.cpu_measured",
         (time.perf_counter() - t0) * 1e6, "mini-engine")
    t0 = time.perf_counter()
    prim.combine([cos[0]], eng)
    emit("t2.combine_restore.cpu_measured",
         (time.perf_counter() - t0) * 1e6, "mini-engine")
    eng2 = NodeEngine(cfg, node_id=1, max_active=4, max_len=128, page_size=16)
    prim.yield_(cos[1], eng)
    t0 = time.perf_counter()
    prim.migrate(cos[1], eng, eng2)
    emit("t2.migrate.cpu_measured", (time.perf_counter() - t0) * 1e6,
         "mini-engine")

    # --- modelled at paper scale (DeepSeek-R1-class, 8 devices) ----------
    ds = get_config("deepseek_r1")
    hw = plan_lib.Hardware()
    hidden_mb = 512 * ds.d_model * 2 / 2**20     # 512 seqs x 7168 bf16
    emit("t2.hidden_ckpt.modeled",
         hidden_mb * 2**20 / hw.hbm_bw * 1e6,
         f"{hidden_mb:.1f}MB at HBM bw; paper <5us overlapped")
    kv_10k = 10000 * kv_bytes_per_token(ds)
    emit("t2.combine_kv_restore.modeled",
         kv_10k / hw.host_link_bw * 1e6 / ds.num_layers,
         "per seq per layer; paper ~200us")
    emit("t2.migrate_2k_seq.modeled",
         2000 * kv_bytes_per_token(ds) / 25e9 * 1e6,
         "over 200Gb/s IB; paper ~2.9ms for 144MB MLA KV")
    emit("t2.partition_reconfig.modeled", 7.0e6,
         "parallelism reconfig; paper 5-10s")
    emit("t2.crossnode_sync.modeled", 7000.0,
         "per 64-token page; paper 5-10ms")


if __name__ == "__main__":
    run()
