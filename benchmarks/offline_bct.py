"""Table 3 reproduction: offline-inference batch completion time.

6K sequences, prefill-heavy (8K->2K) and decode-heavy (2K->8K), on 8- and
16-GPU clusters, BatchGen coroutine scheduling vs the static-binding
baseline (SGLang-like).  Times come from the §5.4 performance model through
the *real* scheduler (the same code path the CPU mini-engine runs)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.runtime.cluster import Cluster, fixed_workload, \
    run_static_baseline

N_SEQS = 384        # scaled 1/16 from the paper's 6K for tractable sim time
SCALE = 16


def run():
    cfg = get_config("qwen3_moe_30b")     # assigned-pool MoE stand-in
    hw = plan_lib.Hardware()
    for name, (i, o) in {"prefill_heavy_8k_2k": (8192, 2048),
                         "decode_heavy_2k_8k": (2048, 8192)}.items():
        for gpus in (8, 16):
            wl = fixed_workload(N_SEQS, i, o)
            cl = Cluster(cfg, hw, nodes=gpus // 8, devices_per_node=8,
                         max_active=512, max_len=i + o + 64)
            rep = cl.run(wl)
            base = run_static_baseline(cfg, hw, wl, nodes=gpus // 8,
                                       max_active=64, max_len=i + o + 64)
            bct_min = rep["bct_s"] * SCALE / 60
            base_min = base["bct_s"] * SCALE / 60
            emit(f"t3.batchgen.{name}.{gpus}gpu", rep["bct_s"] * 1e6,
                 f"BCT={bct_min:.1f}min util={rep['utilization']:.2f}")
            emit(f"t3.static.{name}.{gpus}gpu", base["bct_s"] * 1e6,
                 f"BCT={base_min:.1f}min")
            emit(f"t3.speedup.{name}.{gpus}gpu", 0.0,
                 f"{base['bct_s']/rep['bct_s']:.2f}x (paper 1.25-1.85x)")


if __name__ == "__main__":
    run()
