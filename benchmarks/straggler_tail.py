"""Straggler tail A/B: batch completion time with one persistently slow
node, mitigation on vs off, on the virtual-clock SimEngine cluster.

One node of a 3-node cluster runs ``factor`` x slow from tick 1 (a
``FaultPlan.straggler`` injection — same tokens per round, inflated
virtual clock).  The A leg runs with the scheduler's default straggler
mitigation (ProgressTracker detection -> NODE_SLOW shedding -> hedged
re-execution); the B leg disables it (``mitigate_stragglers=False``) so
the batch's tail is hostage to the slow node.  Asserts:

* both legs' tokens are bitwise identical to the fault-free run, and
* mitigation cuts batch completion time >= 1.3x (the acceptance gate).

A NodeEngine parity leg re-checks the detect path on real engines: a
4x straggler must be flagged slow, never declared dead, with tokens
unchanged.  ``--smoke`` shrinks everything for CI; assertions are
identical except the real-engine leg is skipped (it needs a model
build).  Results land in ``BENCH_straggler.json``.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, write_json
from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.core.scheduler import SchedulerConfig
from repro.runtime.cluster import Cluster, fixed_workload
from repro.runtime.faults import FaultPlan

CFG_NAME = "qwen3_moe_30b"
FACTOR = 4.0


def _run(n, out_len, fault_plan, sched_cfg=None):
    cl = Cluster(get_config(CFG_NAME), plan_lib.Hardware(), nodes=3,
                 max_active=16, max_len=4096, fault_plan=fault_plan,
                 sched_cfg=sched_cfg)
    wl = fixed_workload(n, 128, out_len)
    ids = cl.sched.submit(wl.prompts, wl.max_out)
    rep = cl.sched.run(max_ticks=200000)
    assert rep["status"] == "completed", rep["status"]
    toks = {i: list(cl.sched.cos[i].generated) for i in ids}
    return rep, toks


def _leg(rep, toks, toks_free):
    assert toks == toks_free, "mitigation must not change a single token"
    rb = rep["robustness"]
    return {"bct_s": rep["bct_s"], "slow_flags": rb["slow_flags"],
            "sheds": rb["sheds"], "shed_migrations": rb["shed_migrations"],
            "hedges": rb["hedges"]}


def _sim_ab(n, out_len):
    _, toks_free = _run(n, out_len, None)
    rep_on, toks_on = _run(n, out_len, FaultPlan.straggler(0, factor=FACTOR))
    rep_off, toks_off = _run(
        n, out_len, FaultPlan.straggler(0, factor=FACTOR),
        SchedulerConfig(page_size=64, mitigate_stragglers=False))
    on, off = _leg(rep_on, toks_on, toks_free), _leg(rep_off, toks_off,
                                                    toks_free)
    assert on["slow_flags"] >= 1 and on["sheds"] >= 1
    speedup = off["bct_s"] / on["bct_s"]
    emit("straggler.mitigated", on["bct_s"] * 1e6,
         f"bct {off['bct_s']:.1f}s->{on['bct_s']:.1f}s ({speedup:.2f}x) "
         f"sheds={on['sheds']} hedges={on['hedges']['launched']}")
    assert speedup >= 1.3, \
        f"mitigation must cut the {FACTOR}x-straggler tail >= 1.3x, " \
        f"got {speedup:.2f}x"
    return {"n": n, "out_len": out_len, "factor": FACTOR,
            "speedup": speedup, "on": on, "off": off}


def _real_parity():
    """NodeEngine detect-path leg: flagged slow, never dead, bitwise."""
    import numpy as np

    from repro.configs import reduced_config
    from repro.core.scheduler import CoroutineScheduler
    from repro.runtime.engine import NodeEngine
    from repro.sampling import SamplingParams

    def run(fault_plan):
        cfg = reduced_config("llama3_2_1b")
        rng = np.random.default_rng(5)
        engines = [NodeEngine(cfg, node_id=i, max_active=3, max_len=96,
                              page_size=8, seed=0) for i in range(2)]
        sched = CoroutineScheduler(engines, SchedulerConfig(page_size=8),
                                   fault_plan=fault_plan)
        prompts = [list(rng.integers(2, 100, 5)) for _ in range(6)]
        ids = sched.submit(prompts, [64] * 6,
                           sampling=SamplingParams())
        rep = sched.run(max_ticks=2000)
        return rep, {i: list(sched.cos[i].generated) for i in ids}

    rep0, toks0 = run(None)
    rep1, toks1 = run(FaultPlan.straggler(0, factor=FACTOR))
    rb = rep1["robustness"]
    assert toks1 == toks0 and rep1["completed"] == rep0["completed"] == 6
    assert rb["slow_flags"] >= 1 and rb["failed_nodes"] == []
    emit("straggler.real_parity", rep1["bct_s"] * 1e6,
         f"flags={rb['slow_flags']} failovers={rb['health_failovers']}")
    return {"slow_flags": rb["slow_flags"],
            "health_failovers": rb["health_failovers"]}


def run(smoke: bool = False):
    # survivors need slot headroom for shed targets (48 seqs on 48 slots
    # would leave nowhere to move work): both legs run the cluster at
    # partial subscription, which is the regime the mitigation targets
    ab = _sim_ab(n=24, out_len=2048) if smoke else _sim_ab(n=30,
                                                           out_len=2048)
    payload = {"sim_ab": ab, "mode": "smoke" if smoke else "full"}
    if not smoke:
        payload["real_parity"] = _real_parity()
    write_json("straggler", payload)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    run(smoke=ap.parse_args().smoke)
