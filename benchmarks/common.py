"""Shared benchmark helpers: CSV emission, BENCH_*.json output, timing."""
from __future__ import annotations

import json
import time
from typing import Dict, List

ROWS: List[str] = []
WRITTEN: List[str] = []     # BENCH_*.json paths written this process


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row)


def write_json(name: str, payload: Dict) -> str:
    """Write machine-readable results to ``BENCH_<name>.json`` (cwd) so the
    perf trajectory is diffable across PRs."""
    path = f"BENCH_{name}.json"
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    WRITTEN.append(path)
    print(f"# wrote {path}")
    return path


def rows_as_dicts(rows: List[str]) -> List[Dict]:
    out = []
    for r in rows:
        name, us, derived = r.split(",", 2)
        out.append({"name": name, "us_per_call": float(us),
                    "derived": derived})
    return out


def timed(fn, *args, repeat=3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    import jax

    jax.block_until_ready(out) if out is not None else None
    return (time.perf_counter() - t0) / repeat * 1e6, out
