"""Shared benchmark helpers: CSV emission + cluster construction."""
from __future__ import annotations

import time
from typing import List

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row)


def timed(fn, *args, repeat=3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    import jax

    jax.block_until_ready(out) if out is not None else None
    return (time.perf_counter() - t0) / repeat * 1e6, out
