"""Decode throughput: fused megastep vs the seed per-token loop.

The steady-state decode loop is where batch throughput is won or lost
(PAPER.md; "Mind the Memory Gap" calls out the dispatch-bound regime).
This benchmark drives the REAL mini-engine through the full coroutine
scheduler twice — NodeEngine(fused=True), one jitted lax.scan per page,
vs NodeEngine(fused=False), one jitted step + host round-trip per token —
and reports end-to-end tokens/s.  Results go to
``BENCH_decode_throughput.json`` so the perf trajectory is tracked.
``--overlap`` A/Bs the pipelined host-KV sync (stage + SYNC_DRAIN
overlap) against blocking sync on the same fused path and reports the
hidden-transfer fraction.

    PYTHONPATH=src python benchmarks/decode_throughput.py [--tiny]
        [--sampled [--vocab-sweep]] [--stream] [--overlap]
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

if __package__ in (None, ""):      # `python benchmarks/decode_throughput.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import emit, write_json
from repro.configs import reduced_config
from repro.core.events import TokenBlockEvent
from repro.core.scheduler import CoroutineScheduler, SchedulerConfig
from repro.runtime.engine import NodeEngine
from repro.sampling import SamplingParams


def _throughput(cfg, *, fused: bool, max_active: int, page: int,
                max_out: int, repeats: int = 3, sampling=None) -> dict:
    eng = NodeEngine(cfg, max_active=max_active, max_len=max_out + 32,
                     page_size=page, seed=0, fused=fused)
    prompts = [[2, 3, 4, 5, 6, 7, 8, 9]] * max_active

    def once():
        sched = CoroutineScheduler([eng], SchedulerConfig(page_size=page))
        sched.submit(prompts, [max_out] * max_active, sampling=sampling)
        t0 = time.perf_counter()
        rep = sched.run(max_ticks=100000)
        dt = time.perf_counter() - t0
        assert rep["completed"] == max_active
        return max_active * max_out / dt

    once()                                  # warmup: compile everything
    d2h0, steps0 = eng.d2h_transfers, eng.decode_steps
    tok_s = max(once() for _ in range(repeats))   # best-of-N: least noise
    return {"tokens_per_s": tok_s,
            "d2h_transfers": (eng.d2h_transfers - d2h0) // repeats,
            "decode_steps": (eng.decode_steps - steps0) // repeats}


def _throughput_stream(cfg, *, max_active: int, page: int, max_out: int,
                       repeats: int = 3) -> dict:
    """Fused decode consumed through ``sched.stream()`` — measures the
    per-record generator overhead of the stream-first surface vs the
    blocking ``run()`` collection on the identical engine path."""
    eng = NodeEngine(cfg, max_active=max_active, max_len=max_out + 32,
                     page_size=page, seed=0, fused=True)
    prompts = [[2, 3, 4, 5, 6, 7, 8, 9]] * max_active

    def once():
        sched = CoroutineScheduler([eng], SchedulerConfig(page_size=page))
        sched.submit(prompts, [max_out] * max_active)
        n_tok = 0
        t0 = time.perf_counter()
        for rec in sched.stream(max_ticks=100000, kinds=TokenBlockEvent):
            n_tok += len(rec.tokens)
        dt = time.perf_counter() - t0
        assert n_tok == max_active * max_out
        return n_tok / dt

    once()                                  # warmup: compile everything
    d2h0 = eng.d2h_transfers
    tok_s = max(once() for _ in range(repeats))
    return {"tokens_per_s": tok_s,
            "d2h_transfers": (eng.d2h_transfers - d2h0) // repeats}


def run_stream(tiny: bool = False) -> dict:
    """Streaming-vs-blocking overhead tracking -> BENCH_stream_decode.json.

    Both sides run the identical fused megastep; the delta is pure
    host-side event-loop + record-emission cost, which must stay small
    (the device program dominates on a real accelerator)."""
    cfg = dataclasses.replace(reduced_config("llama3_2_1b"),
                              dtype="float32", num_layers=1, d_model=64,
                              d_ff=128, head_dim=16, vocab_size=256)
    max_active, page, max_out = (2, 8, 12) if tiny else (8, 64, 96)
    blocking = _throughput(cfg, fused=True, max_active=max_active,
                           page=page, max_out=max_out)
    stream = _throughput_stream(cfg, max_active=max_active, page=page,
                                max_out=max_out)
    overhead = blocking["tokens_per_s"] / stream["tokens_per_s"]
    emit("decode.stream.tok_s", 1e6 / stream["tokens_per_s"],
         f"{stream['tokens_per_s']:.0f} tok/s, "
         f"{stream['d2h_transfers']} d2h")
    emit("decode.stream.vs_blocking", 0.0, f"{overhead:.2f}x overhead")
    payload = {
        "config": {"arch": "llama3_2_1b(reduced)", "max_active": max_active,
                   "page_size": page, "max_out": max_out, "tiny": tiny},
        "blocking_run": blocking, "stream": stream,
        "stream_overhead_vs_run": overhead,
    }
    write_json("stream_decode", payload)
    return payload


def run_overlap(tiny: bool = False) -> dict:
    """Pipelined vs blocking host-KV sync A/B (--overlap).

    Both sides run the identical fused megastep and the identical jitted
    sync gather; the delta is purely WHERE the blob materializes —
    ``overlap=True`` stages it with an async device→host copy at SYNC
    and lands it at the next round's SYNC_DRAIN (behind the following
    megastep), ``overlap=False`` blocks at SYNC like the seed path.
    Reports end-to-end page-loop speedup, the per-run wall time spent
    blocked on sync materialization, and the hidden-transfer fraction
    (1 - pipelined_wait / blocking_wait).  Generated tokens must be
    bitwise identical — asserted here, and host-store parity is held by
    tests/test_overlap_sync.py."""
    cfg = dataclasses.replace(reduced_config("llama3_2_1b"),
                              dtype="float32", num_layers=1, d_model=64,
                              d_ff=128, head_dim=16, vocab_size=256)
    max_active, page, max_out = (2, 8, 12) if tiny else (8, 64, 96)
    eng_o = NodeEngine(cfg, max_active=max_active, max_len=max_out + 32,
                       page_size=page, seed=0, fused=True, overlap=True)
    eng_b = NodeEngine(cfg, max_active=max_active, max_len=max_out + 32,
                       page_size=page, seed=0, fused=True, overlap=False)
    prompts = [[2, 3, 4, 5, 6, 7, 8, 9]] * max_active

    def once(e):
        sched = CoroutineScheduler([e], SchedulerConfig(page_size=page))
        ids = sched.submit(prompts, [max_out] * max_active)
        t0 = time.perf_counter()
        rep = sched.run(max_ticks=100000)
        dt = time.perf_counter() - t0
        assert rep["completed"] == max_active
        return (max_active * max_out / dt,
                [tuple(sched.cos[i].generated) for i in ids])

    _, toks_o = once(eng_o)                 # warmup: compile everything
    _, toks_b = once(eng_b)
    assert toks_o == toks_b, "pipelined sync changed generated tokens"
    w0_o, w0_b = eng_o.sync_wait_s, eng_b.sync_wait_s
    stages0, stalls0 = eng_o.sync_stages, eng_o.sync_stalls
    # interleaved best-of-N so machine-load drift cancels out of the ratio
    o_tok = b_tok = 0.0
    repeats = 3
    for _ in range(repeats):
        o_tok = max(o_tok, once(eng_o)[0])
        b_tok = max(b_tok, once(eng_b)[0])
    wait_o = (eng_o.sync_wait_s - w0_o) / repeats
    wait_b = (eng_b.sync_wait_s - w0_b) / repeats
    hidden = max(0.0, min(1.0, 1.0 - wait_o / wait_b)) if wait_b > 0 else 0.0
    speedup = o_tok / b_tok
    emit("decode.overlap.tok_s", 1e6 / o_tok,
         f"{o_tok:.0f} tok/s, {wait_o*1e3:.2f} ms sync wait")
    emit("decode.overlap.blocking.tok_s", 1e6 / b_tok,
         f"{b_tok:.0f} tok/s, {wait_b*1e3:.2f} ms sync wait")
    emit("decode.overlap.speedup", 0.0,
         f"{speedup:.2f}x, {hidden:.0%} of sync wait hidden")
    return {
        "config": {"arch": "llama3_2_1b(reduced)", "max_active": max_active,
                   "page_size": page, "max_out": max_out, "tiny": tiny},
        "pipelined": {"tokens_per_s": o_tok, "sync_wait_s": wait_o,
                      "sync_stages": (eng_o.sync_stages - stages0)
                      // repeats,
                      "sync_stalls": (eng_o.sync_stalls - stalls0)
                      // repeats},
        "blocking": {"tokens_per_s": b_tok, "sync_wait_s": wait_b},
        "speedup": speedup,
        "hidden_transfer_fraction": hidden,
        "tokens_bitwise_identical": True,
    }


def run(tiny: bool = False) -> dict:
    # Dispatch-bound regime: per-step device compute must sit well below
    # the per-token host round-trip the looped path pays, as it does for a
    # real model on an accelerator.  On CPU that means fp32 (bf16 is
    # software-emulated) and small dims.
    cfg = dataclasses.replace(reduced_config("llama3_2_1b"),
                              dtype="float32", num_layers=1, d_model=64,
                              d_ff=128, head_dim=16, vocab_size=256)
    max_active, page, max_out = (2, 8, 12) if tiny else (8, 64, 96)
    looped = _throughput(cfg, fused=False, max_active=max_active,
                         page=page, max_out=max_out)
    fused = _throughput(cfg, fused=True, max_active=max_active,
                        page=page, max_out=max_out)
    speedup = fused["tokens_per_s"] / looped["tokens_per_s"]
    emit("decode.looped.tok_s", 1e6 / looped["tokens_per_s"],
         f"{looped['tokens_per_s']:.0f} tok/s, "
         f"{looped['d2h_transfers']} d2h")
    emit("decode.fused.tok_s", 1e6 / fused["tokens_per_s"],
         f"{fused['tokens_per_s']:.0f} tok/s, "
         f"{fused['d2h_transfers']} d2h")
    emit("decode.fused.speedup", 0.0, f"{speedup:.2f}x")
    payload = {
        "config": {"arch": "llama3_2_1b(reduced)", "max_active": max_active,
                   "page_size": page, "max_out": max_out, "tiny": tiny},
        "looped": looped, "fused": fused, "speedup": speedup,
    }
    write_json("decode_throughput", payload)
    return payload


def run_vocab_sweep(tiny: bool = False) -> dict:
    """Sampling-overhead trajectory over real vocabulary sizes.

    The single-pass pipeline's claim is that sampled-decode overhead
    stays bounded as V grows to 128k (the top-kc tier does ONE partial
    sort over V and everything else in the (B, kc) lanes, so the extra
    per-step work beyond the greedy head is ~V-independent).  Each sweep
    point runs the REAL engine fused path greedy vs sampled and records
    ``sampling_overhead_vs_greedy``; the driver folds the table into
    ``BENCH_sampled_decode.json`` so the growth curve is tracked
    PR-over-PR."""
    vocabs = (512, 4096) if tiny else (512, 32768, 131072)
    max_active, page, max_out = (2, 8, 8) if tiny else (8, 64, 96)
    sp = SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=0)
    sweep = {}
    for V in vocabs:
        cfg = dataclasses.replace(reduced_config("llama3_2_1b"),
                                  dtype="float32", num_layers=1, d_model=64,
                                  d_ff=128, head_dim=16, vocab_size=V)
        # one engine, greedy/sampled runs interleaved (best-of-N each) so
        # machine-load drift between the two measurements cancels
        eng = NodeEngine(cfg, max_active=max_active, max_len=max_out + 32,
                         page_size=page, seed=0, fused=True)
        prompts = [[2, 3, 4, 5, 6, 7, 8, 9]] * max_active

        def once(sampling):
            sched = CoroutineScheduler([eng], SchedulerConfig(page_size=page))
            sched.submit(prompts, [max_out] * max_active, sampling=sampling)
            t0 = time.perf_counter()
            rep = sched.run(max_ticks=100000)
            dt = time.perf_counter() - t0
            assert rep["completed"] == max_active
            return max_active * max_out / dt

        once(None), once(sp)                      # warmup: compile both
        g = s = 0.0
        for _ in range(3 if V > 4096 else 5):     # small V: noisier ratio
            g = max(g, once(None))
            s = max(s, once(sp))
        overhead = g / s
        emit(f"decode.sampled.V{V}.vs_greedy", 0.0, f"{overhead:.2f}x")
        sweep[str(V)] = {"greedy_tok_s": g, "sampled_tok_s": s,
                         "sampling_overhead_vs_greedy": overhead}
    return {"config": {"max_active": max_active, "page_size": page,
                       "max_out": max_out, "tiny": tiny,
                       "sampling": {"temperature": 0.8, "top_k": 40,
                                    "top_p": 0.95}},
            "by_vocab": sweep}


def run_sampled(tiny: bool = False, vocab_sweep: bool = False) -> dict:
    """Sampled-decode variant: temperature/top-k/top-p active (single-pass
    joint-threshold pipeline + Gumbel-max in the megastep carry).
    Measures the fused one-transfer-per-page path against the per-token
    looped baseline AND reports the sampling overhead vs greedy fused
    decode.  Results go to ``BENCH_sampled_decode.json``."""
    cfg = dataclasses.replace(reduced_config("llama3_2_1b"),
                              dtype="float32", num_layers=1, d_model=64,
                              d_ff=128, head_dim=16, vocab_size=256)
    max_active, page, max_out = (2, 8, 12) if tiny else (8, 64, 96)
    sp = SamplingParams(temperature=0.8, top_k=40, top_p=0.95, seed=0)
    # all three measurements interleave best-of-N runs (the reported
    # ratios must not be at the mercy of machine-load drift between
    # separately-timed measurements)
    eng = NodeEngine(cfg, max_active=max_active, max_len=max_out + 32,
                     page_size=page, seed=0, fused=True)
    eng_l = NodeEngine(cfg, max_active=max_active, max_len=max_out + 32,
                       page_size=page, seed=0, fused=False)
    prompts = [[2, 3, 4, 5, 6, 7, 8, 9]] * max_active

    def once(e, sampling):
        sched = CoroutineScheduler([e], SchedulerConfig(page_size=page))
        sched.submit(prompts, [max_out] * max_active, sampling=sampling)
        t0 = time.perf_counter()
        rep = sched.run(max_ticks=100000)
        dt = time.perf_counter() - t0
        assert rep["completed"] == max_active
        return max_active * max_out / dt

    once(eng, sp), once(eng, None), once(eng_l, sp)   # warmup: compile all
    d2h0, steps0 = eng.d2h_transfers, eng.decode_steps
    d2h0_l = eng_l.d2h_transfers
    f_tok = g_tok = l_tok = 0.0
    for _ in range(3):
        f_tok = max(f_tok, once(eng, sp))
        g_tok = max(g_tok, once(eng, None))
        l_tok = max(l_tok, once(eng_l, sp))
    per = (eng.d2h_transfers - d2h0) // 6, (eng.decode_steps - steps0) // 6
    fused = {"tokens_per_s": f_tok, "d2h_transfers": per[0],
             "decode_steps": per[1]}
    greedy = {"tokens_per_s": g_tok, "d2h_transfers": per[0],
              "decode_steps": per[1]}
    looped = {"tokens_per_s": l_tok,
              "d2h_transfers": (eng_l.d2h_transfers - d2h0_l) // 3,
              "decode_steps": per[1]}
    speedup = fused["tokens_per_s"] / looped["tokens_per_s"]
    overhead = greedy["tokens_per_s"] / fused["tokens_per_s"]
    emit("decode.sampled.looped.tok_s", 1e6 / looped["tokens_per_s"],
         f"{looped['tokens_per_s']:.0f} tok/s, "
         f"{looped['d2h_transfers']} d2h")
    emit("decode.sampled.fused.tok_s", 1e6 / fused["tokens_per_s"],
         f"{fused['tokens_per_s']:.0f} tok/s, "
         f"{fused['d2h_transfers']} d2h")
    emit("decode.sampled.speedup", 0.0, f"{speedup:.2f}x")
    emit("decode.sampled.vs_greedy", 0.0, f"{overhead:.2f}x slower")
    payload = {
        "config": {"arch": "llama3_2_1b(reduced)", "max_active": max_active,
                   "page_size": page, "max_out": max_out, "tiny": tiny,
                   "sampling": {"temperature": 0.8, "top_k": 40,
                                "top_p": 0.95}},
        "looped": looped, "fused": fused, "greedy_fused": greedy,
        "speedup": speedup, "sampling_overhead_vs_greedy": overhead,
    }
    if vocab_sweep:
        payload["vocab_sweep"] = run_vocab_sweep(tiny=tiny)
    write_json("sampled_decode", payload)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-sized run for CI")
    ap.add_argument("--sampled", action="store_true",
                    help="run the sampled-decode variant too")
    ap.add_argument("--vocab-sweep", action="store_true",
                    help="with --sampled: sweep V in {512, 32k, 128k}")
    ap.add_argument("--stream", action="store_true",
                    help="run the streaming-API variant too")
    ap.add_argument("--overlap", action="store_true",
                    help="A/B the pipelined vs blocking host-KV sync")
    args = ap.parse_args()
    p = run(tiny=args.tiny)
    print(f"fused {p['fused']['tokens_per_s']:.0f} tok/s vs looped "
          f"{p['looped']['tokens_per_s']:.0f} tok/s -> "
          f"{p['speedup']:.2f}x")
    if args.overlap:
        o = run_overlap(tiny=args.tiny)
        p["overlap"] = o
        write_json("decode_throughput", p)
        print(f"overlap: {o['pipelined']['tokens_per_s']:.0f} tok/s vs "
              f"blocking {o['blocking']['tokens_per_s']:.0f} tok/s -> "
              f"{o['speedup']:.2f}x "
              f"({o['hidden_transfer_fraction']:.0%} sync wait hidden)")
    if args.sampled:
        s = run_sampled(tiny=args.tiny, vocab_sweep=args.vocab_sweep)
        print(f"sampled: fused {s['fused']['tokens_per_s']:.0f} tok/s vs "
              f"looped {s['looped']['tokens_per_s']:.0f} tok/s -> "
              f"{s['speedup']:.2f}x "
              f"({s['sampling_overhead_vs_greedy']:.2f}x vs greedy)")
        for V, row in s.get("vocab_sweep", {}).get("by_vocab", {}).items():
            print(f"  V={V}: "
                  f"{row['sampling_overhead_vs_greedy']:.2f}x vs greedy")
    if args.stream:
        st = run_stream(tiny=args.tiny)
        print(f"stream: {st['stream']['tokens_per_s']:.0f} tok/s vs "
              f"blocking {st['blocking_run']['tokens_per_s']:.0f} tok/s -> "
              f"{st['stream_overhead_vs_run']:.2f}x overhead")


if __name__ == "__main__":
    main()
